"""Paged-vs-resident ClientStore bench (ISSUE 6): the price of out-of-core.

Two questions, two sections:

* :func:`smoke_section` — at a population that still FITS on device,
  what round-rate overhead does chunk-boundary paging add over the
  resident scanned driver (``paging_overhead`` gate, a machine-
  independent ratio of back-to-back timings), and how many device bytes
  does a staged chunk hold vs the resident banks (``paging_bytes_ratio``
  gate — EXACT byte counts from the stores' own accounting, so a paging
  regression that silently stages the whole population fails tier-1)?
* :func:`scale` — the N ≥ 10⁵ STATEFUL smoke the resident engine cannot
  hold at real model sizes: scaffold (per-client control variates) over
  100k clients, with the device-bytes watermark sampled from
  ``jax.live_arrays()`` at every chunk boundary and ASSERTED under a
  fraction of the resident footprint.  Run it in a FRESH process
  (``python -m benchmarks.bench_paging --scale``, its own CI stage) so
  other benches' leftover device arrays can't pollute the watermark.

PR 10 adds the DISK rung (``repro.fl.coldstore``), two more sections:

* :func:`coldtier_section` — the mmap tier's price over host-paged at
  the smoke size (``coldtier_overhead`` timing gate) and the exact
  resident-vs-staged byte ratio through the disk tier
  (``coldtier_bytes_ratio`` gate).
* :func:`scale_cold` (``--scale --tier mmap``, fresh process) — the
  N = 10⁶ residency rung: a million stateless clients stream from an
  on-disk dataset with host RSS asserted BOUNDED (the cold bytes never
  enter the process), then N = 2.5·10⁵ STATEFUL scaffold clients with
  sparse zero-init mmap state and the device watermark assert, plus a
  scatter-overlap on/off timing pair on the stateful rung.
"""
from __future__ import annotations

import os
import sys
import time

import jax
import numpy as np

from repro.core.algorithms import HParams
from repro.data import FederatedDataset
from repro.fl.simulate import FedSim
from repro.fl.store import device_bytes
from repro.fl.tasks import ConvexTask
from repro.models.simple import LogisticModel

from benchmarks.common import emit


def _convex_ds(n, d, n_clients, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=d).astype(np.float32) / np.sqrt(d)
    y = np.sign(x @ w + 0.1 * rng.normal(size=n)).astype(np.float32)
    y[y == 0] = 1.0
    return FederatedDataset.from_arrays({"x": x, "y": y}, n_clients,
                                        alpha=0.0, seed=seed, test_frac=0.1)


def _bank_bytes(bank) -> int:
    return device_bytes({"x": bank.x, "y": bank.y, "sizes": bank.sizes})


def smoke_section(rounds=32, n_clients=256, s=16, eval_every=8, d=32,
                  reps=3):
    """paged/resident scanned us/round + exact staged-vs-resident bytes.

    scaffold keeps the comparison honest: per-client control variates
    make the paged path gather AND scatter state every chunk — the full
    cost, not the stateless free case."""
    ds = _convex_ds(n=4 * n_clients, d=d, n_clients=n_clients)
    task = ConvexTask(LogisticModel(d=d, lam=1e-3))
    hp = HParams(lr=0.3)

    def scanned_once(sim, seed):
        t0 = time.perf_counter()
        st, _ = sim.run_scanned(jax.random.PRNGKey(seed), rounds,
                                sample_clients=s, eval_every=eval_every)
        jax.block_until_ready(st.params)
        return (time.perf_counter() - t0) / rounds * 1e6

    out = {}
    for tag, bank in (("resident", ds.device_bank(steps=1, batch=0)),
                      ("paged", ds.paged_bank(steps=1, batch=0))):
        sim = FedSim(task.with_data(bank), "scaffold", hp, n_clients)
        scanned_once(sim, 0)                          # compile
        out[tag] = (sim, min(scanned_once(sim, r) for r in range(reps)))
    us_r, us_p = out["resident"][1], out["paged"][1]
    emit("paging/scanned/resident", us_r,
         f"rounds={rounds},S={s}/{n_clients},chunk={eval_every}")
    emit("paging/scanned/paged", us_p,
         f"overhead_vs_resident={us_p / us_r:.2f}x")

    # exact device bytes: resident rows (data bank + client-state bank)
    # vs what ONE paged chunk actually staged — straight from the stores
    sim_r, sim_p = out["resident"][0], out["paged"][0]
    st_r = sim_r.init(jax.random.PRNGKey(0))
    resident_rows = _bank_bytes(sim_r.task.data) + device_bytes(st_r.clients)
    st_p = sim_p.init(jax.random.PRNGKey(0))
    sim_p.round(st_p, None, jax.random.PRNGKey(1), sample_clients=s)
    staged_rows = sim_p.task.data.last_staged_bytes \
        + st_p.clients.last_staged_bytes
    emit("paging/bytes/resident_rows", float(resident_rows),
         f"N={n_clients} data+state rows on device")
    emit("paging/bytes/staged_rows", float(staged_rows),
         f"one S={s} chunk; ratio={resident_rows / staged_rows:.2f}x")


def scale(n_clients=100_000, s=64, rounds=8, eval_every=2, d=16) -> int:
    """N ≥ 10⁵ stateful clients, device memory bounded by the cohort.

    Returns nonzero (CI stage failure) if the device watermark is not a
    small fraction of what the resident engine would hold."""
    ds = _convex_ds(n=n_clients, d=d, n_clients=n_clients)
    task = ConvexTask(LogisticModel(d=d, lam=1e-3))
    bank = ds.paged_bank(steps=1, batch=0)
    sim = FedSim(task.with_data(bank), "scaffold", HParams(lr=0.3),
                 n_clients)

    peak = 0

    def watermark(params):
        nonlocal peak
        live = sum(int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
                   for a in jax.live_arrays())
        peak = max(peak, live)
        return 0.0

    t0 = time.perf_counter()
    st, _ = sim.run_scanned(jax.random.PRNGKey(0), rounds,
                            sample_clients=s, eval_every=eval_every,
                            eval_fn=watermark)
    jax.block_until_ready(st.params)
    us = (time.perf_counter() - t0) / rounds * 1e6

    # what the resident engine would pin on device for the same run
    state_row = sum(int(np.prod(np.shape(x))) * 4
                    for x in jax.tree.leaves(
                        sim.algo.init_client(task, st.params)))
    resident = bank.host_bytes() + n_clients * state_row
    host = bank.host_bytes() + st.clients.host_bytes()
    emit("paging/scale/round_us", us,
         f"N={n_clients},S={s},chunk={eval_every},scaffold")
    emit("paging/scale/device_peak_bytes", float(peak),
         f"host_cold={host}B,resident_equiv={resident}B")
    assert not st.clients.stateless, "scale run must be STATEFUL"
    if peak * 4 > resident:
        print(f"PAGING-SCALE-FAIL: device watermark {peak}B is not "
              f"bounded by the cohort (resident equiv {resident}B)",
              file=sys.stderr)
        return 1
    print(f"PAGING-SCALE-OK: peak {peak}B on device for N={n_clients} "
          f"stateful clients ({resident // max(peak, 1)}x under resident)")
    return 0


def coldtier_section(rounds=32, n_clients=256, s=16, eval_every=8, d=32,
                     reps=3):
    """mmap cold tier vs host-paged: scanned us/round + exact bytes.

    Same shape as :func:`smoke_section` one tier further out — scaffold
    keeps state gather/scatter on the clock, and the staged chunks are
    bytewise identical across tiers, so the timing ratio isolates pure
    disk-tier cost (page faults + the pinned staging hop)."""
    ds = _convex_ds(n=4 * n_clients, d=d, n_clients=n_clients)
    task = ConvexTask(LogisticModel(d=d, lam=1e-3))
    hp = HParams(lr=0.3)

    def scanned_once(sim, seed):
        t0 = time.perf_counter()
        st, _ = sim.run_scanned(jax.random.PRNGKey(seed), rounds,
                                sample_clients=s, eval_every=eval_every)
        jax.block_until_ready(st.params)
        return (time.perf_counter() - t0) / rounds * 1e6

    from repro.data.streaming import StreamingFederatedDataset
    sfd = StreamingFederatedDataset.from_dataset(ds)
    with sfd.mmap_bank(steps=1, batch=0, owned=True) as mbank:
        out = {}
        for tag, bank in (("hostpaged", ds.paged_bank(steps=1, batch=0)),
                          ("mmap", mbank)):
            sim = FedSim(task.with_data(bank), "scaffold", hp, n_clients)
            scanned_once(sim, 0)                      # compile
            out[tag] = (sim, min(scanned_once(sim, r) for r in range(reps)))
        us_h, us_m = out["hostpaged"][1], out["mmap"][1]
        emit("coldtier/scanned/hostpaged", us_h,
             f"rounds={rounds},S={s}/{n_clients},chunk={eval_every}")
        emit("coldtier/scanned/mmap", us_m,
             f"overhead_vs_hostpaged={us_m / us_h:.2f}x")

        # exact bytes through the DISK tier: resident rows vs one staged
        # chunk — the out-of-core property itself, one rung further out
        sim_m = out["mmap"][0]
        st_m = sim_m.init(jax.random.PRNGKey(0))
        state_row = sum(int(np.prod(np.shape(x))) * 4
                        for x in jax.tree.leaves(
                            sim_m.algo.init_client(task, st_m.params)))
        resident_rows = _bank_bytes(
            _convex_ds(n=4 * n_clients, d=d,
                       n_clients=n_clients).device_bank(steps=1, batch=0)
        ) + n_clients * state_row
        sim_m.round(st_m, None, jax.random.PRNGKey(1), sample_clients=s)
        staged_rows = mbank.last_staged_bytes \
            + st_m.clients.last_staged_bytes
        emit("coldtier/bytes/resident_rows", float(resident_rows),
             f"N={n_clients} data+state rows on device")
        emit("coldtier/bytes/staged_rows", float(staged_rows),
             f"one S={s} chunk from disk; "
             f"ratio={resident_rows / staged_rows:.2f}x")


def _rss_kb(field: str = "RssAnon") -> int:
    """A resident-set line from /proc/self/status, in kB.

    ``RssAnon`` is the residency metric the cold-tier asserts on:
    process-OWNED memory (heap, device buffers on the CPU backend) that
    cannot be reclaimed.  ``RssFile`` — the mapped cold-file pages — is
    reported but not asserted: those pages are clean page cache the
    kernel drops under pressure (and on this kernel a single faulted
    row maps a whole 2 MB large folio, so the number tracks fault
    count × folio size, not memory the process is holding)."""
    with open("/proc/self/status") as f:
        for line in f:
            if line.startswith(field):
                return int(line.split()[1])
    return 0


def _stream_convex(directory, n_clients, per_client, d, seed=0):
    """Write an N-client convex dataset STRAIGHT to disk in blocks —
    the [n_samples, d] features never exist in process memory."""
    from repro.data.streaming import StreamingFederatedDataset
    rng = np.random.default_rng(seed)
    w = rng.normal(size=d).astype(np.float32) / np.sqrt(d)
    n = n_clients * per_client
    wr = StreamingFederatedDataset.writer(
        directory, x_shape=(d,), x_dtype=np.float32, y_shape=(),
        y_dtype=np.float32, m=per_client)
    block = 1 << 15
    for lo in range(0, n, block):
        x = rng.normal(size=(min(block, n - lo), d)).astype(np.float32)
        y = np.sign(x @ w + 0.1 * rng.normal(size=len(x))
                    ).astype(np.float32)
        y[y == 0] = 1.0
        wr.add_samples(x, y)
    idx = np.arange(n, dtype=np.int64).reshape(n_clients, per_client)
    sizes = np.full(n_clients, per_client, np.int32)
    for lo in range(0, n_clients, block):
        wr.add_clients(idx[lo:lo + block], sizes[lo:lo + block])
    return wr.finalize()


def scale_cold() -> int:
    """The DISK residency rungs (fresh process: ``--scale --tier mmap``).

    Rung 1 — N = 10⁶ STATELESS (fedavg): the cold bytes live on disk
    and must stay there; asserts the run's ANONYMOUS host-RSS growth
    (process-owned memory, sampled at every chunk boundary) is under
    half the cold footprint — copying the dataset into the process,
    the failure mode this tier exists to prevent, would blow straight
    past it.  Rung 2 — N = 2.5·10⁵ STATEFUL (scaffold): sparse
    zero-init mmap state, the device watermark assert from the host
    rung, and a scatter-overlap on/off timing pair (min of 2 passes
    each)."""
    import tempfile
    task32 = ConvexTask(LogisticModel(d=32, lam=1e-3))
    ok = True

    def host_cohorts(n, s, rounds, seed=0):
        """Host-drawn explicit cohorts: at this N the in-graph sampler
        (``jax.random.permutation`` over [N], vmapped over rounds) would
        dominate BOTH watermarks being asserted — O(N·rounds) device
        intermediates and arena RSS — and the rungs measure residency,
        not the sampler."""
        rng = np.random.default_rng(seed)
        return np.stack([np.sort(rng.choice(n, s, replace=False))
                         for _ in range(rounds)]).astype(np.int32)

    # ---- rung 1: N = 1e6 stateless, bounded anonymous host RSS ----
    n1 = 1_000_000
    with tempfile.TemporaryDirectory(prefix="coldscale-") as tmp:
        sfd = _stream_convex(tmp, n1, per_client=4, d=32)
        cold = sum(os.path.getsize(os.path.join(tmp, f))
                   for f in os.listdir(tmp))
        with sfd.mmap_bank(steps=1, batch=0) as bank:
            sim = FedSim(task32.with_data(bank), "fedavg", HParams(lr=0.3),
                         n1)
            anon0, file0 = _rss_kb("RssAnon"), _rss_kb("RssFile")
            peak_anon = anon0

            def anon_watermark(params):
                nonlocal peak_anon
                peak_anon = max(peak_anon, _rss_kb("RssAnon"))
                return 0.0

            t0 = time.perf_counter()
            st, _ = sim.run_scanned(jax.random.PRNGKey(0), 6,
                                    cohorts=host_cohorts(n1, 64, 6),
                                    eval_every=2, eval_fn=anon_watermark)
            jax.block_until_ready(st.params)
            us = (time.perf_counter() - t0) / 6 * 1e6
            anon_delta = (peak_anon - anon0) * 1024
            file_delta = (_rss_kb("RssFile") - file0) * 1024
        emit("coldtier/scale/n1e6_round_us", us,
             f"N={n1},S=64,chunk=2,fedavg,stateless")
        emit("coldtier/scale/n1e6_anon_delta_bytes", float(anon_delta),
             f"cold_disk={cold}B,mapped_file_delta={file_delta}B")
        assert st.clients.stateless
        if anon_delta * 2 > cold:
            print(f"COLDTIER-SCALE-FAIL: anonymous RSS grew {anon_delta}B "
                  f"against {cold}B of cold disk — the dataset is being "
                  "copied into the process", file=sys.stderr)
            ok = False

    # ---- rung 2: N = 2.5e5 stateful, device watermark + overlap ----
    n2, s, rounds, eval_every, d = 250_000, 64, 8, 2, 16
    task16 = ConvexTask(LogisticModel(d=d, lam=1e-3))
    with tempfile.TemporaryDirectory(prefix="coldscale-") as tmp:
        sfd = _stream_convex(tmp, n2, per_client=1, d=d)
        peak = 0

        def watermark(params):
            nonlocal peak
            live = sum(int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
                       for a in jax.live_arrays())
            peak = max(peak, live)
            return 0.0

        cohorts = host_cohorts(n2, s, rounds, seed=1)
        us_by_overlap = {}
        for tag, overlap in (("overlap_on", True), ("overlap_off", False)):
            with sfd.mmap_bank(steps=1, batch=0) as bank:
                sim = FedSim(task16.with_data(bank), "scaffold",
                             HParams(lr=0.3), n2, scatter_overlap=overlap)
                sim.run_scanned(jax.random.PRNGKey(0), 2,
                                cohorts=cohorts[:2],
                                eval_every=eval_every)   # compile + warmup
                best = np.inf
                for _ in range(2):                       # min-of-passes
                    t0 = time.perf_counter()
                    st, _ = sim.run_scanned(jax.random.PRNGKey(1), rounds,
                                            cohorts=cohorts,
                                            eval_every=eval_every,
                                            eval_fn=watermark)
                    jax.block_until_ready(st.params)
                    best = min(best,
                               (time.perf_counter() - t0) / rounds * 1e6)
                    st.clients.close()
                us_by_overlap[tag] = best
                state_row = sum(
                    int(np.prod(np.shape(x))) * 4 for x in jax.tree.leaves(
                        sim.algo.init_client(task16, st.params)))
                resident = bank.host_bytes() + n2 * state_row
                assert not st.clients.stateless, "rung 2 must be STATEFUL"
        on, off = us_by_overlap["overlap_on"], us_by_overlap["overlap_off"]
        emit("coldtier/scale/overlap_on", on,
             f"N={n2},S={s},chunk={eval_every},scaffold,mmap state")
        emit("coldtier/scale/overlap_off", off,
             f"sync scatter; on/off={on / off:.2f}x")
        emit("coldtier/scale/device_peak_bytes", float(peak),
             f"resident_equiv={resident}B")
        if peak * 4 > resident:
            print(f"COLDTIER-SCALE-FAIL: device watermark {peak}B is not "
                  f"bounded by the cohort (resident equiv {resident}B)",
                  file=sys.stderr)
            ok = False

    if ok:
        print(f"COLDTIER-SCALE-OK: N={n1} streamed from disk with "
              f"anon_delta={anon_delta}B; N={n2} stateful at {peak}B "
              "device watermark")
    return 0 if ok else 1


def main():
    if "--scale" in sys.argv:
        print("name,us_per_call,derived")
        tier = (sys.argv[sys.argv.index("--tier") + 1]
                if "--tier" in sys.argv else "host")
        if tier not in ("host", "mmap"):
            print(f"unknown --tier {tier!r} (host|mmap)", file=sys.stderr)
            sys.exit(2)
        sys.exit(scale_cold() if tier == "mmap" else scale())
    smoke_section()
    coldtier_section()


if __name__ == "__main__":
    main()

"""Paged-vs-resident ClientStore bench (ISSUE 6): the price of out-of-core.

Two questions, two sections:

* :func:`smoke_section` — at a population that still FITS on device,
  what round-rate overhead does chunk-boundary paging add over the
  resident scanned driver (``paging_overhead`` gate, a machine-
  independent ratio of back-to-back timings), and how many device bytes
  does a staged chunk hold vs the resident banks (``paging_bytes_ratio``
  gate — EXACT byte counts from the stores' own accounting, so a paging
  regression that silently stages the whole population fails tier-1)?
* :func:`scale` — the N ≥ 10⁵ STATEFUL smoke the resident engine cannot
  hold at real model sizes: scaffold (per-client control variates) over
  100k clients, with the device-bytes watermark sampled from
  ``jax.live_arrays()`` at every chunk boundary and ASSERTED under a
  fraction of the resident footprint.  Run it in a FRESH process
  (``python -m benchmarks.bench_paging --scale``, its own CI stage) so
  other benches' leftover device arrays can't pollute the watermark.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.core.algorithms import HParams
from repro.data import FederatedDataset
from repro.fl.simulate import FedSim
from repro.fl.store import device_bytes
from repro.fl.tasks import ConvexTask
from repro.models.simple import LogisticModel

from benchmarks.common import emit


def _convex_ds(n, d, n_clients, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=d).astype(np.float32) / np.sqrt(d)
    y = np.sign(x @ w + 0.1 * rng.normal(size=n)).astype(np.float32)
    y[y == 0] = 1.0
    return FederatedDataset.from_arrays({"x": x, "y": y}, n_clients,
                                        alpha=0.0, seed=seed, test_frac=0.1)


def _bank_bytes(bank) -> int:
    return device_bytes({"x": bank.x, "y": bank.y, "sizes": bank.sizes})


def smoke_section(rounds=32, n_clients=256, s=16, eval_every=8, d=32,
                  reps=3):
    """paged/resident scanned us/round + exact staged-vs-resident bytes.

    scaffold keeps the comparison honest: per-client control variates
    make the paged path gather AND scatter state every chunk — the full
    cost, not the stateless free case."""
    ds = _convex_ds(n=4 * n_clients, d=d, n_clients=n_clients)
    task = ConvexTask(LogisticModel(d=d, lam=1e-3))
    hp = HParams(lr=0.3)

    def scanned_once(sim, seed):
        t0 = time.perf_counter()
        st, _ = sim.run_scanned(jax.random.PRNGKey(seed), rounds,
                                sample_clients=s, eval_every=eval_every)
        jax.block_until_ready(st.params)
        return (time.perf_counter() - t0) / rounds * 1e6

    out = {}
    for tag, bank in (("resident", ds.device_bank(steps=1, batch=0)),
                      ("paged", ds.paged_bank(steps=1, batch=0))):
        sim = FedSim(task.with_data(bank), "scaffold", hp, n_clients)
        scanned_once(sim, 0)                          # compile
        out[tag] = (sim, min(scanned_once(sim, r) for r in range(reps)))
    us_r, us_p = out["resident"][1], out["paged"][1]
    emit("paging/scanned/resident", us_r,
         f"rounds={rounds},S={s}/{n_clients},chunk={eval_every}")
    emit("paging/scanned/paged", us_p,
         f"overhead_vs_resident={us_p / us_r:.2f}x")

    # exact device bytes: resident rows (data bank + client-state bank)
    # vs what ONE paged chunk actually staged — straight from the stores
    sim_r, sim_p = out["resident"][0], out["paged"][0]
    st_r = sim_r.init(jax.random.PRNGKey(0))
    resident_rows = _bank_bytes(sim_r.task.data) + device_bytes(st_r.clients)
    st_p = sim_p.init(jax.random.PRNGKey(0))
    sim_p.round(st_p, None, jax.random.PRNGKey(1), sample_clients=s)
    staged_rows = sim_p.task.data.last_staged_bytes \
        + st_p.clients.last_staged_bytes
    emit("paging/bytes/resident_rows", float(resident_rows),
         f"N={n_clients} data+state rows on device")
    emit("paging/bytes/staged_rows", float(staged_rows),
         f"one S={s} chunk; ratio={resident_rows / staged_rows:.2f}x")


def scale(n_clients=100_000, s=64, rounds=8, eval_every=2, d=16) -> int:
    """N ≥ 10⁵ stateful clients, device memory bounded by the cohort.

    Returns nonzero (CI stage failure) if the device watermark is not a
    small fraction of what the resident engine would hold."""
    ds = _convex_ds(n=n_clients, d=d, n_clients=n_clients)
    task = ConvexTask(LogisticModel(d=d, lam=1e-3))
    bank = ds.paged_bank(steps=1, batch=0)
    sim = FedSim(task.with_data(bank), "scaffold", HParams(lr=0.3),
                 n_clients)

    peak = 0

    def watermark(params):
        nonlocal peak
        live = sum(int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
                   for a in jax.live_arrays())
        peak = max(peak, live)
        return 0.0

    t0 = time.perf_counter()
    st, _ = sim.run_scanned(jax.random.PRNGKey(0), rounds,
                            sample_clients=s, eval_every=eval_every,
                            eval_fn=watermark)
    jax.block_until_ready(st.params)
    us = (time.perf_counter() - t0) / rounds * 1e6

    # what the resident engine would pin on device for the same run
    state_row = sum(int(np.prod(np.shape(x))) * 4
                    for x in jax.tree.leaves(
                        sim.algo.init_client(task, st.params)))
    resident = bank.host_bytes() + n_clients * state_row
    host = bank.host_bytes() + st.clients.host_bytes()
    emit("paging/scale/round_us", us,
         f"N={n_clients},S={s},chunk={eval_every},scaffold")
    emit("paging/scale/device_peak_bytes", float(peak),
         f"host_cold={host}B,resident_equiv={resident}B")
    assert not st.clients.stateless, "scale run must be STATEFUL"
    if peak * 4 > resident:
        print(f"PAGING-SCALE-FAIL: device watermark {peak}B is not "
              f"bounded by the cohort (resident equiv {resident}B)",
              file=sys.stderr)
        return 1
    print(f"PAGING-SCALE-OK: peak {peak}B on device for N={n_clients} "
          f"stateful clients ({resident // max(peak, 1)}x under resident)")
    return 0


def main():
    if "--scale" in sys.argv:
        print("name,us_per_call,derived")
        sys.exit(scale())
    smoke_section()


if __name__ == "__main__":
    main()

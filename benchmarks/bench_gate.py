"""Bench regression gate: compare a smoke run against the checked-in
baseline and fail tier-1 on >tol regressions.

Usage (scripts/ci.sh wires this up)::

    python -m benchmarks.run --smoke            # writes BENCH_pr5.json
    python -m benchmarks.bench_gate BENCH_pr5.json \
        benchmarks/baseline_pr5.json --tol 0.25

Both files carry a ``gates`` section of machine-independent RATIOS
(packed-vs-per-leaf speedup, K-sweep growth, sharded-vs-vmap overhead,
scanned-vs-per-round dispatch speedup — see ``benchmarks.run._gates``).
A gate regresses when its value moves past baseline·(1 ± tol) in its
``worse`` direction; a gate present in the baseline but missing from the
current run also fails (a silently dropped bench must not read as a
pass).  Refresh the baseline by copying a trusted run's BENCH_pr5.json
over benchmarks/baseline_pr5.json.
"""
from __future__ import annotations

import argparse
import json
import sys


def check(current: dict, baseline: dict, tol: float) -> list[str]:
    failures = []
    cur_gates = current.get("gates", {})
    for name, base in sorted(baseline.get("gates", {}).items()):
        cur = cur_gates.get(name)
        if cur is None:
            failures.append(f"{name}: missing from current run")
            print(f"GATE {name}: MISSING (baseline {base['value']:.3f})")
            continue
        bv, cv = float(base["value"]), float(cur["value"])
        worse = base.get("worse", "higher")
        if worse == "higher":
            bad = cv > bv * (1.0 + tol)
            bound = f"<= {bv * (1.0 + tol):.3f}"
        else:
            bad = cv < bv * (1.0 - tol)
            bound = f">= {bv * (1.0 - tol):.3f}"
        status = "FAIL" if bad else "ok"
        print(f"GATE {name}: {cv:.3f} (baseline {bv:.3f}, needs {bound}) "
              f"{status}")
        if bad:
            failures.append(f"{name}: {cv:.3f} vs baseline {bv:.3f} "
                            f"(worse={worse}, tol={tol:.0%})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="BENCH_pr5.json from this run")
    ap.add_argument("baseline", help="checked-in baseline json")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = check(current, baseline, args.tol)
    if failures:
        print("BENCH GATE FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print(f"bench gate passed ({len(baseline.get('gates', {}))} gates, "
          f"tol {args.tol:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

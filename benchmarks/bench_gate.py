"""Bench regression gate: compare a smoke run against the checked-in
baseline and fail tier-1 on >tol regressions.

Usage (scripts/ci.sh wires this up)::

    python -m benchmarks.run --smoke            # writes BENCH_pr10.json
    python -m benchmarks.bench_gate BENCH_pr10.json \
        benchmarks/baseline_pr10.json --tol 0.25

Both files carry a ``gates`` section of machine-independent RATIOS
(packed-vs-per-leaf speedup, K-sweep growth, sharded-vs-vmap overhead,
scanned-vs-per-round dispatch speedup, paged-vs-resident staging
overhead and staged-bytes ratio — see ``benchmarks.run._gates``).
A gate regresses when its value moves past baseline·(1 ± tol) in its
``worse`` direction; a gate present in the baseline but missing from the
current run also fails (a silently dropped bench must not read as a
pass).

Refresh the baseline with ``--update-baseline``::

    python -m benchmarks.bench_gate BENCH_pr10.json \
        benchmarks/baseline_pr10.json --update-baseline

which copies the current run's gates over the baseline file — but FIRST
checks the current run against the existing baseline and REFUSES to
regenerate when any gate is failing: regenerating from a regressed run
would silently widen the gate, and the next regression on top of it
would still pass.  A deliberate trade-off (e.g. a feature that costs
some sharded overhead) is recorded with ``--allow-regression``, which
prints exactly which gates moved and by how much so the widening is an
explicit, reviewable act rather than a side effect.
"""
from __future__ import annotations

import argparse
import json
import sys


def check(current: dict, baseline: dict, tol: float) -> list[str]:
    failures = []
    cur_gates = current.get("gates", {})
    for name, base in sorted(baseline.get("gates", {}).items()):
        cur = cur_gates.get(name)
        if cur is None:
            failures.append(f"{name}: missing from current run")
            print(f"GATE {name}: MISSING (baseline {base['value']:.3f})")
            continue
        bv, cv = float(base["value"]), float(cur["value"])
        worse = base.get("worse", "higher")
        if worse == "higher":
            bad = cv > bv * (1.0 + tol)
            bound = f"<= {bv * (1.0 + tol):.3f}"
        else:
            bad = cv < bv * (1.0 - tol)
            bound = f">= {bv * (1.0 - tol):.3f}"
        status = "FAIL" if bad else "ok"
        print(f"GATE {name}: {cv:.3f} (baseline {bv:.3f}, needs {bound}) "
              f"{status}")
        if bad:
            failures.append(f"{name}: {cv:.3f} vs baseline {bv:.3f} "
                            f"(worse={worse}, tol={tol:.0%})")
    return failures


def update_baseline(current: dict, baseline: dict, baseline_path: str,
                    tol: float, allow_regression: bool) -> int:
    """Regenerate ``baseline_path`` from the current run's gates.

    Guard: if the current run FAILS against the existing baseline, the
    regeneration would widen a failing gate — refuse unless the caller
    passed ``--allow-regression`` (and then list the widened gates, so
    the loosening is explicit in the CI log / PR diff)."""
    failures = check(current, baseline, tol)
    if failures and not allow_regression:
        print("REFUSING to update baseline: the current run fails the "
              "existing gates — regenerating now would silently widen "
              "them:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        print("Fix the regression, or re-run with --allow-regression to "
              "record the trade-off deliberately.", file=sys.stderr)
        return 1
    if failures:
        print(f"WIDENING {len(failures)} gate(s) (--allow-regression):")
        for f_ in failures:
            print(f"  widened {f_}")
    gates = current.get("gates", {})
    if not gates:
        print("REFUSING to update baseline: current run has no gates "
              "(did --smoke crash before writing them?)", file=sys.stderr)
        return 1
    out = {"meta": baseline.get("meta", {}), "gates": gates}
    with open(baseline_path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {baseline_path}: {len(gates)} gates")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="BENCH_pr10.json from this run")
    ap.add_argument("baseline", help="checked-in baseline json")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="regenerate the baseline from the current run "
                         "(refuses if the run fails the existing gates)")
    ap.add_argument("--allow-regression", action="store_true",
                    help="with --update-baseline: record a deliberate "
                         "gate widening instead of refusing")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    if args.update_baseline:
        return update_baseline(current, baseline, args.baseline, args.tol,
                               args.allow_regression)
    failures = check(current, baseline, args.tol)
    if failures:
        print("BENCH GATE FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print(f"bench gate passed ({len(baseline.get('gates', {}))} gates, "
          f"tol {args.tol:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

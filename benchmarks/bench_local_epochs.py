"""Paper Fig. 3: accuracy vs number of local epochs at a fixed total local
update budget (1 epoch × 3R rounds, 3 epochs × R rounds, ...), α = 0.1.

Validates: FedPM stays ahead of FedAvg/LocalNewton at every K.
derived = best accuracy.

Plus a round-latency sweep over K ∈ {1, 4, 16}: with the packed gram bank
the FOOF path factors once per round and the K scan steps are pure
solves/matmuls, so us/round must grow sublinearly in K (the seed
refactorized every step → ~linear).  derived = steps."""
from __future__ import annotations

from benchmarks.common import (DNN_HP, dnn_setup, emit, run_dnn,
                               time_dnn_round)

SCHEDULES = ((1, 18), (3, 6), (6, 3))     # (epochs, rounds): fixed budget
K_SWEEP = (1, 4, 16)


def k_sweep(setup=None, ks=K_SWEEP, algos=("fedpm_foof", "localnewton_foof"),
            batch=64, reps=5):
    """Steady-state round latency vs local-step count K for the FOOF
    algorithms (factor-once amortization trajectory).  The K-growth ratio
    us(K_max)/us(K_1) is a bench-gate metric (benchmarks.run --smoke)."""
    setup = setup or dnn_setup(alpha=0.1)
    for algo in algos:
        base = None
        for k in ks:
            us = time_dnn_round(setup, algo, DNN_HP[algo], k_steps=k,
                                batch=batch, reps=reps)
            base = base or us
            emit(f"local_epochs_ksweep/{algo}/K{k}", us,
                 f"steps={k} x_vs_K1={us / base:.2f}")


def main():
    setup = dnn_setup(alpha=0.1)
    for algo in ("fedavg", "localnewton_foof", "fedpm_foof"):
        for epochs, rounds in SCHEDULES:
            accs, us = run_dnn(setup, algo, DNN_HP[algo], rounds,
                               epochs=epochs)
            emit(f"local_epochs_fig3/{algo}/E{epochs}xR{rounds}", us,
                 f"best_acc={max(accs):.4f}")
    k_sweep(setup)


if __name__ == "__main__":
    main()

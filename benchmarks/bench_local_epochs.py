"""Paper Fig. 3: accuracy vs number of local epochs at a fixed total local
update budget (1 epoch × 3R rounds, 3 epochs × R rounds, ...), α = 0.1.

Validates: FedPM stays ahead of FedAvg/LocalNewton at every K.
derived = best accuracy."""
from __future__ import annotations

from benchmarks.common import DNN_HP, dnn_setup, emit, run_dnn

SCHEDULES = ((1, 18), (3, 6), (6, 3))     # (epochs, rounds): fixed budget


def main():
    setup = dnn_setup(alpha=0.1)
    for algo in ("fedavg", "localnewton_foof", "fedpm_foof"):
        for epochs, rounds in SCHEDULES:
            accs, us = run_dnn(setup, algo, DNN_HP[algo], rounds,
                               epochs=epochs)
            emit(f"local_epochs_fig3/{algo}/E{epochs}xR{rounds}", us,
                 f"best_acc={max(accs):.4f}")


if __name__ == "__main__":
    main()

"""Paper Fig. 6 (Appendix D.2): impact of client sampling — accuracy vs
participating clients per round ∈ {2, 5, 10} of 10, α = 0.1.

Validates: all methods degrade with fewer participants; FedPM degrades
least.  derived = best accuracy."""
from __future__ import annotations

import jax
import numpy as np

from repro.core.algorithms import HParams
from repro.data.federated import build_round_batches, steps_per_epoch
from repro.fl.simulate import FedSim

from benchmarks.common import DNN_HP, dnn_setup, emit


def main(rounds=12):
    setup = dnn_setup(alpha=0.1)
    ds, task = setup["ds"], setup["task"]
    k = steps_per_epoch(ds, 64) * 2
    for algo in ("fedavg", "scaffold", "localnewton_foof", "fedpm_foof"):
        for m in (2, 5, 10):
            sim = FedSim(task, algo, DNN_HP[algo], ds.n_clients)
            st = sim.init(jax.random.PRNGKey(0))
            _, hist = sim.run(
                jax.random.PRNGKey(0),
                lambda t, _k: build_round_batches(
                    ds, k, 64, np.random.default_rng(t)),
                rounds=rounds, sample_clients=m,
                eval_fn=lambda p: task.metric(p, setup["test"]))
            emit(f"sampling_fig6/{algo}/m{m}", 0.0,
                 f"best_acc={max(hist['metric']):.4f}")


if __name__ == "__main__":
    main()

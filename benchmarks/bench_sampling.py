"""Paper Fig. 6 (Appendix D.2): impact of client sampling — accuracy vs
participating clients per round ∈ {2, 5, 10} of 10, α = 0.1 — plus the
participation engine's compute-scaling claim: per-round wall-clock scales
with the sampled cohort size S, not N (gather/compute/scatter core).

Validates: all methods degrade with fewer participants; FedPM degrades
least; derived = best accuracy.  The scaling section emits us/round for
S ∈ {N, N/2, N/4} on the convex task — derived = speedup over full
participation (≥2× expected at S=N/4)."""
from __future__ import annotations

import jax
import numpy as np

from repro.core.algorithms import HParams
from repro.data.federated import build_round_batches, steps_per_epoch
from repro.fl.simulate import FedSim

from benchmarks.common import (DNN_HP, convex_setup, dnn_setup, emit,
                               run_convex, time_convex_round)


def fig6(rounds=12):
    setup = dnn_setup(alpha=0.1)
    ds, task = setup["ds"], setup["task"]
    k = steps_per_epoch(ds, 64) * 2
    for algo in ("fedavg", "scaffold", "localnewton_foof", "fedpm_foof"):
        for m in (2, 5, 10):
            sim = FedSim(task, algo, DNN_HP[algo], ds.n_clients)
            _, hist = sim.run(
                jax.random.PRNGKey(0),
                # participant-aware: batches are built for the cohort only
                lambda t, _k, clients: build_round_batches(
                    ds, k, 64, np.random.default_rng(t), clients=clients),
                rounds=rounds, sample_clients=m,
                eval_fn=lambda p: task.metric(p, setup["test"]))
            emit(f"sampling_fig6/{algo}/m{m}", 0.0,
                 f"best_acc={max(hist['metric']):.4f}")


def scaling(n_clients=16, reps=30):
    """Per-round client compute scales with S: us/round at S = N, N/2, N/4."""
    setup = convex_setup(n_clients=n_clients)
    hp = {"fedpm": HParams(lr=1.0, damping=1e-2),
          "fedpm_foof": HParams(lr=0.3, damping=1.0),
          "scaffold": HParams(lr=0.3)}
    for algo in ("fedpm", "fedpm_foof", "scaffold"):
        us_full = time_convex_round(setup, algo, hp[algo], reps=reps)
        for s in (n_clients, n_clients // 2, n_clients // 4):
            us = (us_full if s == n_clients else
                  time_convex_round(setup, algo, hp[algo],
                                    sample_clients=s, reps=reps))
            emit(f"sampling_scaling/{algo}/S{s}", us,
                 f"speedup_vs_full={us_full / us:.2f}x")
        # convergence is unchanged by routing through the gathered path
        errs_full, _, _ = run_convex(setup, algo, hp[algo], rounds=5)
        errs_s, _, _ = run_convex(setup, algo, hp[algo], rounds=5,
                                  sample_clients=n_clients // 4)
        emit(f"sampling_converge/{algo}",
             0.0, f"err_full={errs_full[-1]:.2e},err_S4={errs_s[-1]:.2e}")


def main(rounds=12):
    scaling()
    fig6(rounds=rounds)


if __name__ == "__main__":
    main()

"""Paper Fig. 6 (Appendix D.2): impact of client sampling — accuracy vs
participating clients per round ∈ {2, 5, 10} of 10, α = 0.1 — plus the
participation engine's compute-scaling claim: per-round wall-clock scales
with the sampled cohort size S, not N (gather/compute/scatter core).

Validates: all methods degrade with fewer participants; FedPM degrades
least; derived = best accuracy.  The scaling section emits us/round for
S ∈ {N, N/2, N/4} on the convex task — derived = speedup over full
participation (≥2× expected at S=N/4).

The sharded section times the mesh-sharded engine (``repro.fl.sharded``)
against the vmap oracle on a FORCED 8-device host mesh (subprocess —
device count locks at jax init), checks round equivalence, and reports
the per-device client-bank footprint (N/8 rows).  Its overhead ratio is
a bench-gate metric (benchmarks.run --smoke)."""
from __future__ import annotations

import os
import subprocess
import sys

import jax
import numpy as np

from repro.core.algorithms import HParams
from repro.data.federated import build_round_batches, steps_per_epoch
from repro.fl.simulate import FedSim

from benchmarks.common import (DNN_HP, convex_setup, dnn_setup, emit,
                               run_convex, time_convex_round)


def fig6(rounds=12):
    setup = dnn_setup(alpha=0.1)
    ds, task = setup["ds"], setup["task"]
    k = steps_per_epoch(ds, 64) * 2
    for algo in ("fedavg", "scaffold", "localnewton_foof", "fedpm_foof"):
        for m in (2, 5, 10):
            sim = FedSim(task, algo, DNN_HP[algo], ds.n_clients)
            _, hist = sim.run(
                jax.random.PRNGKey(0),
                # participant-aware: batches are built for the cohort only
                lambda t, _k, clients: build_round_batches(
                    ds, k, 64, np.random.default_rng(t), clients=clients),
                rounds=rounds, sample_clients=m,
                eval_fn=lambda p: task.metric(p, setup["test"]))
            emit(f"sampling_fig6/{algo}/m{m}", 0.0,
                 f"best_acc={max(hist['metric']):.4f}")


def scaling(n_clients=16, reps=30):
    """Per-round client compute scales with S: us/round at S = N, N/2, N/4."""
    setup = convex_setup(n_clients=n_clients)
    hp = {"fedpm": HParams(lr=1.0, damping=1e-2),
          "fedpm_foof": HParams(lr=0.3, damping=1.0),
          "scaffold": HParams(lr=0.3)}
    for algo in ("fedpm", "fedpm_foof", "scaffold"):
        us_full = time_convex_round(setup, algo, hp[algo], reps=reps)
        for s in (n_clients, n_clients // 2, n_clients // 4):
            us = (us_full if s == n_clients else
                  time_convex_round(setup, algo, hp[algo],
                                    sample_clients=s, reps=reps))
            emit(f"sampling_scaling/{algo}/S{s}", us,
                 f"speedup_vs_full={us_full / us:.2f}x")
        # convergence is unchanged by routing through the gathered path
        errs_full, _, _ = run_convex(setup, algo, hp[algo], rounds=5)
        errs_s, _, _ = run_convex(setup, algo, hp[algo], rounds=5,
                                  sample_clients=n_clients // 4)
        emit(f"sampling_converge/{algo}",
             0.0, f"err_full={errs_full[-1]:.2e},err_S4={errs_s[-1]:.2e}")


def sharded_worker(n_clients=16, reps=10):
    """Sharded-vs-vmap numbers; runs INSIDE the forced-8-device process.

    Emits us/round for both engines at S ∈ {N, N/4}, the max-abs round
    divergence (fp32 mixing tolerance), and the per-device bank rows."""
    import jax.numpy as jnp
    from repro.fl.sharded import bank_shard_rows, make_client_mesh

    setup = convex_setup(n_clients=n_clients)
    mesh = make_client_mesh()
    nd = jax.device_count()
    hp = {"fedpm": HParams(lr=1.0, damping=1e-2),
          "scaffold": HParams(lr=0.3)}
    for algo in ("fedpm", "scaffold"):
        for s in (n_clients, n_clients // 4):
            sc = 0 if s == n_clients else s
            # min-of-3 passes per engine: the gate ratios these two rows,
            # and a transient load spike during exactly one loop otherwise
            # fabricates a 2x overhead regression (observed on CPU hosts)
            us_v = time_convex_round(setup, algo, hp[algo],
                                     sample_clients=sc, reps=reps, passes=3)
            us_s = time_convex_round(setup, algo, hp[algo],
                                     sample_clients=sc, reps=reps, mesh=mesh,
                                     passes=3)
            emit(f"sampling_sharded/{algo}/S{s}/vmap", us_v, f"devices={nd}")
            emit(f"sampling_sharded/{algo}/S{s}/sharded", us_s,
                 f"overhead_vs_vmap={us_s / us_v:.2f}x")
        # round equivalence: sharded ≡ vmap to fp32 mixing tolerance
        ref = FedSim(setup["task"], algo, hp[algo], n_clients)
        sh = FedSim(setup["task"], algo, hp[algo], n_clients, mesh=mesh)
        part = np.arange(0, n_clients, 3)
        rng = jax.random.PRNGKey(0)
        a, _ = ref.round(ref.init(rng), setup["batches"], rng,
                         participants=part)
        b, _ = sh.round(sh.init(rng), setup["batches"], rng,
                        participants=part)
        err = max([float(jnp.max(jnp.abs(x - y))) for x, y in
                   zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params))],
                  default=0.0)
        emit(f"sampling_sharded/equiv/{algo}", 0.0, f"max_abs_err={err:.2e}")
        rows = bank_shard_rows(b.clients)
        if rows:
            emit(f"sampling_sharded/bank_rows/{algo}", 0.0,
                 f"per_device={rows[0][0]}/{n_clients} shards={len(rows)}")


def sharded(reps=10):
    """Spawn the 8-fake-device worker and forward its CSV rows (so they
    land in ``benchmarks.common.RECORDS`` for the bench gate)."""
    env = dict(os.environ)
    # append (not overwrite) so inherited XLA tuning flags still apply in
    # the worker; last occurrence of the device-count flag wins
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sampling",
         "--sharded-worker", str(reps)],
        capture_output=True, text=True, env=env, cwd=root, timeout=600)
    if res.returncode != 0:
        sys.stderr.write(res.stderr[-2000:])
        raise RuntimeError(f"sharded worker failed rc={res.returncode}")
    for line in res.stdout.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) == 3 and parts[0].startswith("sampling_sharded"):
            emit(parts[0], float(parts[1]), parts[2])


def main(rounds=12):
    # paper rows first: a sharded-worker subprocess failure must not
    # cost the Fig. 6 accuracy rows
    scaling()
    fig6(rounds=rounds)
    sharded()


if __name__ == "__main__":
    if "--sharded-worker" in sys.argv:
        i = sys.argv.index("--sharded-worker")
        reps = int(sys.argv[i + 1]) if len(sys.argv) > i + 1 else 10
        sharded_worker(reps=reps)
    else:
        main()

"""Paper Table 16 (Appendix D.5): per-round client train time, client→server
communication volume, and state memory per method.

Comm bytes are EXACT declared-wire-field sizes (the mesh collective
payloads; ``Message.bytes_on_wire`` — telemetry fields like ``loss``
excluded), not simulated link timings (DESIGN.md §7).
derived = comm bytes/round."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.api import message_wire_bytes
from repro.data.federated import build_round_batches, steps_per_epoch
from repro.fl.simulate import FedSim
from repro.utils import tree_bytes

from benchmarks.common import DNN_HP, dnn_setup, emit

METHODS = ("fedavg", "fedavgm", "fedprox", "scaffold", "fedadam",
           "ltda", "fedsophia", "localnewton_foof", "fedpm_foof")


def main(rounds=3):
    setup = dnn_setup(alpha=0.1)
    ds, task = setup["ds"], setup["task"]
    k = steps_per_epoch(ds, 64) * 2
    for algo in METHODS:
        sim = FedSim(task, algo, DNN_HP[algo], ds.n_clients)
        st = sim.init(jax.random.PRNGKey(0))
        r = np.random.default_rng(0)
        # measure message size once via a direct client call
        batches = build_round_batches(ds, k, 64, r)
        one = jax.tree.map(lambda x: x[0], batches)
        cstate = jax.tree.map(lambda x: x[0], st.clients)
        msg, _ = sim.algo.client(task, sim.hp, st.params, cstate, st.server,
                                 one, jax.random.PRNGKey(0))
        comm = message_wire_bytes(msg)
        state_mem = tree_bytes(st.params) + tree_bytes(st.server)
        t0 = time.perf_counter()
        for t in range(rounds):
            st, _ = sim.round(st, batches, jax.random.PRNGKey(t))
        us = (time.perf_counter() - t0) / rounds * 1e6
        emit(f"profiling_table16/{algo}", us,
             f"comm_bytes={comm};state_bytes={state_mem}")


if __name__ == "__main__":
    main()

"""Paper Fig. 1 (Test 1): convergence of 9 methods on w8a/a9a-like strongly
convex logistic regression, K = 1, full gradients/Hessians.

Validates: FedPM ≡ FedNL superlinear; LocalNewton plateaus (local-
preconditioner bias); FO methods converge slowly.  derived = final
‖θ−θ*‖ after `rounds`."""
from __future__ import annotations

from repro.core.algorithms import HParams

from benchmarks.common import convex_setup, emit, run_convex

METHODS = {
    "psgd": HParams(lr=0.5),
    "fedavg": HParams(lr=0.5),
    "fedavgm": HParams(lr=0.5, momentum=0.9),
    "scaffold": HParams(lr=0.5),
    "fedadam": HParams(lr=0.3, server_lr=0.05),
    "fednl": HParams(lr=1.0, damping=0.0),
    "fedns": HParams(lr=1.0, damping=1e-3),
    "localnewton": HParams(lr=1.0, damping=0.0),
    "fedpm": HParams(lr=1.0, damping=0.0),
}


def main(datasets=("a9a", "w8a"), rounds=12):
    for ds_name in datasets:
        setup = convex_setup(ds_name)
        for algo, hp in METHODS.items():
            errs, fgaps, us = run_convex(setup, algo, hp, rounds)
            emit(f"convex_fig1/{ds_name}/{algo}", us,
                 f"err={errs[-1]:.3e};fgap={fgaps[-1]:.3e};"
                 f"err_r3={errs[2]:.3e}")


if __name__ == "__main__":
    main()

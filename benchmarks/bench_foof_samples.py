"""Paper Fig. 7 (Appendix D.4): effect of the number of samples used to
compute FOOF matrices — accuracy vs per-round cost.

Validates: accuracy is insensitive to the FOOF sample count on the simple
task while cost grows with it.  derived = best accuracy."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.algorithms import HParams
from repro.data.federated import build_round_batches, steps_per_epoch
from repro.fl.simulate import FedSim

from benchmarks.common import dnn_setup, emit


class GramSubsampleTask:
    """Wrap a task so FOOF grams use only the first n samples of a batch."""

    def __init__(self, task, n):
        self._task, self.n = task, n

    def init(self, rng):
        return self._task.init(rng)

    def loss_grad(self, p, b):
        return self._task.loss_grad(p, b)

    def metric(self, p, b):
        return self._task.metric(p, b)

    def grams(self, p, b):
        import jax as _jax
        sub = _jax.tree.map(lambda x: x[:self.n], b)
        return self._task.grams(p, sub)


def main(rounds=10, sizes=(16, 64, 128)):
    setup = dnn_setup(alpha=0.1)
    ds = setup["ds"]
    k = steps_per_epoch(ds, 128) * 2
    hp = HParams(lr=0.3, damping=1.0)
    for n in sizes:
        task = GramSubsampleTask(setup["task"], n)
        sim = FedSim(task, "fedpm_foof", hp, ds.n_clients)
        st = sim.init(jax.random.PRNGKey(0))
        r = np.random.default_rng(0)
        accs = []
        t0 = time.perf_counter()
        for t in range(rounds):
            batches = build_round_batches(ds, k, 128, r)
            st, _ = sim.round(st, batches, jax.random.PRNGKey(t))
            accs.append(float(task.metric(st.params, setup["test"])))
        us = (time.perf_counter() - t0) / rounds * 1e6
        emit(f"foof_samples_fig7/n{n}", us, f"best_acc={max(accs):.4f}")


if __name__ == "__main__":
    main()

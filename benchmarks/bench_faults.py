"""Fault-tolerance bench (ISSUE 9): quarantine overhead + convergence
under failure.

The fault-tolerant scanned engine adds machinery the plain engine does
not pay for: wire-boundary fault injection, a decode-once + per-report
validity check (finiteness over every leaf + the update-norm bound),
message sanitization, weight masking, the all-rejected carry-forward
select and the keep-masked state restore.  The ``fault_overhead``
bench-gate metric times the SAME schedule both ways — the plain scanned
engine vs a ZERO-FAULT :class:`~repro.fl.faults.FaultModel` wrapping the
identical inner schedule (same cohorts, same batches, same rngs; the
contract tests pin the trajectories bitwise-equal) — so the ratio
isolates the quarantine graph, not the workload (~1x expected; a
blow-up means the validity/sanitize pass stopped fusing into the
scanned round body).

The convergence section is the ISSUE's smoke scenario: 20% crashes + 5%
corrupted reports on the second-order ``fedpm_foof`` path with
``cholesky_safe`` escalation — every round completes, params stay
finite, the loss still goes DOWN, and the in-graph rejection counter
matches the host-side event log exactly.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.algorithms import HParams
from repro.fl import faults as FLT
from repro.fl import schedule as SCH
from repro.fl.simulate import FedSim

from benchmarks.common import emit
from benchmarks.bench_scan import tiny_convex_task


def quarantine_overhead(rounds=32, n_clients=16, s=4, reps=3):
    """us/round: plain scanned engine vs the zero-fault quarantined
    engine on the identical schedule.  Min over ``reps`` full-run
    repetitions per path (one compile each, excluded)."""
    task = tiny_convex_task(n_clients=n_clients)
    inner = SCH.SampledSchedule(s=s, seed=0)
    fm = FLT.FaultModel(inner=inner)        # all-zero fault codes
    sim = FedSim(task, "fedpm", HParams(lr=1.0, damping=1e-2), n_clients)

    def run_once(seed, cohorts):
        t0 = time.perf_counter()
        st, _ = sim.run_scanned(jax.random.PRNGKey(seed), rounds,
                                cohorts=cohorts, eval_every=rounds)
        jax.block_until_ready(st.params)
        return (time.perf_counter() - t0) / rounds * 1e6

    run_once(0, inner)                      # compile both paths
    run_once(0, fm)
    us_plain = min(run_once(r, inner) for r in range(reps))
    us_q = min(run_once(r, fm) for r in range(reps))
    emit("faults/scanned/plain", us_plain,
         f"rounds={rounds},S={s},N={n_clients}")
    emit("faults/scanned/quarantined", us_q,
         f"overhead_vs_plain={us_q / us_plain:.2f}x")


def convergence_under_failure(rounds=24, n_clients=16, s=4,
                              crash=0.2, corrupt=0.05):
    """The ISSUE's failure scenario end-to-end on the preconditioned
    path: loss must still fall, params stay finite, counters exact.
    Convergence is tracked on a held-out batch (the convex task's
    messages carry no per-client loss metric)."""
    task = tiny_convex_task(n_clients=n_clients)
    # Same generator draw as tiny_convex_task(seed=0): the eval batch is
    # the task's own data, so the population loss is the quantity the
    # federated objective actually minimizes.
    rng = np.random.default_rng(0)
    d = task.model.d
    xe = rng.normal(size=(2048, d)).astype(np.float32)
    we = rng.normal(size=d).astype(np.float32) / np.sqrt(d)
    ye = np.sign(xe @ we + 0.1 * rng.normal(size=2048)).astype(np.float32)
    ye[ye == 0] = 1.0
    eval_batch = {"x": xe, "y": ye}
    eval_fn = jax.jit(lambda p: task.model.loss(p, eval_batch))
    fm = FLT.FaultModel(inner=SCH.SampledSchedule(s=s, seed=0),
                        crash=crash, corrupt=corrupt, seed=3)
    plan = SCH.resolve(fm, rounds=rounds, n=n_clients, sample_clients=0)
    hp = HParams(lr=1.0, damping=1e-2, inverse_method="cholesky_safe")
    sim = FedSim(task, "fedpm", hp, n_clients)
    t0 = time.perf_counter()
    st, hist = sim.run_scanned(jax.random.PRNGKey(0), rounds, cohorts=fm,
                               eval_fn=eval_fn, eval_every=4)
    jax.block_until_ready(st.params)
    us = (time.perf_counter() - t0) / rounds * 1e6
    for leaf in jax.tree.leaves(st.params):
        assert np.isfinite(np.asarray(leaf)).all(), "non-finite params"
    np.testing.assert_array_equal(hist["n_rejected"],
                                  FLT.expected_rejections(plan.faults))
    np.testing.assert_array_equal(hist["n_failed"], plan.n_failed)
    metrics = hist["metric"]
    assert all(np.isfinite(metrics)), f"non-finite eval loss: {metrics}"
    assert metrics[-1] < metrics[0], \
        f"loss did not fall under failure: {metrics}"
    emit("faults/convergence/faulted", us,
         f"crash={crash},corrupt={corrupt},"
         f"failed={int(hist['n_failed'].sum())},"
         f"rejected={int(hist['n_rejected'].sum())},"
         f"loss={metrics[0]:.3f}->{metrics[-1]:.3f}")


def smoke_section():
    """CI gate rows: the overhead pair (both sides in one repetition so
    machine load cancels from the ratio) plus one convergence assert."""
    quarantine_overhead()
    convergence_under_failure()


def main():
    quarantine_overhead()
    convergence_under_failure()


if __name__ == "__main__":
    main()

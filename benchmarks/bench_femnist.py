"""Paper Table 15 (Appendix D.3): real-world federated dataset — FEMNIST.

FEMNIST's defining property is the NATURAL per-writer partition (user-level
non-IID).  The offline stand-in generates per-writer style shifts (affine
pixel bias + class-usage skew) over 28×28×1 images with 62 classes, ragged
writer sizes, and samples 10 writers per round — matching the paper's
protocol (10 of 3597 writers, 5 local epochs).  The paper's CNN is used.
derived = best accuracy."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import HParams
from repro.data.federated import FederatedDataset, build_round_batches
from repro.fl.simulate import FedSim
from repro.fl.tasks import DNNTask
from repro.models.simple import CNNModel

from benchmarks.common import emit

# hyperparameters follow the paper's FEMNIST Table 12 (lr 0.5/1.0 band,
# damping 1.0, clip 1.0 for the second-order methods)
METHODS = {
    "fedavg": HParams(lr=0.1),
    "fedavgm": HParams(lr=0.1, momentum=0.7),
    "scaffold": HParams(lr=0.05),
    "localnewton_foof": HParams(lr=1.0, damping=1.0, clip=1.0),
    "fedpm_foof": HParams(lr=1.0, damping=1.0, clip=1.0),
}


def make_femnist_like(n_writers=24, classes=16, hw=28, seed=0):
    """Writer-partitioned images: shared class templates + per-writer
    style (pixel bias, contrast) + per-writer class-usage skew."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(classes, hw, hw, 1)).astype(np.float32)
    for _ in range(2):
        base = (base + np.roll(base, 1, 1) + np.roll(base, -1, 1)
                + np.roll(base, 1, 2) + np.roll(base, -1, 2)) / 5.0
    xs, ys, shards, off = [], [], [], 0
    for w in range(n_writers):
        n_w = int(rng.integers(60, 180))            # ragged writer sizes
        usage = rng.dirichlet(np.full(classes, 0.3))
        y = rng.choice(classes, size=n_w, p=usage)
        style_bias = 0.35 * rng.normal(size=(1, hw, hw, 1)).astype(np.float32)
        contrast = 1.0 + 0.2 * rng.normal()
        x = contrast * base[y] + style_bias + \
            0.45 * rng.normal(size=(n_w, hw, hw, 1)).astype(np.float32)
        xs.append(x.astype(np.float32))
        ys.append(y.astype(np.int32))
        shards.append(np.arange(off, off + n_w))
        off += n_w
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    # held-out: fresh samples from 8 unseen "writers"
    test = make_test(base, classes, hw, rng)
    return FederatedDataset(x=x, y=y, shards=shards,
                            test_x=test[0], test_y=test[1])


def make_test(base, classes, hw, rng, n=800):
    y = rng.integers(0, classes, size=n)
    bias = 0.35 * rng.normal(size=(n, 1, 1, 1)).astype(np.float32)
    x = base[y] + bias + 0.45 * rng.normal(size=(n, hw, hw, 1)).astype(np.float32)
    return x.astype(np.float32), y.astype(np.int32)


def main(rounds=9, sample_writers=10):
    ds = make_femnist_like()
    model = CNNModel(in_hw=28, in_ch=1, num_classes=16, foof_block=256)
    task = DNNTask(model)
    test = ds.test_batch()
    for algo, hp in METHODS.items():
        sim = FedSim(task, algo, hp, ds.n_clients)
        st = sim.init(jax.random.PRNGKey(0))
        r = np.random.default_rng(0)
        accs = []
        t0 = time.perf_counter()
        for t in range(rounds):
            batches = build_round_batches(ds, 7, 32, r)
            chosen = r.choice(ds.n_clients, size=sample_writers,
                              replace=False)
            mask = jnp.zeros((ds.n_clients,)).at[chosen].set(1.0)
            st, _ = sim.round(st, batches, jax.random.PRNGKey(t), mask)
            accs.append(float(task.metric(st.params, test)))
        us = (time.perf_counter() - t0) / rounds * 1e6
        emit(f"femnist_table15/{algo}", us, f"best_acc={max(accs):.4f}")


if __name__ == "__main__":
    main()

"""Dispatch-overhead bench (ISSUE 4): scanned vs per-round rounds/sec.

FedPM-class experiments run hundreds of short rounds; at small model
sizes the per-round driver's cost is dominated by one jit dispatch + host
round-trip per round.  ``FedSim.run_scanned`` compiles a whole chunk of
rounds into one ``lax.scan`` program, so the dispatch cost amortizes
across the chunk.  This bench times both drivers on a deliberately TINY
convex task (per-round math ≪ dispatch overhead) and emits the
machine-independent speedup ratio — the ``scan_dispatch_*`` bench-gate
metrics (≥2× expected; a ratio collapse means per-round host work crept
back into the scanned path).

Both drivers run the SAME banked data path (resident device bank,
in-graph cohort sampling), so the ratio isolates dispatch + host-loop
overhead, not data handling.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.algorithms import HParams
from repro.data import FederatedDataset
from repro.fl.simulate import FedSim, round_keys
from repro.fl.tasks import ConvexTask
from repro.models.simple import LogisticModel

from benchmarks.common import emit

#: (algo, hparams) pairs timed by :func:`dispatch` — FedPM (the paper's
#: method; per-round Hessian + cholesky) and FedAvg (the pure dispatch
#: floor: almost no per-round math)
DISPATCH_ALGOS = (
    ("fedpm", HParams(lr=1.0, damping=1e-2)),
    ("fedavg", HParams(lr=0.3)),
)


def tiny_convex_task(n=2048, d=32, n_clients=16, seed=0):
    """A small logistic task with a resident full-shard data bank."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=d).astype(np.float32) / np.sqrt(d)
    y = np.sign(x @ w + 0.1 * rng.normal(size=n)).astype(np.float32)
    y[y == 0] = 1.0
    ds = FederatedDataset.from_arrays({"x": x, "y": y}, n_clients,
                                      alpha=0.0, seed=seed, test_frac=0.1)
    task = ConvexTask(LogisticModel(d=d, lam=1e-3))
    return task.with_data(ds.device_bank(steps=1, batch=0))


def dispatch(rounds=32, n_clients=16, s=4, reps=3):
    """us/round for the per-round banked loop vs one scanned chunk.

    Both paths include one ``init`` per repetition and block only at the
    end (async dispatch allowed — that's the realistic per-round cost);
    min over ``reps`` repetitions per path."""
    task = tiny_convex_task(n_clients=n_clients)
    for algo, hp in DISPATCH_ALGOS:
        sim = FedSim(task, algo, hp, n_clients)

        def perround_once(seed):
            k_init, keys = round_keys(jax.random.PRNGKey(seed), rounds)
            st = sim.init(k_init)
            t0 = time.perf_counter()
            for t in range(rounds):
                st, _ = sim.round(st, None, keys[t], sample_clients=s)
            jax.block_until_ready(st.params)
            return (time.perf_counter() - t0) / rounds * 1e6

        def scanned_once(seed):
            t0 = time.perf_counter()
            st, _ = sim.run_scanned(jax.random.PRNGKey(seed), rounds,
                                    sample_clients=s, eval_every=rounds)
            jax.block_until_ready(st.params)
            return (time.perf_counter() - t0) / rounds * 1e6

        perround_once(0)                              # compile both paths
        scanned_once(0)
        us_pr = min(perround_once(r) for r in range(reps))
        us_sc = min(scanned_once(r) for r in range(reps))
        emit(f"scan_dispatch/{algo}/perround", us_pr,
             f"rounds={rounds},S={s}/{n_clients}")
        emit(f"scan_dispatch/{algo}/scanned", us_sc,
             f"speedup_vs_perround={us_pr / us_sc:.2f}x")


def chunking(rounds=64, n_clients=16, s=4):
    """us/round vs eval_every (chunk length): the dispatch amortization
    curve — chunk 1 pays the full per-chunk dispatch every round."""
    task = tiny_convex_task(n_clients=n_clients)
    sim = FedSim(task, "fedpm", HParams(lr=1.0, damping=1e-2), n_clients)
    for ee in (1, 8, rounds):
        sim.run_scanned(jax.random.PRNGKey(0), rounds, sample_clients=s,
                        eval_every=ee)               # compile
        t0 = time.perf_counter()
        st, _ = sim.run_scanned(jax.random.PRNGKey(1), rounds,
                                sample_clients=s, eval_every=ee)
        jax.block_until_ready(st.params)
        us = (time.perf_counter() - t0) / rounds * 1e6
        emit(f"scan_chunking/fedpm/chunk{ee}", us, f"rounds={rounds}")


def main():
    dispatch()
    chunking()


if __name__ == "__main__":
    main()

"""Communication-cost bench: exact per-round wire bytes from the registry.

Unlike the timing benches, the numeric CSV slot here is BYTES per client
per round (``derived`` says which direction) — computed by
``repro.core.api.comm_cost`` (pure ``jax.eval_shape``, no compilation),
so the rows are exact and machine-independent.  The smoke gates ratio a
wire transform OFF over ON (bf16, top-k, gram sketch): a transform that
silently stops shrinking the payload collapses its ratio and fails the
bench gate.

Reference sizes match the README registry table: the Test-2 MLP
(64→128→64→10, K=2 steps of batch 64) for layer-wise methods and the
Test-1 convex model (d=123, full batch) for flat/Hessian methods.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import api
from repro.core.algorithms import ALGORITHMS, HParams
from repro.fl.tasks import ConvexTask, DNNTask
from repro.models.simple import LogisticModel, MLPModel

from benchmarks.common import emit

#: transform-on/off pairs the smoke gates ratio (off ÷ on, worse=lower)
TRANSFORM_PAIRS = (
    ("fedavg", "fedavg_bf16"),
    ("fedadam", "fedadam_topk"),
    ("fedpm_foof", "fedpm_foof_sketch"),
)


def reference_tasks():
    """THE reference sizes for comm accounting — also consumed by
    ``scripts/gen_alg_table.py``, so the README registry table and the
    gated ``comm/*`` rows can never report different models."""
    cvx = ConvexTask(LogisticModel(d=123, lam=1e-3))
    cvx_batch = {"x": jnp.zeros((1, 500, 123), jnp.float32),
                 "y": jnp.zeros((1, 500), jnp.float32)}
    dnn = DNNTask(MLPModel(in_dim=64, hidden=(128, 64), num_classes=10))
    dnn_batch = {"x": jnp.zeros((2, 64, 64), jnp.float32),
                 "y": jnp.zeros((2, 64), jnp.int32)}
    return (cvx, cvx_batch), (dnn, dnn_batch)


def hp_for(name: str) -> HParams:
    """Reference hparams: defaults, except FedNS reports at sketch=32
    (sketch=0 would degenerate to the full d×d frame)."""
    return HParams(sketch=32) if name == "fedns" else HParams()


def reference_cost(name: str) -> dict:
    """``api.comm_cost`` of one registered algorithm at the reference
    sizes (shared by the bench rows and the README table)."""
    (cvx, cb), (dnn, db) = reference_tasks()
    a = ALGORITHMS[name]
    task, batch = (cvx, cb) if a.needs_hessian else (dnn, db)
    return api.comm_cost(a, task, hp_for(name), batch)


def main(algos=None) -> None:
    for name in sorted(algos or ALGORITHMS):
        c = reference_cost(name)
        emit(f"comm/{name}/up", c["bytes_up_per_client"],
             "bytes_up/client/round")
        emit(f"comm/{name}/down", c["bytes_down_per_client"],
             "bytes_down/client/round")


def smoke_section() -> None:
    """The gate subset: every transform pair's on/off rows."""
    names = sorted({n for pair in TRANSFORM_PAIRS for n in pair})
    main(algos=names)


if __name__ == "__main__":
    main()

"""Roofline summary (spec §g): reads the dry-run artifacts and emits one
row per (arch × shape × mesh) with the three roofline terms, the dominant
bottleneck and the useful-FLOPs ratio.  derived carries the terms.

``kernel_section`` benches the three gram-bank hot kernels (Schur/Cholesky
solve, adaptive Newton–Schulz invert-and-apply, fused Eq. 12 mixing)
against their unfused/LAPACK references at the canonical gate shapes, and
anchors each measurement to its analytic ``KernelRoofline`` bound —
derived carries ``bound_us``/``frac`` (achieved fraction of roofline) and
the dominant term.  The ratio rows feed the ``pallas_*_speedup`` gates in
``benchmarks.run --smoke``."""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")


def _min_us(fn, iters: int = 7, warmup: int = 2) -> float:
    """Min wall-clock µs over ``iters`` post-warmup passes.  The gate
    ratios compare two kernels' MINIMA: min filters the CI host's load
    spikes far better than median at these sub-ms launch times."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def _spd_bank(key, nb, bs):
    import jax
    import jax.numpy as jnp
    m = jax.random.normal(key, (nb, bs, bs))
    return jnp.einsum("...ij,...kj->...ik", m, m) / bs + 0.05 * jnp.eye(bs)


def kernel_section():
    """Gram-bank kernel roofline rows (three ref/fused pairs).

    On CPU the "fused" side is each op's default dispatch — the Schur jnp
    restructuring for cholesky, the interpret-mode Pallas kernel for the
    adaptive NS and fused-mix paths — i.e. exactly what the library runs
    in this container; on TPU the same calls hit the compiled kernels."""
    import jax
    import jax.numpy as jnp

    from repro.distributed.roofline import (chol_solve_roofline,
                                            mix_roofline, ns_solve_roofline)
    from repro.kernels.cholesky import ops as chol_ops
    from repro.kernels.cholesky.ref import chol_solve_ref
    from repro.kernels.mix import ops as mix_ops
    from repro.kernels.mix.ref import mix_ref
    from repro.kernels.nschulz import ops as ns_ops
    from repro.kernels.nschulz.ref import ns_solve_ref

    def row(name, us, rl):
        bound = rl.bound_us()
        emit(f"kernels/{name}", us,
             f"bound_us={bound:.1f};frac={bound / max(us, 1e-9):.3f};"
             f"dom={rl.dominant()}")

    damping = 0.1
    # --- Schur/Cholesky batched solve: [16, 128, 128] vs k=96 ----------
    nb, bs, k = 16, 128, 96
    a = _spd_bank(jax.random.PRNGKey(0), nb, bs)
    b = jax.random.normal(jax.random.PRNGKey(1), (nb, bs, k))
    ref = jax.jit(lambda a, b: chol_solve_ref(a, b, damping=damping))
    fused = jax.jit(lambda a, b: chol_ops.chol_solve(a, b, damping=damping))
    rl = chol_solve_roofline(nb, bs, k)
    row("chol_solve/ref", _min_us(lambda: ref(a, b)), rl)
    row("chol_solve/fused", _min_us(lambda: fused(a, b)), rl)

    # --- adaptive NS invert-and-apply: [16, 64, 96], budget 25 ---------
    nb, bs, k = 16, 64, 96
    a = _spd_bank(jax.random.PRNGKey(2), nb, bs)
    b = jax.random.normal(jax.random.PRNGKey(3), (nb, bs, k))
    ref = jax.jit(lambda a, b: ns_solve_ref(a, b, iters=20, damping=damping))
    fused = jax.jit(lambda a, b: ns_ops.ns_solve(a, b, iters=25,
                                                 damping=damping,
                                                 use_pallas=True))
    rl = ns_solve_roofline(nb, bs, k, 20)
    row("ns_solve/ref20", _min_us(lambda: ref(a, b)), rl)
    row("ns_solve/fused", _min_us(lambda: fused(a, b)), rl)

    # --- fused Eq. 12 mixing: S=8 clients, R=16 rows, bs=64, k=96 ------
    s, r, bs, k = 8, 16, 64, 96
    ka, kt, kw = jax.random.split(jax.random.PRNGKey(4), 3)
    m = jax.random.normal(ka, (s, r, bs, bs))
    a = jnp.einsum("srij,srkj->srik", m, m) / bs + 0.05 * jnp.eye(bs)
    t = jax.random.normal(kt, (s, r, bs, k))
    w = jax.nn.softmax(jax.random.normal(kw, (s,)))
    unfused = jax.jit(lambda a, t, w: mix_ref(a, t, w, damping=damping,
                                              method="ns", iters=20))
    fused = jax.jit(lambda a, t, w: mix_ops.mix_precond(
        a, t, w, damping=damping, iters=25, solver="ns"))
    rl = mix_roofline(s, r, bs, k, 20)
    row("mix/unfused", _min_us(lambda: unfused(a, t, w)), rl)
    row("mix/fused", _min_us(lambda: fused(a, t, w)), rl)


def load_results(path=RESULTS):
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    # dedupe: keep the last entry per (arch, shape, mesh, algo, tag)
    seen = {}
    for r in rows:
        key = (r.get("arch"), r.get("shape"), r.get("mesh"),
               r.get("algo"), r.get("tag"))
        seen[key] = r
    return list(seen.values())


def main():
    kernel_section()
    rows = load_results()
    if not rows:
        emit("roofline/NO_DRYRUN_RESULTS", 0.0, "run repro.launch.dryrun")
        return
    for r in sorted(rows, key=lambda r: (str(r.get("arch")),
                                         str(r.get("shape")),
                                         str(r.get("mesh")))):
        if "skipped" in r:
            emit(f"roofline/{r['arch']}/{r['shape']}/skip", 0.0,
                 r["skipped"][:60])
            continue
        if "error" in r:
            emit(f"roofline/{r['arch']}/{r['shape']}/{r.get('mesh')}", 0.0,
                 "ERROR " + r["error"][:60])
            continue
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r.get('algo')}"
             + (f"/{r['tag']}" if r.get("tag") else ""),
             step_s * 1e6,
             f"dom={r['dominant']};compute_s={r['compute_s']:.4f};"
             f"memory_s={r['memory_s']:.4f};"
             f"collective_s={r['collective_s']:.4f};"
             f"useful={r['useful_ratio']:.3f}")


if __name__ == "__main__":
    main()

"""Roofline summary (spec §g): reads the dry-run artifacts and emits one
row per (arch × shape × mesh) with the three roofline terms, the dominant
bottleneck and the useful-FLOPs ratio.  derived carries the terms."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")


def load_results(path=RESULTS):
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    # dedupe: keep the last entry per (arch, shape, mesh, algo, tag)
    seen = {}
    for r in rows:
        key = (r.get("arch"), r.get("shape"), r.get("mesh"),
               r.get("algo"), r.get("tag"))
        seen[key] = r
    return list(seen.values())


def main():
    rows = load_results()
    if not rows:
        emit("roofline/NO_DRYRUN_RESULTS", 0.0, "run repro.launch.dryrun")
        return
    for r in sorted(rows, key=lambda r: (str(r.get("arch")),
                                         str(r.get("shape")),
                                         str(r.get("mesh")))):
        if "skipped" in r:
            emit(f"roofline/{r['arch']}/{r['shape']}/skip", 0.0,
                 r["skipped"][:60])
            continue
        if "error" in r:
            emit(f"roofline/{r['arch']}/{r['shape']}/{r.get('mesh')}", 0.0,
                 "ERROR " + r["error"][:60])
            continue
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r.get('algo')}"
             + (f"/{r['tag']}" if r.get("tag") else ""),
             step_s * 1e6,
             f"dom={r['dominant']};compute_s={r['compute_s']:.4f};"
             f"memory_s={r['memory_s']:.4f};"
             f"collective_s={r['collective_s']:.4f};"
             f"useful={r['useful_ratio']:.3f}")


if __name__ == "__main__":
    main()

"""Buffered-async engine bench (ISSUE 8): rounds/sec under churn.

The buffered-async driver adds machinery the synchronous scanned engine
does not pay for: a params ring in the scan carry (one snapshot write
per round), the per-flush stale-params gather, staleness-damped
aggregation weights and the gram damping hook.  This bench times the
SAME flush pattern both ways — the ``BufferedSchedule``'s built cohort
rows replayed synchronously (fresh params, the dead rounds skipped by
the same ``lax.cond``) vs the full async engine (stale params from the
ring, ``weight_pow`` damping) — so the ratio isolates the async
machinery, not the schedule's duty cycle.  The ``async_overhead``
bench-gate metric is that ratio (~1x expected; a blow-up means the ring
or the stale gather stopped fusing into the scanned round body).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.algorithms import HParams
from repro.fl import schedule as SCH
from repro.fl.simulate import FedSim

from benchmarks.common import emit
from benchmarks.bench_scan import tiny_convex_task


def churn(rounds=32, n_clients=16, goal=4, reps=3):
    """us/round: synchronous replay of a churny flush schedule vs the
    buffered-async engine on the identical schedule.  Min over ``reps``
    full-run repetitions per path (one compile each, excluded)."""
    task = tiny_convex_task(n_clients=n_clients)
    sched = SCH.BufferedSchedule(goal=goal, concurrency=2 * goal,
                                 delay=(1, 3), seed=0, weight_pow=0.5)
    rows, taus = sched.build(n_clients, rounds)
    live = rows[:, 0] >= 0
    window = int(taus[live].max(initial=0)) + 1
    sim = FedSim(task, "fedpm", HParams(lr=1.0, damping=1e-2), n_clients)

    def run_once(seed, cohorts):
        t0 = time.perf_counter()
        st, _ = sim.run_scanned(jax.random.PRNGKey(seed), rounds,
                                cohorts=cohorts, eval_every=rounds)
        jax.block_until_ready(st.params)
        return (time.perf_counter() - t0) / rounds * 1e6

    run_once(0, rows)                                 # compile both paths
    run_once(0, sched)
    us_sync = min(run_once(r, rows) for r in range(reps))
    us_async = min(run_once(r, sched) for r in range(reps))
    emit("async/scanned/sync", us_sync,
         f"rounds={rounds},live={int(live.sum())},goal={goal}")
    emit("async/scanned/buffered", us_async,
         f"window={window},overhead_vs_sync={us_async / us_sync:.2f}x")


def main():
    churn()


if __name__ == "__main__":
    main()

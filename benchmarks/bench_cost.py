"""Paper Table 2: computation/communication costs of FedPM with the full
Hessian vs the FOOF approximation.

Measures construction time, inversion time (Cholesky vs Newton–Schulz vs
the fused Pallas NS kernel in interpret mode) and the per-round
client→server payload in bytes.  derived = payload bytes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.inverse import inverse
from repro.kernels.gram import ops as gram_ops
from repro.kernels.gram.ref import gram_blocks_ref
from repro.models.simple import LogisticModel
from repro.utils import timeit_us

from benchmarks.common import emit


def main(d=512, t_tokens=4096, block=128):
    rng = jax.random.PRNGKey(0)
    # ---- FedPM w/ full Hessian on logistic regression (d² objects) ----
    model = LogisticModel(d=d, lam=1e-3)
    x = jax.random.normal(rng, (t_tokens, d))
    y = jnp.sign(jax.random.normal(rng, (t_tokens,)))
    theta = jnp.zeros(d)
    batch = {"x": x, "y": y}
    hess = jax.jit(model.hessian)
    us = timeit_us(lambda: hess(theta, batch))
    emit("cost_table2/full/construct", us, f"bytes={d*d*4}")
    h = hess(theta, batch)
    us = timeit_us(lambda: inverse(h, 1e-3, method="cholesky"))
    emit("cost_table2/full/invert_cholesky", us, f"bytes={d*d*4}")
    emit("cost_table2/full/comm", 0.0, f"bytes={d*d*4 + d*4}")

    # ---- FedPM w/ FOOF (block-diagonal d·block objects) ----
    xb = jax.random.normal(rng, (t_tokens, d))
    gram_ref = jax.jit(lambda v: gram_blocks_ref(v, block))
    us = timeit_us(lambda: gram_ref(xb))
    nb = d // block
    foof_bytes = nb * block * block * 4
    emit("cost_table2/foof/construct_jnp", us, f"bytes={foof_bytes}")
    us = timeit_us(lambda: gram_ops.gram(xb, block, use_pallas=True))
    emit("cost_table2/foof/construct_pallas_interpret", us,
         f"bytes={foof_bytes}")
    a = gram_ref(xb) + 0.1 * jnp.eye(block)
    us = timeit_us(lambda: inverse(a, 0.1, method="cholesky"))
    emit("cost_table2/foof/invert_cholesky", us, f"bytes={foof_bytes}")
    us = timeit_us(lambda: inverse(a, 0.1, method="ns", ns_iters=16))
    emit("cost_table2/foof/invert_ns", us, f"bytes={foof_bytes}")
    emit("cost_table2/foof/comm", 0.0, f"bytes={foof_bytes + d*4}")


if __name__ == "__main__":
    main()

"""Paper Table 2: computation/communication costs of FedPM with the full
Hessian vs the FOOF approximation.

Measures construction time, inversion time (Cholesky vs Newton–Schulz vs
the fused Pallas NS kernel in interpret mode) and the per-round
client→server payload in bytes.  derived = payload bytes.

Plus the packed gram-bank section: per-leaf tree walks (one tiny solve per
layer) vs the bank (one batched factor+solve per block size), and the
fused Pallas invert-and-apply kernel vs its two-launch equivalent.  This
section doubles as the tier-1 interpret-mode kernel smoke (scripts/ci.sh
runs ``--smoke``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import foof as F
from repro.core.inverse import inverse
from repro.kernels.gram import ops as gram_ops
from repro.kernels.gram.ref import gram_blocks_ref
from repro.kernels.nschulz import ops as ns_ops
from repro.models.simple import LogisticModel
from repro.utils import timeit_us

from benchmarks.common import emit


def _bank_trees(n_layers, nb, bs, dout, vocab=256, seed=0):
    """Synthetic multi-layer tree: n_layers blocked gram leaves sharing one
    block size + a diagonal embedding lane."""
    rng = jax.random.PRNGKey(seed)
    params, grads, grams = {}, {}, {}
    for i in range(n_layers):
        k1, k2, rng = tuple(jax.random.split(rng, 3))
        m = jax.random.normal(k1, (nb, bs, bs))
        a = jnp.einsum("nij,nkj->nik", m, m) / bs + 0.05 * jnp.eye(bs)
        params[f"layer{i}"] = {"w": jnp.zeros((nb * bs, dout))}
        grads[f"layer{i}"] = {"w": jax.random.normal(k2, (nb * bs, dout))}
        grams[f"layer{i}"] = {"w": a}
    k1, rng = jax.random.split(rng)
    params["embed"] = {"w": jnp.zeros((vocab, dout))}
    grads["embed"] = {"w": jax.random.normal(k1, (vocab, dout))}
    grams["embed"] = {"w": jax.random.uniform(rng, (vocab,)) + 0.1}
    return params, grads, grams


def bank_section(n_layers=8, nb=2, bs=128, dout=96):
    """packed vs per-leaf: same math, one batched launch per block size vs
    one per layer.  derived = layer count covered per launch."""
    params, grads, grams = _bank_trees(n_layers, nb, bs, dout)
    for packed, tag in ((False, "perleaf"), (True, "packed")):
        pre = jax.jit(lambda g, p=packed: F.precondition_tree(
            params, g, grams, damping=0.1, packed=p))
        us = timeit_us(lambda: pre(grads))
        emit(f"cost_bank/precondition_{tag}", us, f"layers={n_layers}")
        invf = jax.jit(lambda a, p=packed: F.invert_grams(
            a, damping=0.1, packed=p))
        us = timeit_us(lambda: invf(grams))
        emit(f"cost_bank/invert_{tag}", us, f"layers={n_layers}")
    # factor-once amortization: cached-factor apply vs full factor+solve
    pp = jax.jit(lambda g: F.build_preconditioner(g, damping=0.1))(grams)
    app = jax.jit(lambda t, g: F.apply_preconditioner(pp, t, g))
    us = timeit_us(lambda: app(params, grads))
    emit("cost_bank/apply_cached_factors", us, f"layers={n_layers}")
    # fused Pallas invert-and-apply (interpret off-TPU) vs two launches
    m = jax.random.normal(jax.random.PRNGKey(1), (nb * n_layers, bs, bs))
    a = jnp.einsum("nij,nkj->nik", m, m) / bs + 0.1 * jnp.eye(bs)
    b = jax.random.normal(jax.random.PRNGKey(2), (nb * n_layers, bs, dout))
    us = timeit_us(lambda: ns_ops.ns_solve(a, b, iters=12, use_pallas=True))
    emit("cost_bank/pallas_fused_invert_apply", us, f"blocks={nb * n_layers}")
    us = timeit_us(lambda: ns_ops.ns_inverse(a, iters=12, use_pallas=True) @ b)
    emit("cost_bank/pallas_invert_then_apply", us,
         f"blocks={nb * n_layers}")


def main(d=512, t_tokens=4096, block=128, smoke=False):
    if smoke:
        # interpret-mode kernel smoke for tier-1 CI: small shapes, every
        # kernel path (gram, NS inverse, fused invert-and-apply, bank)
        d, t_tokens, block = 128, 512, 64
    rng = jax.random.PRNGKey(0)
    # ---- FedPM w/ full Hessian on logistic regression (d² objects) ----
    model = LogisticModel(d=d, lam=1e-3)
    x = jax.random.normal(rng, (t_tokens, d))
    y = jnp.sign(jax.random.normal(rng, (t_tokens,)))
    theta = jnp.zeros(d)
    batch = {"x": x, "y": y}
    hess = jax.jit(model.hessian)
    us = timeit_us(lambda: hess(theta, batch))
    emit("cost_table2/full/construct", us, f"bytes={d*d*4}")
    h = hess(theta, batch)
    us = timeit_us(lambda: inverse(h, 1e-3, method="cholesky"))
    emit("cost_table2/full/invert_cholesky", us, f"bytes={d*d*4}")
    emit("cost_table2/full/comm", 0.0, f"bytes={d*d*4 + d*4}")

    # ---- FedPM w/ FOOF (block-diagonal d·block objects) ----
    xb = jax.random.normal(rng, (t_tokens, d))
    gram_ref = jax.jit(lambda v: gram_blocks_ref(v, block))
    us = timeit_us(lambda: gram_ref(xb))
    nb = d // block
    foof_bytes = nb * block * block * 4
    emit("cost_table2/foof/construct_jnp", us, f"bytes={foof_bytes}")
    us = timeit_us(lambda: gram_ops.gram(xb, block, use_pallas=True))
    emit("cost_table2/foof/construct_pallas_interpret", us,
         f"bytes={foof_bytes}")
    a = gram_ref(xb) + 0.1 * jnp.eye(block)
    us = timeit_us(lambda: inverse(a, 0.1, method="cholesky"))
    emit("cost_table2/foof/invert_cholesky", us, f"bytes={foof_bytes}")
    us = timeit_us(lambda: inverse(a, 0.1, method="ns", ns_iters=16))
    emit("cost_table2/foof/invert_ns", us, f"bytes={foof_bytes}")
    emit("cost_table2/foof/comm", 0.0, f"bytes={foof_bytes + d*4}")

    # ---- packed gram bank vs per-leaf walks ----
    if smoke:
        bank_section(n_layers=4, nb=2, bs=32, dout=24)
    else:
        bank_section()


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)

"""Benchmark harness entry: one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (spec).  CPU-budgeted sizes; see
benchmarks/common.py and EXPERIMENTS.md for the paper mapping:

  bench_convex        → Fig. 1      bench_dnn          → Table 3
  bench_local_epochs  → Fig. 3      bench_sampling     → Fig. 6
  bench_foof_samples  → Fig. 7      bench_cost         → Table 2
  bench_femnist       → Table 15 (FEMNIST, writer-partitioned + sampling)
  bench_profiling     → Table 16    bench_roofline     → §Roofline (dry-run)

``--smoke`` runs the CI perf-gate subset — packed-vs-per-leaf bank
numbers, the roofline-anchored gram-bank kernel pairs (Schur/Cholesky
solve, adaptive Newton–Schulz, fused Eq. 12 mixing — the three
``pallas_*_speedup`` gates), the K-sweep factor-once amortization, the
sharded-vs-vmap engine comparison on a forced 8-device host mesh, the
scanned-vs-per-round dispatch ratio, the paged-vs-resident ClientStore
overhead and exact staged-bytes ratios, the disk-tier
``coldtier_overhead`` / ``coldtier_bytes_ratio`` pair (mmap store vs
host-paged at the same shapes), the buffered-async-vs-sync
``async_overhead`` ratio, the fault-quarantine ``fault_overhead``
ratio, and the comm-bytes
wire-transform on/off ratios — and serializes every emitted row plus
machine-independent gate RATIOS to ``BENCH_pr10.json``.
``benchmarks.bench_gate`` compares those
ratios against the checked-in ``benchmarks/baseline_pr10.json`` and
fails tier-1 on >25% regressions (scripts/ci.sh wires both up; the
N ≥ 10⁵ paged scale smokes run as their OWN ci.sh stages —
``python -m benchmarks.bench_paging --scale [--tier mmap]`` in fresh
processes, so the watermarks they assert (``jax.live_arrays()`` on the
host tier, peak ``RssAnon`` on the N = 10⁶ disk tier) aren't polluted
by other benches' leftovers).
"""
from __future__ import annotations

import json
import sys
import traceback


def _run(benches) -> list[str]:
    failed = []
    for name, fn in benches:
        try:
            fn()
        except Exception as e:                      # keep the harness going
            failed.append(name)
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    return failed


# gate name → (numerator row, denominator row, worse direction, family).
# Families tie each gate to the bench stage that refreshes its rows, so
# smoke() can sample a gate once per repetition of ITS stage and take the
# median: numerator and denominator are always measured back-to-back in
# the same repetition, so correlated machine load cancels out of the
# ratio (min-merging rows across interleaved repetitions does not — a
# fast numerator from one rep against a slow denominator from another
# fabricates a regression).
_GATE_SPECS = {
    # packed gram bank must stay faster than the per-leaf walks
    "packed_precondition_speedup": (
        "cost_bank/precondition_perleaf", "cost_bank/precondition_packed",
        "lower", "bank"),
    # gram-bank hot kernels vs their unfused/LAPACK references at the
    # canonical gate shapes (bench_roofline.kernel_section; min-of-passes
    # timings, both sides measured in the same repetition)
    "pallas_cholesky_speedup": (
        "kernels/chol_solve/ref", "kernels/chol_solve/fused", "lower",
        "kernels"),
    "pallas_ns_speedup": (
        "kernels/ns_solve/ref20", "kernels/ns_solve/fused", "lower",
        "kernels"),
    "pallas_mix_speedup": (
        "kernels/mix/unfused", "kernels/mix/fused", "lower", "kernels"),
    "packed_invert_speedup": (
        "cost_bank/invert_perleaf", "cost_bank/invert_packed", "lower",
        "bank"),
    # factor-once amortization: K=16 rounds must stay sublinear in K
    "ksweep_k16_growth": (
        "local_epochs_ksweep/fedpm_foof/K16",
        "local_epochs_ksweep/fedpm_foof/K1", "higher", "ksweep"),
    # sharded engine overhead vs the vmap oracle (8 fake host devices)
    "sharded_overhead_fedpm": (
        "sampling_sharded/fedpm/S16/sharded",
        "sampling_sharded/fedpm/S16/vmap", "higher", "sharded"),
    "sharded_overhead_scaffold": (
        "sampling_sharded/scaffold/S16/sharded",
        "sampling_sharded/scaffold/S16/vmap", "higher", "sharded"),
    # scan-compiled driver must keep amortizing dispatch: per-round us /
    # scanned us ≥ 2x at the tiny smoke size (a collapse means per-round
    # host work crept back into the scanned path)
    "scan_dispatch_speedup_fedpm": (
        "scan_dispatch/fedpm/perround", "scan_dispatch/fedpm/scanned",
        "lower", "scan"),
    "scan_dispatch_speedup_fedavg": (
        "scan_dispatch/fedavg/perround", "scan_dispatch/fedavg/scanned",
        "lower", "scan"),
    # paged ClientStore: chunk-boundary staging overhead vs the resident
    # scanned driver (a blow-up means paging work crept INSIDE the chunk
    # loop — e.g. a per-call recompile of the eager cohort draw)
    "paging_overhead": (
        "paging/scanned/paged", "paging/scanned/resident", "higher",
        "paging"),
    # disk-tier ClientStore (repro.fl.coldstore): the mmap rung's price
    # over host-paged at the same shapes (a blow-up means cold reads
    # stopped being row-granular — e.g. a stage faulting whole leaves)
    "coldtier_overhead": (
        "coldtier/scanned/mmap", "coldtier/scanned/hostpaged", "higher",
        "coldtier"),
    # EXACT device bytes through the disk tier: resident rows ÷ one
    # staged chunk (the out-of-core property, one rung further out)
    "coldtier_bytes_ratio": (
        "coldtier/bytes/resident_rows", "coldtier/bytes/staged_rows",
        "lower", "coldtier"),
    # EXACT device bytes: resident [N, ...] rows ÷ one staged chunk.  A
    # collapse means the paged path silently stages (close to) the whole
    # population — the out-of-core property itself regressed.
    "paging_bytes_ratio": (
        "paging/bytes/resident_rows", "paging/bytes/staged_rows", "lower",
        "paging"),
    # wire-transform uplink savings (EXACT byte ratios, off ÷ on — a
    # transform that stops shrinking its payload collapses the ratio)
    "comm_bf16_ratio": (
        "comm/fedavg/up", "comm/fedavg_bf16/up", "lower", "comm"),
    "comm_topk_ratio": (
        "comm/fedadam/up", "comm/fedadam_topk/up", "lower", "comm"),
    "comm_sketch_ratio": (
        "comm/fedpm_foof/up", "comm/fedpm_foof_sketch/up", "lower", "comm"),
    # buffered-async engine vs a synchronous replay of the SAME flush
    # schedule (a blow-up means the params ring / stale gather stopped
    # fusing into the scanned round body)
    "async_overhead": (
        "async/scanned/buffered", "async/scanned/sync", "higher", "async"),
    # fault-quarantined scanned engine (zero-fault FaultModel) vs the
    # plain scanned engine on the identical schedule (a blow-up means the
    # validity/sanitize pass stopped fusing into the scanned round body)
    "fault_overhead": (
        "faults/scanned/quarantined", "faults/scanned/plain", "higher",
        "faults"),
}


def _gates(records: dict, family: str) -> dict:
    """Machine-independent regression-gate ratios for one bench family.

    Ratios of two timings from the same repetition cancel machine speed,
    so a checked-in baseline transfers across hosts (absolute us would
    not)."""
    gates = {}
    for name, (num, den, worse, fam) in _GATE_SPECS.items():
        if fam != family:
            continue
        a, b = records.get(num), records.get(den)
        if a and b and a["us"] > 0 and b["us"] > 0:
            gates[name] = {"value": a["us"] / b["us"], "worse": worse}
    return gates


def _median_gates(samples: list[dict]) -> dict:
    import statistics
    merged: dict = {}
    for s in samples:
        for k, v in s.items():
            merged.setdefault(k, []).append(v["value"])
    return {k: {"value": round(statistics.median(vs), 4),
                "worse": _GATE_SPECS[k][2]}
            for k, vs in merged.items()}


def smoke(out_path: str = "BENCH_pr10.json") -> int:
    from benchmarks import (bench_async, bench_comm, bench_cost,
                            bench_faults, bench_local_epochs, bench_paging,
                            bench_roofline, bench_sampling, bench_scan)
    from benchmarks.common import RECORDS, dnn_setup

    print("name,us_per_call,derived")
    samples: list[dict] = []

    failed = _run([
        ("cost", lambda: bench_cost.main(smoke=True)),
    ])
    # comm-bytes gates are exact eval_shape ratios — one sample suffices
    failed += _run([("comm", bench_comm.smoke_section)])
    samples.append(_gates(RECORDS, "comm"))
    # scanned-vs-per-round dispatch ratio (bench does its own min-of-reps
    # per path; outer repetitions median-merge the gate like the others)
    for _ in range(2):
        failed += _run([("scan", bench_scan.dispatch)])
        samples.append(_gates(RECORDS, "scan"))
    # paged-vs-resident store: timing ratio (median over repetitions) and
    # the exact staged-bytes ratio (deterministic — repeats agree)
    for _ in range(2):
        failed += _run([("paging", bench_paging.smoke_section)])
        samples.append(_gates(RECORDS, "paging"))
    # disk-tier (mmap) vs host-paged store: timing ratio (median over
    # repetitions) plus the exact resident/staged row-bytes ratio
    for _ in range(2):
        failed += _run([("coldtier", bench_paging.coldtier_section)])
        samples.append(_gates(RECORDS, "coldtier"))
    # buffered-async vs synchronous replay of the same flush schedule
    for _ in range(2):
        failed += _run([("async", bench_async.churn)])
        samples.append(_gates(RECORDS, "async"))
    # fault-quarantined vs plain scanned engine, plus the
    # convergence-under-failure assert (counters exact, loss falls)
    for _ in range(2):
        failed += _run([("faults", bench_faults.smoke_section)])
        samples.append(_gates(RECORDS, "faults"))
    # gate rows re-measured at default (non-smoke) sizes — the tiny smoke
    # shapes don't separate packed from per-leaf reliably — with the gate
    # ratio sampled per repetition and median-merged (see _GATE_SPECS)
    for _ in range(3):
        failed += _run([("bank", bench_cost.bank_section)])
        samples.append(_gates(RECORDS, "bank"))
    # gram-bank kernel rooflines: ref and fused are min-of-passes within
    # one repetition; the three pallas_*_speedup gates median-merge
    for _ in range(3):
        failed += _run([("kernels", bench_roofline.kernel_section)])
        samples.append(_gates(RECORDS, "kernels"))
    ksetup = dnn_setup(alpha=0.1, n_clients=8, n=1200, dim=16, classes=4)
    for _ in range(2):
        failed += _run([("ksweep", lambda: bench_local_epochs.k_sweep(
            setup=ksetup, ks=(1, 16), algos=("fedpm_foof",), batch=16,
            reps=3))])
        samples.append(_gates(RECORDS, "ksweep"))
    # ONE worker subprocess (each pays a full cold jax init + compile —
    # repeating it would blow the ci.sh stage budget); its rows are
    # already steady-state means over 8 post-compile reps, and the
    # checked-in baselines carry the sharded family's wider noise
    # envelope (see benchmarks/baseline_pr10.json meta)
    failed += _run([("sharded", lambda: bench_sampling.sharded(reps=8))])
    samples.append(_gates(RECORDS, "sharded"))

    out = {"rows": RECORDS, "gates": _median_gates(samples)}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}: {len(out['rows'])} rows, "
          f"{len(out['gates'])} gates", file=sys.stderr)
    return 1 if failed else 0


def main() -> None:
    if "--smoke" in sys.argv:
        sys.exit(smoke())
    from benchmarks import (bench_async, bench_comm, bench_convex,
                            bench_cost, bench_dnn, bench_faults,
                            bench_femnist, bench_foof_samples,
                            bench_local_epochs, bench_paging,
                            bench_profiling, bench_roofline,
                            bench_sampling, bench_scan)
    print("name,us_per_call,derived")
    failed = _run([
        ("comm", bench_comm.main),
        ("convex", lambda: bench_convex.main(rounds=10)),
        ("dnn", lambda: bench_dnn.main(rounds=10)),
        ("local_epochs", bench_local_epochs.main),
        ("sampling", lambda: bench_sampling.main(rounds=10)),
        ("foof_samples", lambda: bench_foof_samples.main(rounds=8)),
        ("femnist", lambda: bench_femnist.main(rounds=8)),
        ("cost", bench_cost.main),
        ("scan", bench_scan.main),
        ("async", bench_async.main),
        ("faults", bench_faults.main),
        ("paging", bench_paging.main),
        ("profiling", bench_profiling.main),
        ("roofline", bench_roofline.main),
    ])
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Benchmark harness entry: one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (spec).  CPU-budgeted sizes; see
benchmarks/common.py and EXPERIMENTS.md for the paper mapping:

  bench_convex        → Fig. 1      bench_dnn          → Table 3
  bench_local_epochs  → Fig. 3      bench_sampling     → Fig. 6
  bench_foof_samples  → Fig. 7      bench_cost         → Table 2
  bench_femnist       → Table 15 (FEMNIST, writer-partitioned + sampling)
  bench_profiling     → Table 16    bench_roofline     → §Roofline (dry-run)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_convex, bench_cost, bench_dnn,
                            bench_femnist, bench_foof_samples,
                            bench_local_epochs, bench_profiling,
                            bench_roofline, bench_sampling)
    print("name,us_per_call,derived")
    benches = [
        ("convex", lambda: bench_convex.main(rounds=10)),
        ("dnn", lambda: bench_dnn.main(rounds=10)),
        ("local_epochs", bench_local_epochs.main),
        ("sampling", lambda: bench_sampling.main(rounds=10)),
        ("foof_samples", lambda: bench_foof_samples.main(rounds=8)),
        ("femnist", lambda: bench_femnist.main(rounds=8)),
        ("cost", bench_cost.main),
        ("profiling", bench_profiling.main),
        ("roofline", bench_roofline.main),
    ]
    failed = []
    for name, fn in benches:
        try:
            fn()
        except Exception as e:                      # keep the harness going
            failed.append(name)
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()

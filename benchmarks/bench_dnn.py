"""Paper Table 3 (Test 2): best test accuracy of each method at
α ∈ {1.0, 0.1} on the CIFAR-class synthetic task, 2 local epochs.

Validates: FedPM > FO methods and > LocalNewton, with the gap growing at
α = 0.1.  derived = best accuracy."""
from __future__ import annotations

from benchmarks.common import DNN_HP, dnn_setup, emit, run_dnn

METHODS = ("fedavg", "fedavgm", "fedprox", "scaffold", "fedadam",
           "localnewton_foof", "fedpm_foof")


def main(rounds=8, alphas=(1.0, 0.1), seeds=(0, 1)):
    import numpy as np
    for alpha in alphas:
        for algo in METHODS:
            best, early = [], []
            for seed in seeds:
                # spread=3.2 keeps the synthetic task unsaturated so the
                # method ordering is visible (Table-3 class comparison)
                setup = dnn_setup(alpha=alpha, seed=seed, spread=3.2)
                accs, us = run_dnn(setup, algo, DNN_HP[algo], rounds,
                                   seed=seed)
                best.append(max(accs))
                early.append(accs[2])
            emit(f"dnn_table3/alpha{alpha}/{algo}", us,
                 f"best_acc={np.mean(best):.4f};std={np.std(best):.4f};"
                 f"acc_r3={np.mean(early):.4f}")


if __name__ == "__main__":
    main()

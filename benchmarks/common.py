"""Shared benchmark scaffolding.

Every bench prints ``name,us_per_call,derived`` CSV rows (spec).  Sizes are
CPU-budgeted stand-ins for the paper's setups (DESIGN.md §7): the *relative*
claims (method ordering, heterogeneity gaps, convergence classes) are what
each bench validates; absolute accuracies differ from CIFAR.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import HParams
from repro.data import (FederatedDataset, make_clustered_classification,
                        make_libsvm_like)
from repro.data.federated import build_round_batches, steps_per_epoch
from repro.fl.simulate import FedSim
from repro.fl.tasks import ConvexTask, DNNTask
from repro.models.simple import LogisticModel, MLPModel


#: every ``emit`` also lands here — ``benchmarks.run --smoke`` serializes
#: the registry (plus derived regression-gate ratios) to BENCH_pr10.json
RECORDS: dict[str, dict] = {}


def emit(name: str, us_per_call: float, derived) -> None:
    RECORDS[name] = {"us": float(us_per_call), "derived": str(derived)}
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


# ------------------------------------------------------------- Test 1 ------

def convex_setup(dataset="a9a", n_clients=None, seed=0):
    data = make_libsvm_like(dataset, seed=seed)
    n = n_clients or data["n_clients"]
    ds = FederatedDataset.from_arrays(data, n, alpha=0.0, seed=seed,
                                      test_frac=0.1)
    d = data["x"].shape[1]
    model = LogisticModel(d=d, lam=1e-3)
    task = ConvexTask(model)
    batches = ds.client_full_batches(k_steps=1)
    ux = np.asarray(batches["x"][:, 0]).reshape(-1, d)
    uy = np.asarray(batches["y"][:, 0]).reshape(-1)
    full = {"x": jnp.asarray(ux), "y": jnp.asarray(uy)}
    theta = jnp.zeros(d)
    for _ in range(25):
        theta = theta - jnp.linalg.solve(model.hessian(theta, full),
                                         model.grad(theta, full))
    return dict(ds=ds, model=model, task=task, batches=batches,
                theta_star=theta, f_star=float(model.loss(theta, full)),
                full=full, d=d)


def run_convex(setup, algo, hp, rounds, init_scale=0.1, seed=0,
               sample_clients=0):
    """``sample_clients`` > 0: per-round uniform cohorts of that size go
    through the engine's gathered participation path (compute scales with
    S, not N)."""
    n = setup["ds"].n_clients
    sim = FedSim(setup["task"], algo, hp, n)
    rng = jax.random.PRNGKey(seed)
    st = sim.init(rng)
    st.params = setup["theta_star"] + init_scale * jax.random.normal(
        rng, (setup["d"],))
    np_rng = np.random.default_rng(seed)
    errs, fgaps = [], []
    t0 = time.perf_counter()
    for t in range(rounds):
        if sample_clients and sample_clients < n:
            chosen = np.sort(np_rng.choice(n, size=sample_clients,
                                           replace=False))
            sub = jax.tree.map(lambda x: x[chosen], setup["batches"])
            st, _ = sim.round(st, sub, jax.random.PRNGKey(t),
                              participants=chosen)
        else:
            st, _ = sim.round(st, setup["batches"], jax.random.PRNGKey(t))
        errs.append(float(jnp.linalg.norm(st.params - setup["theta_star"])))
        fgaps.append(abs(float(setup["model"].loss(st.params, setup["full"]))
                         - setup["f_star"]))
    us = (time.perf_counter() - t0) / rounds * 1e6
    return errs, fgaps, us


def time_convex_round(setup, algo, hp, sample_clients=0, reps=20, seed=0,
                      mesh=None, passes=1):
    """Steady-state us/round (post-compile) for a fixed cohort size.

    ``mesh``: route the round through the mesh-sharded engine
    (``repro.fl.sharded``) instead of the single-device vmap path.
    ``passes`` > 1 repeats the (already compiled) timing loop and returns
    the fastest pass mean — transient host-load spikes hit one pass, not
    all of them, so gated rows (sharded-vs-vmap) stop inheriting the
    machine's worst moment."""
    n = setup["ds"].n_clients
    sim = FedSim(setup["task"], algo, hp, n, mesh=mesh)
    st = sim.init(jax.random.PRNGKey(seed))
    st.params = setup["theta_star"] + 0.05 * jax.random.normal(
        jax.random.PRNGKey(seed), (setup["d"],))
    s = sample_clients or n
    chosen = np.arange(s)
    batches = (jax.tree.map(lambda x: x[chosen], setup["batches"])
               if s < n else setup["batches"])
    st, _ = sim.round(st, batches, jax.random.PRNGKey(0),
                      participants=chosen)          # compile
    jax.block_until_ready(st.params)
    # rounds DONATE their input state, so chain st forward (reusing one
    # state would hand the jit deleted buffers)
    best = float("inf")
    for _ in range(max(1, passes)):
        t0 = time.perf_counter()
        for t in range(reps):
            st, _ = sim.round(st, batches, jax.random.PRNGKey(t),
                              participants=chosen)
            jax.block_until_ready(st.params)
        best = min(best, (time.perf_counter() - t0) / reps * 1e6)
    return best


# ------------------------------------------------------------- Test 2 ------

DNN_HP = {
    "fedavg": HParams(lr=0.1),
    "fedavgm": HParams(lr=0.1, momentum=0.9),
    "fedprox": HParams(lr=0.1, prox_mu=0.001),
    "scaffold": HParams(lr=0.1),
    "fedadam": HParams(lr=0.05, server_lr=0.03),
    "ltda": HParams(lr=0.01, damping=1e-3),
    "fedsophia": HParams(lr=0.03),
    "localnewton_foof": HParams(lr=0.3, damping=1.0),
    "fedpm_foof": HParams(lr=0.3, damping=1.0),
}


def dnn_setup(alpha=0.1, n_clients=10, n=6000, dim=64, classes=10, seed=0,
              spread=1.6):
    data = make_clustered_classification(n, dim, classes, seed=seed,
                                         spread=spread)
    ds = FederatedDataset.from_arrays(data, n_clients, alpha=alpha, seed=seed)
    model = MLPModel(in_dim=dim, hidden=(128, 64), num_classes=classes)
    task = DNNTask(model)
    return dict(ds=ds, model=model, task=task, test=ds.test_batch())


def time_dnn_round(setup, algo, hp, k_steps, batch=64, reps=5, seed=0):
    """Steady-state us/round (post-compile) at a fixed local-step count K —
    isolates how round latency scales with K (factor-once amortization)."""
    ds, task = setup["ds"], setup["task"]
    sim = FedSim(task, algo, hp, ds.n_clients)
    st = sim.init(jax.random.PRNGKey(seed))
    r = np.random.default_rng(seed)
    batches = build_round_batches(ds, k_steps, batch, r)
    st, _ = sim.round(st, batches, jax.random.PRNGKey(0))       # compile
    jax.block_until_ready(jax.tree.leaves(st.params)[0])
    t0 = time.perf_counter()
    for t in range(reps):   # chain st: rounds donate their input state
        st, _ = sim.round(st, batches, jax.random.PRNGKey(t))
        jax.block_until_ready(jax.tree.leaves(st.params)[0])
    return (time.perf_counter() - t0) / reps * 1e6


def run_dnn(setup, algo, hp, rounds, epochs=2, batch=64, seed=0):
    ds, task = setup["ds"], setup["task"]
    k = steps_per_epoch(ds, batch) * epochs
    sim = FedSim(task, algo, hp, ds.n_clients)
    st = sim.init(jax.random.PRNGKey(seed))
    r = np.random.default_rng(seed)
    accs = []
    t0 = time.perf_counter()
    for t in range(rounds):
        batches = build_round_batches(ds, k, batch, r)
        st, _ = sim.round(st, batches, jax.random.PRNGKey(1000 * seed + t))
        accs.append(float(task.metric(st.params, setup["test"])))
    us = (time.perf_counter() - t0) / rounds * 1e6
    return accs, us

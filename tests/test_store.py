"""ClientStore seam contracts (ISSUE 6):

* ``plan_chunk`` pads chunk unions to a static capacity and remaps
  cohorts to staged-row positions (pad rows dead, -1 rows preserved);
* ``HostStateStore`` round-trips gather/scatter against host numpy,
  stages ZERO bytes for stateless algorithms, and deep-copies;
* the PAGED engine (``ds.paged_bank``) matches the RESIDENT engine to
  fp32 tolerance on identical cohort schedules — sampled, scheduled
  (with an empty round inside a chunk), and full participation — on the
  vmap engine here and the mesh-sharded engine in an 8-fake-device
  subprocess;
* paged device memory is bounded by the chunk's staging capacity, not N;
* a donated-away ``FedState`` is rejected at the ``round`` entry with an
  actionable message pointing at ``FedState.copy()``.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import HParams, get_algorithm
from repro.data import DeviceDataBank, FederatedDataset, HostPagedBank, \
    make_clustered_classification
from repro.fl.simulate import FedSim, FedState, round_keys
from repro.fl.store import ClientStore, HostStateStore, device_bytes, \
    plan_chunk, round_up
from repro.fl.tasks import DNNTask
from repro.models.simple import MLPModel

N, R = 12, 5


@pytest.fixture(scope="module")
def ds():
    data = make_clustered_classification(1200, 16, 4, seed=0)
    return FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)


@pytest.fixture(scope="module")
def task(ds):
    return DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4))


def _resident(task, ds):
    return task.with_data(ds.device_bank(steps=2, batch=16))


def _paged(task, ds):
    return task.with_data(ds.paged_bank(steps=2, batch=16))


def _assert_close(a, b, tag):
    """Paged ≡ resident to fp32 tolerance (the staged program is
    shape-smaller, so XLA fusion may differ by ~1 ulp per op)."""
    cl_a = a.clients.bank if isinstance(a.clients, HostStateStore) \
        else a.clients
    cl_b = b.clients.bank if isinstance(b.clients, HostStateStore) \
        else b.clients
    for name, x, y in (("params", a.params, b.params),
                       ("server", a.server, b.server),
                       ("clients", cl_a, cl_b)):
        for u, v in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=2e-6, atol=2e-6,
                                       err_msg=f"{tag}:{name}")


# ------------------------------------------------------------ plan_chunk ---

def test_plan_chunk_remaps_and_pads():
    rows = np.array([[2, 5, 9], [2, 7, 9]], np.int32)
    union, n_live, local = plan_chunk(rows, cap=6)
    assert union.tolist() == [2, 5, 7, 9, 9, 9]      # pad repeats last live
    assert n_live == 4
    np.testing.assert_array_equal(union[local], rows)  # remap inverts
    # live cohort rows stay sorted strictly ascending (bucket_cohort req)
    assert np.all(np.diff(local, axis=1) > 0)


def test_plan_chunk_preserves_empty_rows():
    rows = np.array([[1, 3], [-1, -1]], np.int32)
    union, n_live, local = plan_chunk(rows, cap=4)
    assert n_live == 2
    np.testing.assert_array_equal(local[1], [-1, -1])
    np.testing.assert_array_equal(union[local[0]], rows[0])


def test_plan_chunk_all_empty_and_overflow():
    union, n_live, local = plan_chunk(np.full((2, 3), -1, np.int32), cap=3)
    assert n_live == 0 and np.all(local == -1)
    with pytest.raises(ValueError, match="staging capacity"):
        plan_chunk(np.arange(8, dtype=np.int32)[None], cap=4)


def test_round_up():
    assert round_up(5, 4) == 8 and round_up(8, 4) == 8 and round_up(0, 4) == 4


# --------------------------------------------------------- HostStateStore --

def test_host_state_store_roundtrip():
    store = HostStateStore.broadcast({"c": jnp.arange(3.0)}, n=6)
    assert isinstance(store, ClientStore) and not store.is_resident
    assert store.n_clients == 6 and not store.stateless
    rows = np.array([1, 4])
    staged = store.gather(rows)
    assert store.last_staged_bytes == device_bytes(staged) > 0
    store.scatter(rows, {"c": jnp.stack([jnp.full((3,), 7.0),
                                         jnp.full((3,), 8.0)])})
    np.testing.assert_array_equal(store.bank["c"][1], 7.0)
    np.testing.assert_array_equal(store.bank["c"][4], 8.0)
    np.testing.assert_array_equal(store.bank["c"][0], [0, 1, 2])  # untouched
    # scatter ignores trailing capacity padding beyond len(rows)
    store.scatter(np.array([2]), {"c": jnp.zeros((4, 3))})
    np.testing.assert_array_equal(store.bank["c"][3], [0, 1, 2])


def test_host_state_store_copy_branches():
    store = HostStateStore.broadcast({"c": jnp.zeros((2,))}, n=4)
    twin = store.copy()
    store.scatter(np.array([0]), {"c": jnp.ones((1, 2))})
    np.testing.assert_array_equal(twin.bank["c"], 0.0)


def test_state_store_prefetch_read_ahead():
    """State-row ``prefetch`` is REAL read-ahead (stages into the cache,
    consumed by the next matching gather) — not the pre-PR10 no-op."""
    store = HostStateStore.broadcast({"c": jnp.arange(3.0)}, n=6)
    rows = np.array([1, 4])
    store.prefetch(rows)
    key = (rows.tobytes(), None)
    assert key in store._cache
    cached = store._cache[key][1]
    assert store.gather(rows)["c"] is cached["c"]    # consumed the stage
    assert store._cache == {}


def test_state_store_scatter_invalidates_prefetch():
    """A scatter touching prefetched rows drops the stale stage; disjoint
    prefetches survive."""
    store = HostStateStore.broadcast({"c": jnp.zeros((2,))}, n=8)
    hot, cold = np.array([1, 4]), np.array([6, 7])
    store.prefetch(hot)
    store.prefetch(cold)
    store.scatter(np.array([4]), {"c": jnp.ones((1, 2))})
    assert (hot.tobytes(), None) not in store._cache
    assert (cold.tobytes(), None) in store._cache
    np.testing.assert_array_equal(store.gather(hot)["c"][1], 1.0)


def test_state_store_scatter_async_and_fence():
    """Write-behind: ``scatter_async`` returns before the rows land;
    ``fence`` (row-filtered or full) retires the write, and ``gather`` of
    intersecting rows fences implicitly."""
    store = HostStateStore.broadcast({"c": jnp.zeros((2,))}, n=8)
    rows = np.array([2, 5])
    store.scatter_async(rows, {"c": jnp.ones((2, 2))})
    store.fence(np.array([3]))                       # disjoint: may keep it
    store.fence(rows)                                # intersecting: waits
    assert store._pending == []
    np.testing.assert_array_equal(store.bank["c"][2], 1.0)
    store.scatter_async(rows, {"c": jnp.full((2, 2), 2.0)})
    np.testing.assert_array_equal(store.gather(rows)["c"],  # implicit fence
                                  np.full((2, 2), 2.0))
    store.scatter_async(rows, {"c": jnp.full((2, 2), 3.0)})
    store.fence()                                    # rows=None: drain all
    assert store._pending == []
    np.testing.assert_array_equal(store.bank["c"][5], 3.0)


def test_state_store_prefetch_skips_in_flight_rows():
    """Read-ahead must not cache rows an un-fenced write-behind may still
    be writing (the stale-read hazard rule)."""
    from concurrent.futures import Future
    store = HostStateStore.broadcast({"c": jnp.zeros((2,))}, n=8)
    fut = Future()                                   # never resolves: in flight
    store._pending.append((np.array([4]), fut))
    store.prefetch(np.array([4, 6]))                 # intersects: skipped
    assert store._cache == {}
    store.prefetch(np.array([6, 7]))                 # disjoint: cached
    assert (np.array([6, 7]).tobytes(), None) in store._cache
    fut.set_result(None)
    store.fence()


def test_state_store_fence_reraises_worker_error():
    from concurrent.futures import Future
    store = HostStateStore.broadcast({"c": jnp.zeros((2,))}, n=8)
    fut = Future()
    fut.set_exception(RuntimeError("drain failed"))
    store._pending.append((np.array([1]), fut))
    with pytest.raises(RuntimeError, match="drain failed"):
        store.fence()
    assert store._pending == []


def test_stateless_store_pages_nothing():
    assert get_algorithm("fedavg").stateless
    assert not get_algorithm("scaffold").stateless
    store = HostStateStore.broadcast((), n=100_000)
    assert store.stateless and store.host_bytes() == 0
    assert store.n_clients == 100_000
    store.gather(np.arange(64))
    assert store.last_staged_bytes == 0
    store.scatter(np.arange(64), ())                 # no-op, no error


# -------------------------------------------------- data-bank store seam ---

def test_banks_implement_client_store(ds):
    res = ds.device_bank(steps=2, batch=16)
    pag = ds.paged_bank(steps=2, batch=16)
    assert isinstance(res, ClientStore) and res.is_resident
    assert isinstance(pag, ClientStore) and not pag.is_resident
    assert res.n_clients == pag.n_clients == N
    assert res.one_client_struct() == pag.one_client_struct()


def test_paged_gather_stages_resident_rows(ds):
    """A staged view's rows are bytewise the resident bank's rows for
    those clients — the equivalence the paged fp32 contract rests on."""
    res = ds.device_bank(steps=2, batch=16)
    pag = ds.paged_bank(steps=2, batch=16)
    rows = np.array([1, 3, 8])
    staged = pag.gather(rows)
    assert isinstance(staged, DeviceDataBank) and staged.spec == res.spec
    want = res.gather(rows)
    for a, b in ((staged.x, want.x), (staged.y, want.y),
                 (staged.sizes, want.sizes)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert pag.last_staged_bytes == device_bytes(
        {"x": staged.x, "y": staged.y, "sizes": staged.sizes})


def test_paged_prefetch_is_consumed(ds):
    pag = ds.paged_bank(steps=2, batch=16)
    rows = np.array([0, 5])
    pag.prefetch(rows)
    cached = pag._cache[(rows.tobytes(), None)]
    assert pag.gather(rows) is cached
    assert pag._cache == {}                          # consumed, not leaked


# ------------------------------------------- paged ≡ resident (vmap) -------

@pytest.mark.parametrize("algo,hp", [
    ("scaffold", HParams(lr=0.1)),                   # stateful clients
    ("fedpm_foof", HParams(lr=0.3, damping=1.0)),    # preconditioned mixing
])
def test_paged_scanned_matches_resident(task, ds, algo, hp):
    rng = jax.random.PRNGKey(0)
    got_r, _ = FedSim(_resident(task, ds), algo, hp, N).run_scanned(
        rng, R, sample_clients=4, eval_every=2)
    got_p, _ = FedSim(_paged(task, ds), algo, hp, N).run_scanned(
        rng, R, sample_clients=4, eval_every=2)
    _assert_close(got_r, got_p, algo)


def test_paged_scheduled_with_empty_round(task, ds):
    np_rng = np.random.default_rng(5)
    cohorts = np.stack([np.sort(np_rng.choice(N, 4, replace=False))
                        for _ in range(R)]).astype(np.int32)
    cohorts[2] = -1                                  # empty round mid-chunk
    rng, hp = jax.random.PRNGKey(1), HParams(lr=0.1)
    got_r, _ = FedSim(_resident(task, ds), "scaffold", hp, N).run_scanned(
        rng, R, cohorts=cohorts, eval_every=2)
    got_p, _ = FedSim(_paged(task, ds), "scaffold", hp, N).run_scanned(
        rng, R, cohorts=cohorts, eval_every=2)
    _assert_close(got_r, got_p, "sched-empty")


def test_paged_full_participation(task, ds):
    rng, hp = jax.random.PRNGKey(2), HParams(lr=0.1)
    got_r, _ = FedSim(_resident(task, ds), "scaffold", hp, N).run_scanned(
        rng, 3, eval_every=3)
    got_p, _ = FedSim(_paged(task, ds), "scaffold", hp, N).run_scanned(
        rng, 3, eval_every=3)
    _assert_close(got_r, got_p, "full")


def test_paged_round_matches_paged_scanned(task, ds):
    """The banked per-round paged loop is the paged scanned driver's
    oracle (same contract shape as the resident engines')."""
    rng, hp = jax.random.PRNGKey(3), HParams(lr=0.1)
    sim = FedSim(_paged(task, ds), "scaffold", hp, N)
    got, _ = sim.run_scanned(rng, R, sample_clients=4, eval_every=2)
    k_init, keys = round_keys(rng, R)
    st = sim.init(k_init)
    for t in range(R):
        st, m = sim.round(st, None, keys[t], sample_clients=4)
    assert m["bytes_up"] > 0
    _assert_close(got, st, "round-vs-scanned")


def test_paged_round_with_participants(task, ds):
    rng, hp = jax.random.PRNGKey(4), HParams(lr=0.1)
    idx = np.array([0, 3, 7], np.int32)
    out = {}
    for tag, build in (("res", _resident), ("pag", _paged)):
        sim = FedSim(build(task, ds), "scaffold", hp, N)
        st = sim.init(jax.random.PRNGKey(9))
        st, _ = sim.round(st, None, rng, participants=idx)
        out[tag] = st
    _assert_close(out["res"], out["pag"], "participants")


def test_paged_non_participants_untouched(task, ds):
    sim = FedSim(_paged(task, ds), "scaffold", HParams(lr=0.1), N)
    st = sim.init(jax.random.PRNGKey(0))
    before = jax.tree.map(np.copy, st.clients.bank)
    st, _ = sim.round(st, None, jax.random.PRNGKey(1),
                      participants=np.array([2, 5], np.int32))
    touched = np.array([2, 5])
    mask = np.ones(N, bool)
    mask[touched] = False
    for b, a in zip(jax.tree.leaves(before),
                    jax.tree.leaves(st.clients.bank)):
        np.testing.assert_array_equal(b[mask], a[mask])
        assert not np.array_equal(b[touched], a[touched])


def test_paged_device_memory_bounded_by_schedule(task, ds):
    """Staged bytes per chunk scale with min(eval_every · S, N), not N —
    the exact-bytes half of the paging contract."""
    hp = HParams(lr=0.1)
    sim = FedSim(_paged(task, ds), "scaffold", hp, N)
    bank = sim.task.data
    sim.run_scanned(jax.random.PRNGKey(0), 2, sample_clients=3,
                    eval_every=1)
    full = ds.device_bank(steps=2, batch=16)
    full_bytes = device_bytes({"x": full.x, "y": full.y, "s": full.sizes})
    assert 0 < bank.last_staged_bytes <= full_bytes * 3 // N + 64
    # explicit per-round staging too
    st = sim.init(jax.random.PRNGKey(0))
    st, _ = sim.round(st, None, jax.random.PRNGKey(1), sample_clients=3)
    assert st.clients.last_staged_bytes == \
        device_bytes(st.clients.gather(np.arange(3)))


def test_paged_rejects_explicit_batches(task, ds):
    sim = FedSim(_paged(task, ds), "fedavg", HParams(lr=0.1), N)
    st = sim.init(jax.random.PRNGKey(0))
    batches = {"x": jnp.zeros((N, 2, 16, 16)), "y": jnp.zeros((N, 2, 16),
                                                             jnp.int32)}
    with pytest.raises(ValueError, match="banked rounds only"):
        sim.round(st, batches, jax.random.PRNGKey(1))


def test_sample_batches_rejects_paged_store(task, ds):
    with pytest.raises(ValueError, match="RESIDENT"):
        _paged(task, ds).sample_batches(jax.random.PRNGKey(0),
                                        jnp.arange(2))


# ------------------------------------------------- donated-state guard -----

def test_consumed_state_rejected_with_actionable_error(task, ds):
    sim = FedSim(_resident(task, ds), "scaffold", HParams(lr=0.1), N)
    st = sim.init(jax.random.PRNGKey(0))
    keep = st.copy()
    sim.round(st, None, jax.random.PRNGKey(1), sample_clients=3)
    with pytest.raises(ValueError, match="FedState.copy"):
        sim.round(st, None, jax.random.PRNGKey(2), sample_clients=3)
    # the copy is still live and usable
    st2, _ = sim.round(keep, None, jax.random.PRNGKey(2), sample_clients=3)
    assert not jax.tree.leaves(st2.clients)[0].is_deleted()


def test_paged_state_copy_branches_host_bank(task, ds):
    sim = FedSim(_paged(task, ds), "scaffold", HParams(lr=0.1), N)
    st = sim.init(jax.random.PRNGKey(0))
    keep = st.copy()
    assert isinstance(keep.clients, HostStateStore)
    assert keep.clients is not st.clients
    st1, _ = sim.round(st, None, jax.random.PRNGKey(1), sample_clients=3)
    # the paged store mutates in place; the copy kept the old rows
    assert any(not np.array_equal(a, b)
               for a, b in zip(jax.tree.leaves(st1.clients.bank),
                               jax.tree.leaves(keep.clients.bank)))


# ------------------------------------------- sharded engine (8 devices) ----

SHARDED_PAGED_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.core.algorithms import HParams
from repro.data import FederatedDataset, make_clustered_classification
from repro.fl.simulate import FedSim
from repro.fl.sharded import make_client_mesh, staging_sharding
from repro.fl.tasks import DNNTask
from repro.models.simple import MLPModel

assert jax.device_count() == 8
mesh = make_client_mesh()
N, R = 16, 4
data = make_clustered_classification(1600, 16, 4, seed=0)
ds = FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)
task = DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4))
hp = HParams(lr=0.1)

def close(a, b, tag):
    ca = a.clients.bank if hasattr(a.clients, "bank") else a.clients
    cb = b.clients.bank if hasattr(b.clients, "bank") else b.clients
    for name, x, y in (("params", a.params, b.params),
                       ("server", a.server, b.server), ("clients", ca, cb)):
        for u, v in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=2e-6, atol=2e-6,
                                       err_msg=f"{tag}:{name}")

rng = jax.random.PRNGKey(0)
res = task.with_data(ds.device_bank(steps=2, batch=16))
pag = task.with_data(ds.paged_bank(steps=2, batch=16))
got_r, _ = FedSim(res, "scaffold", hp, N, mesh=mesh).run_scanned(
    rng, R, sample_clients=6, eval_every=2)
got_p, _ = FedSim(pag, "scaffold", hp, N, mesh=mesh).run_scanned(
    rng, R, sample_clients=6, eval_every=2)
close(got_r, got_p, "sharded-paged")
print("SHARDED-PAGED-EQUIV-OK")

# staged chunks land SHARD-LOCAL: every staged leaf splits over the mesh
sim = FedSim(pag, "scaffold", hp, N, mesh=mesh)
staged = sim.task.data.gather(np.arange(8), sharding=staging_sharding(mesh))
assert len(staged.x.sharding.device_set) == 8
assert all(s.data.shape[0] == 1 for s in staged.x.addressable_shards)
print("SHARDED-PAGED-PLACEMENT-OK")
print("OK")
'''


def test_sharded_paged_contracts():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SHARDED_PAGED_SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("SHARDED-PAGED-EQUIV-OK", "SHARDED-PAGED-PLACEMENT-OK"):
        assert marker in res.stdout, (marker, res.stdout)

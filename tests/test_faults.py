"""Fault-tolerant round contracts (ISSUE 9):

* the HARD zero-fault contract: a zero-probability :class:`FaultModel`
  wrapping any inner schedule reproduces the plain engine BITWISE on the
  vmap engine (sync and buffered-async), and to fp32 mixing tolerance on
  the 8-fake-device mesh engine (subprocess) — the quarantined graph is
  a separate program, but with an all-zero code row every select
  collapses to its identity branch;
* crash / corruption runs complete every round with finite params, and
  the per-round counters match the host-side event log EXACTLY:
  ``n_rejected == expected_rejections(plan.faults)``,
  ``n_failed``/``n_retried`` straight from the event process;
* an all-rejected round degrades to a params-carrying no-op (corrupt=1
  leaves the init state bit-untouched);
* NaN/inf poison survives all three wire transforms (bf16 cast, top-k
  scatter, gram sketch) and is caught AFTER decode — the quarantine
  contract is on decoded messages, not encode-time assumptions;
* ``cholesky_safe`` damping escalation: bitwise-equal to ``cholesky``
  on SPD input, finite on deliberately indefinite grams where the plain
  path NaNs, exact identity fallback when every factorization fails;
* ``Participation.wmean`` all-masked guard: zero total weight falls
  back to the unweighted mean instead of 0/0 NaN;
* ``BufferedSchedule`` timeout + re-dispatch invariants
  (hypothesis-or-fallback property sweep): no duplicate ids per flush
  row, staleness >= 0, retry totals bounded by the retry budget, and
  the legacy timeout=0 build still returns the classic 2-tuple.
"""
import importlib
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hyp_compat import given, settings, st

from repro.core.algorithms import HParams, Participation
from repro.data import FederatedDataset, make_clustered_classification
from repro.fl import faults as FLT
from repro.fl import schedule as SCH
from repro.fl.simulate import FedSim, round_keys
from repro.fl.tasks import DNNTask
from repro.models.simple import MLPModel

# the package __init__ exports a FUNCTION named `inverse` that shadows
# the submodule attribute — import the module by its dotted path
inv = importlib.import_module("repro.core.inverse")

N, R, S = 8, 6, 4


@pytest.fixture(scope="module")
def task():
    data = make_clustered_classification(1200, 16, 4, seed=0)
    ds = FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)
    return DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4)
                   ).with_data(ds.device_bank(steps=2, batch=16))


def _assert_states_equal(a, b, tag=""):
    for name, x, y in (("params", a.params, b.params),
                       ("server", a.server, b.server),
                       ("clients", a.clients, b.clients)):
        for u, v in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                          err_msg=f"{tag}:{name}")


def _assert_finite(tree, tag=""):
    for leaf in jax.tree.leaves(tree):
        assert np.all(np.isfinite(np.asarray(leaf))), tag


# ------------------------------------------------ zero-fault contract ----

@pytest.mark.parametrize("algo", ["fedavg", "scaffold", "fedpm_foof"])
def test_zero_fault_bitwise_sync(task, algo):
    inner = SCH.SampledSchedule(s=S, seed=3)
    hp = HParams(lr=0.1, local_steps=2)
    rng = jax.random.PRNGKey(0)
    st_p, hist_p = FedSim(task, algo, hp, N).run_scanned(
        rng, R, cohorts=inner, eval_fn=lambda p: 0.0, eval_every=3)
    st_q, hist_q = FedSim(task, algo, hp, N).run_scanned(
        rng, R, cohorts=FLT.FaultModel(inner=inner),
        eval_fn=lambda p: 0.0, eval_every=3)
    _assert_states_equal(st_p, st_q, tag=algo)
    assert hist_q["loss"] == hist_p["loss"]
    assert hist_q["n_rejected"].sum() == 0
    assert hist_q["n_failed"].sum() == 0


def test_zero_fault_bitwise_async(task):
    inner = SCH.BufferedSchedule(goal=3, concurrency=6, delay=(1, 3),
                                 seed=2, weight_pow=0.5)
    hp = HParams(lr=0.1)
    rng = jax.random.PRNGKey(7)
    st_p, _ = FedSim(task, "fedpm_foof", hp, N).run_scanned(
        rng, R + 2, cohorts=inner, eval_every=4)
    st_q, hist_q = FedSim(task, "fedpm_foof", hp, N).run_scanned(
        rng, R + 2, cohorts=FLT.FaultModel(inner=inner), eval_every=4)
    _assert_states_equal(st_p, st_q, tag="async")
    assert hist_q["n_rejected"].sum() == 0


# --------------------------------------------- faulted-run contracts -----

def test_sync_crash_corruption_counters(task):
    """The ISSUE's smoke configuration: 20% crash + corruption, every
    round completes, params finite, counters equal the host event log
    exactly."""
    fm = FLT.FaultModel(inner=SCH.SampledSchedule(s=S, seed=3),
                        crash=0.2, corrupt=0.3, seed=11)
    plan = SCH.resolve(fm, rounds=2 * R, n=N, sample_clients=0)
    assert plan.has_faults
    hp = HParams(lr=0.1, local_steps=2, inverse_method="cholesky_safe")
    st_f, hist = FedSim(task, "fedpm_foof", hp, N).run_scanned(
        jax.random.PRNGKey(0), 2 * R, cohorts=fm,
        eval_fn=lambda p: 0.0, eval_every=4)
    _assert_finite(st_f.params, "params")
    _assert_finite(st_f.server, "server")
    np.testing.assert_array_equal(hist["n_rejected"],
                                  FLT.expected_rejections(plan.faults))
    np.testing.assert_array_equal(hist["n_failed"], plan.n_failed)
    assert hist["n_failed"].sum() > 0          # the crash rate did fire
    assert hist["n_rejected"].sum() > 0        # and so did corruption


def test_async_faults_counters(task):
    inner = SCH.BufferedSchedule(goal=3, concurrency=5, delay=(0, 3),
                                 seed=5, timeout=4, max_retries=2)
    fm = FLT.FaultModel(inner=inner, crash=0.2, straggle=0.2,
                        corrupt=0.15, seed=7)
    rounds = 2 * R
    plan = SCH.resolve(fm, rounds=rounds, n=N, sample_clients=0)
    assert plan.is_async and plan.has_faults
    hp = HParams(lr=0.1, inverse_method="cholesky_safe")
    st_f, hist = FedSim(task, "fedpm_foof", hp, N).run_scanned(
        jax.random.PRNGKey(0), rounds, cohorts=fm, eval_every=4)
    _assert_finite(st_f.params, "params")
    np.testing.assert_array_equal(hist["n_rejected"],
                                  FLT.expected_rejections(plan.faults))
    np.testing.assert_array_equal(hist["n_failed"], plan.n_failed)
    np.testing.assert_array_equal(hist["n_retried"], plan.n_retried)


def test_all_rejected_round_is_noop(task):
    """corrupt=1: every report of every round is quarantined — the run
    must degrade to a params-carrying no-op, leaving the INIT state
    bit-untouched (not NaN, not partially mixed)."""
    fm = FLT.FaultModel(inner=SCH.SampledSchedule(s=S, seed=3),
                        corrupt=1.0, seed=1)
    hp = HParams(lr=0.1)
    rng = jax.random.PRNGKey(0)
    sim = FedSim(task, "fedpm_foof", hp, N)
    k_init, _ = round_keys(rng, R)
    init = sim.init(k_init)
    init_params = jax.tree.map(jnp.copy, init.params)
    st_f, hist = FedSim(task, "fedpm_foof", hp, N).run_scanned(
        rng, R, cohorts=fm, eval_fn=lambda p: 0.0, eval_every=3)
    for u, v in zip(jax.tree.leaves(init_params),
                    jax.tree.leaves(st_f.params)):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))
    assert hist["n_rejected"].sum() == R * S
    assert all(np.isnan(loss) for loss in hist["loss"])


def test_paged_faulted_matches_resident(task):
    data = make_clustered_classification(1200, 16, 4, seed=0)
    ds = FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)
    base = DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4))
    pag = base.with_data(ds.paged_bank(steps=2, batch=16))
    fm = FLT.FaultModel(inner=SCH.SampledSchedule(s=S, seed=3),
                        crash=0.2, corrupt=0.3, seed=11)
    hp = HParams(lr=0.1, local_steps=2)
    rng = jax.random.PRNGKey(0)
    st_r, hist_r = FedSim(task, "fedpm_foof", hp, N).run_scanned(
        rng, R, cohorts=fm, eval_every=3)
    st_p, hist_p = FedSim(pag, "fedpm_foof", hp, N).run_scanned(
        rng, R, cohorts=fm, eval_every=3)
    for u, v in zip(jax.tree.leaves(st_r.params),
                    jax.tree.leaves(st_p.params)):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                   rtol=2e-6, atol=2e-6)
    np.testing.assert_array_equal(hist_r["n_rejected"],
                                  hist_p["n_rejected"])


# ------------------------------------------- wire-transform survival -----

@pytest.mark.parametrize("algo", ["fedavg_bf16", "fedadam_topk",
                                  "fedpm_foof_sketch"])
def test_poison_caught_after_every_wire_transform(task, algo):
    """NaN/inf injected into the ENCODED message must be caught by the
    post-decode validity check for each wire transform — a bf16 cast, a
    top-k scatter and a gram-sketch reconstruction all propagate (not
    launder) non-finite payloads, and the counters stay exact."""
    fm = FLT.FaultModel(inner=SCH.SampledSchedule(s=S, seed=3),
                        corrupt=0.5, seed=13)
    plan = SCH.resolve(fm, rounds=R, n=N, sample_clients=0)
    assert FLT.expected_rejections(plan.faults).sum() > 0
    hp = HParams(lr=0.1, local_steps=2)
    st_f, hist = FedSim(task, algo, hp, N).run_scanned(
        jax.random.PRNGKey(0), R, cohorts=fm, eval_every=3)
    _assert_finite(st_f.params, algo)
    _assert_finite(st_f.server, algo)
    np.testing.assert_array_equal(hist["n_rejected"],
                                  FLT.expected_rejections(plan.faults))


# --------------------------------------------------- jax-side units ------

def test_inject_zero_codes_bitwise_passthrough():
    msgs = {"delta": jnp.linspace(-1, 1, 12).reshape(3, 4),
            "idx": jnp.arange(6, dtype=jnp.int32).reshape(3, 2)}
    out = FLT.inject(msgs, jnp.zeros((3,), jnp.int8))
    np.testing.assert_array_equal(np.asarray(out["delta"]),
                                  np.asarray(msgs["delta"]))
    np.testing.assert_array_equal(np.asarray(out["idx"]),
                                  np.asarray(msgs["idx"]))


def test_inject_marks_only_marked_slots():
    msgs = {"delta": jnp.zeros((3, 4), jnp.float32),
            "idx": jnp.arange(6, dtype=jnp.int32).reshape(3, 2)}
    codes = jnp.asarray([FLT.FAULT_NAN, FLT.FAULT_OK, FLT.FAULT_EXPLODE],
                        jnp.int8)
    out = FLT.inject(msgs, codes)
    d = np.asarray(out["delta"])
    assert np.isnan(d[0]).all()
    assert (d[1] == 0).all()
    # explode guarantees magnitude >= 1e30 even on an all-zero leaf
    assert (np.abs(d[2]) >= 1e30).all()
    np.testing.assert_array_equal(np.asarray(out["idx"]),
                                  np.asarray(msgs["idx"]))  # ints untouched


def test_validity_catches_finite_explosion_and_nan():
    good = jnp.ones((4, 3), jnp.float32)
    msgs = {"delta": good.at[1].set(jnp.nan).at[2].set(1e20)}
    v = np.asarray(FLT.validity(msgs, norm_clip=1e6))
    np.testing.assert_array_equal(v, [True, False, False, True])
    # an infinite clip would let the finite 1e20 report through — the
    # FaultModel default must therefore be finite
    assert np.isfinite(FLT.FaultModel(inner=SCH.SampledSchedule(s=2)
                                      ).norm_clip)


def test_sanitize_zeroes_rejected_only():
    msgs = {"delta": jnp.full((3, 2), jnp.nan),
            "loss": jnp.asarray([1.0, jnp.nan, 3.0])}
    out = FLT.sanitize(msgs, jnp.asarray([False, False, True]))
    d = np.asarray(out["delta"])
    assert (d[:2] == 0).all() and np.isnan(d[2]).all()
    lo = np.asarray(out["loss"])
    assert lo[0] == 0.0 and lo[1] == 0.0 and lo[2] == 3.0


# ------------------------------------------------- FaultModel host -------

def test_fault_model_validation():
    buf = SCH.BufferedSchedule(goal=3, concurrency=5)
    with pytest.raises(ValueError, match="timeout"):
        FLT.FaultModel(inner=buf, crash=0.5).build(N, R)
    with pytest.raises(ValueError, match="BufferedSchedule"):
        FLT.FaultModel(inner=SCH.SampledSchedule(s=S),
                       straggle=0.5).build(N, R)
    with pytest.raises(ValueError, match="probability"):
        FLT.FaultModel(inner=buf, crash=1.5).build(N, R)
    with pytest.raises(ValueError, match="norm_clip"):
        FLT.FaultModel(inner=buf, norm_clip=0.0).build(N, R)


def test_fault_model_inner_schedule_unperturbed():
    """The fault rng stream is separate: the FaultModel's cohorts and
    staleness replay the inner schedule's arrays bit-identically, fault
    probabilities on or off."""
    inner = SCH.BufferedSchedule(goal=3, concurrency=5, delay=(0, 3),
                                 seed=5, timeout=4, max_retries=2)
    rows, taus = SCH.resolve(inner, rounds=R, n=N).cohorts, \
        SCH.resolve(inner, rounds=R, n=N).staleness
    plan = SCH.resolve(FLT.FaultModel(inner=inner, corrupt=0.5, seed=9),
                       rounds=R, n=N)
    np.testing.assert_array_equal(plan.cohorts, rows)
    np.testing.assert_array_equal(plan.staleness, taus)


def test_sync_crash_marks_never_on_dead_rounds():
    rows = np.full((4, 3), -1, np.int32)
    rows[1] = [0, 2, 5]
    fm = FLT.FaultModel(inner=SCH.ArraySchedule(cohorts=rows), crash=1.0,
                        seed=0)
    built = fm.build(N, 4)
    assert (built.faults[rows < 0] == 0).all()
    assert built.n_failed.tolist() == [0, 3, 0, 0]


# ------------------------------------- cholesky_safe escalation (sat 1) --

def _spd(key, b, n):
    g = jax.random.normal(key, (b, n, n))
    return g @ jnp.swapaxes(g, -1, -2) + 0.5 * jnp.eye(n)


def test_cholesky_safe_matches_cholesky_on_spd():
    a = _spd(jax.random.PRNGKey(0), 3, 8)
    b = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 2))
    plain = inv.solve(a, b, damping=0.1, method="cholesky")
    safe = inv.solve(a, b, damping=0.1, method="cholesky_safe")
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(safe))
    pi = inv.inverse(a, damping=0.1, method="cholesky")
    si = inv.inverse(a, damping=0.1, method="cholesky_safe")
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(si))


def test_cholesky_safe_finite_on_indefinite():
    """A deliberately indefinite gram (a poisoned bank survivor): the
    plain path NaNs under jit (potrf failure surfaces as non-finite
    factors, never an exception); escalation recovers a finite solve
    PER MATRIX — the healthy batch member keeps its mild-damping
    answer."""
    spd = _spd(jax.random.PRNGKey(0), 1, 6)[0]
    bad = -10.0 * jnp.eye(6) + 0.01  # strongly negative definite
    a = jnp.stack([spd, bad])
    b = jnp.ones((2, 6, 1))
    plain = jax.jit(lambda: inv.solve(a, b, damping=0.05,
                                      method="cholesky"))()
    assert not np.isfinite(np.asarray(plain[1])).all()
    safe = jax.jit(lambda: inv.solve(a, b, damping=0.05,
                                     method="cholesky_safe"))()
    assert np.isfinite(np.asarray(safe)).all()
    # the healthy member is bitwise the mild (1x damping) answer
    np.testing.assert_array_equal(
        np.asarray(safe[0]), np.asarray(plain[0]))


def test_cholesky_safe_identity_fallback():
    """When even 100x damping cannot rescue the factorization the solve
    falls back to the identity preconditioner x = b exactly."""
    a = jnp.full((1, 4, 4), jnp.nan)
    b = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 2))
    out = inv.solve_escalated(a, b, damping=1.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(b))


# --------------------------------------------- wmean guard (sat 2) -------

def test_wmean_all_masked_falls_back_to_unweighted():
    loss = jnp.asarray([1.0, 2.0, 3.0, 6.0], jnp.float32)
    part = Participation(weights=jnp.zeros((4,), jnp.float32), n_total=N)
    out = np.asarray(part.wmean(loss))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, 3.0)   # plain mean, not 0/0
    # the normal path is value-identical to before
    part2 = Participation(weights=jnp.asarray([1.0, 1.0, 0.0, 0.0]),
                          n_total=N)
    np.testing.assert_allclose(np.asarray(part2.wmean(loss)), 1.5)


# --------------------------- BufferedSchedule timeout properties (sat 4) --

def test_buffered_timeout_zero_keeps_legacy_tuple():
    sched = SCH.BufferedSchedule(goal=3, concurrency=5, delay=(0, 2),
                                 seed=1)
    built = sched.build(N, R)
    assert isinstance(built, tuple) and len(built) == 2


@settings(deadline=None, max_examples=25)
@given(goal=st.integers(min_value=1, max_value=4),
       extra=st.integers(min_value=0, max_value=4),
       hi=st.integers(min_value=0, max_value=5),
       timeout=st.integers(min_value=1, max_value=4),
       retries=st.integers(min_value=0, max_value=3),
       seed=st.integers(min_value=0, max_value=6))
def test_buffered_timeout_invariants(goal, extra, hi, timeout, retries,
                                     seed):
    """Event-process invariants under timeouts + re-dispatch.  The
    conservation law dispatched == flushed + busy + dead is asserted
    INSIDE buffered_events at every round — building at all proves it
    held throughout."""
    rounds = 12
    sched = SCH.BufferedSchedule(goal=goal, concurrency=goal + extra,
                                 delay=(0, hi), seed=seed,
                                 timeout=timeout, max_retries=retries)
    built = sched.build(N, rounds)
    assert isinstance(built, SCH.BuiltSchedule)
    rows, taus = np.asarray(built.cohorts), np.asarray(built.staleness)
    live = rows >= 0
    # flush rows carry sorted unique ids — no client in two slots
    for t in range(rounds):
        ids = rows[t][live[t]]
        assert np.unique(ids).size == ids.size
        assert (np.diff(ids) > 0).all() if ids.size > 1 else True
    assert (taus[live] >= 0).all()
    # a client re-dispatches at most `retries` times, so the total
    # retry count is bounded by the population's retry budget — and
    # every retry was preceded by a death
    assert built.n_retried.sum() <= N * retries
    assert built.n_retried.sum() <= built.n_failed.sum()
    assert built.n_failed.sum() <= N * (retries + 1)


@settings(deadline=None, max_examples=10)
@given(crash=st.floats(min_value=0.0, max_value=1.0),
       seed=st.integers(min_value=0, max_value=3))
def test_fault_model_buffered_counters_consistent(crash, seed):
    inner = SCH.BufferedSchedule(goal=2, concurrency=4, delay=(0, 2),
                                 seed=seed, timeout=3, max_retries=1)
    fm = FLT.FaultModel(inner=inner, crash=crash, corrupt=0.3,
                        seed=seed + 1)
    built = fm.build(N, 10)
    assert isinstance(built, SCH.BuiltSchedule)
    # buffered crashes never reach a flush row: code 1 is sync-only
    assert (built.faults != FLT.FAULT_CRASH).all()
    assert (built.faults[built.cohorts < 0] == 0).all()
    plan = SCH.resolve(fm, rounds=10, n=N)
    assert plan.norm_clip == fm.norm_clip


# ------------------------------------------- sharded engine (8 devices) --

FAULTS_SHARDED_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, numpy as np
from repro.core.algorithms import HParams
from repro.data import FederatedDataset, make_clustered_classification
from repro.fl import faults as FLT
from repro.fl import schedule as SCH
from repro.fl.simulate import FedSim
from repro.fl.sharded import make_client_mesh
from repro.fl.tasks import DNNTask
from repro.models.simple import MLPModel

assert jax.device_count() == 8
mesh = make_client_mesh()
N, R, S = 16, 6, 4
data = make_clustered_classification(1600, 16, 4, seed=0)
ds = FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)
task = DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4)
               ).with_data(ds.device_bank(steps=2, batch=16))
hp = HParams(lr=0.1, inverse_method="cholesky_safe")
rng = jax.random.PRNGKey(7)
inner = SCH.SampledSchedule(s=S, seed=3)

st_p, _ = FedSim(task, "fedpm_foof", hp, N, mesh=mesh).run_scanned(
    rng, R, cohorts=inner, eval_every=3)
st_q, hist_q = FedSim(task, "fedpm_foof", hp, N, mesh=mesh).run_scanned(
    rng, R, cohorts=FLT.FaultModel(inner=inner), eval_every=3)
for name in ("params", "server", "clients"):
    for u, v in zip(jax.tree.leaves(getattr(st_p, name)),
                    jax.tree.leaves(getattr(st_q, name))):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                   rtol=2e-6, atol=2e-6, err_msg=name)
assert hist_q["n_rejected"].sum() == 0
print("FAULTS-SHARDED-ZERO-OK")

fm = FLT.FaultModel(inner=inner, crash=0.2, corrupt=0.3, seed=11)
plan = SCH.resolve(fm, rounds=R, n=N)
st_f, hist = FedSim(task, "fedpm_foof", hp, N, mesh=mesh).run_scanned(
    rng, R, cohorts=fm, eval_every=3)
for x in jax.tree.leaves(st_f.params):
    assert np.isfinite(np.asarray(x)).all()
np.testing.assert_array_equal(hist["n_rejected"],
                              FLT.expected_rejections(plan.faults))
np.testing.assert_array_equal(hist["n_failed"], plan.n_failed)
print("FAULTS-SHARDED-COUNT-OK")

buf = SCH.BufferedSchedule(goal=3, concurrency=6, delay=(0, 3), seed=5,
                           timeout=4, max_retries=2)
fma = FLT.FaultModel(inner=buf, crash=0.15, straggle=0.2, corrupt=0.15,
                     seed=7)
plana = SCH.resolve(fma, rounds=R, n=N)
st_a, hista = FedSim(task, "fedpm_foof", hp, N, mesh=mesh).run_scanned(
    rng, R, cohorts=fma, eval_every=3)
for x in jax.tree.leaves(st_a.params):
    assert np.isfinite(np.asarray(x)).all()
np.testing.assert_array_equal(hista["n_rejected"],
                              FLT.expected_rejections(plana.faults))
np.testing.assert_array_equal(hista["n_retried"], plana.n_retried)
print("FAULTS-SHARDED-ASYNC-OK")
print("OK")
'''


def test_sharded_fault_contracts():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", FAULTS_SHARDED_SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("FAULTS-SHARDED-ZERO-OK", "FAULTS-SHARDED-COUNT-OK",
                   "FAULTS-SHARDED-ASYNC-OK"):
        assert marker in res.stdout, (marker, res.stdout)

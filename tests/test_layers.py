"""Layer-level references: chunked attention vs naive softmax, sliding
windows, M-RoPE, SSD scan vs naive recurrence, SSD decode vs scan, MoE
dispatch conservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models import ssm as S


def naive_attention(q, k, v, window=0):
    b, h, sq, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, sq, hd)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k) / jnp.sqrt(hd)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sq)[None, :]
    ok = kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v)
    return o.reshape(b, h, sq, hd)


@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("gqa", [(8, 8), (8, 2)])
def test_chunked_attention_matches_naive(window, gqa):
    h, kv = gqa
    b, s, hd = 2, 128, 32
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (b, h, s, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, kv, s, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, kv, s, hd))
    got = L.chunked_attention(q, k, v, window=window, q_chunk=32, kv_chunk=32)
    want = naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_naive_last_row():
    b, h, kv, s, hd = 2, 8, 2, 64, 16
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (b, h, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, kv, s, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, kv, s, hd))
    got = L.decode_attention(q, k, v, cache_len=40)
    # naive: attend to first 40 positions only
    qg = q.reshape(b, kv, h // kv, 1, hd)
    sc = jnp.einsum("bkgqd,bksd->bkgqs", qg, k) / jnp.sqrt(hd)
    sc = jnp.where(jnp.arange(s)[None, None, None, None] < 40, sc, -jnp.inf)
    want = jnp.einsum("bkgqs,bksd->bkgqd",
                      jax.nn.softmax(sc, -1), v).reshape(b, h, 1, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_mrope_sections_rotate_by_stream():
    """Each frequency band must follow its assigned position stream."""
    b, s, hd = 1, 8, 16
    x = jnp.ones((b, 1, s, hd))
    pos_t = jnp.arange(s)[None, None, :]
    # all three streams equal → must equal plain rope
    pos3 = jnp.broadcast_to(pos_t, (b, 3, s))
    got = L.apply_rope(x, pos3, 1e4, mrope_sections=(4, 2, 2))
    want = L.apply_rope(x, pos_t[:, 0], 1e4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def naive_ssd(x, dt, a_log, b, c):
    """Direct recurrence h_t = exp(dt·a)h_{t-1} + dt·B_t x_t; y = C_t h_t."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    a = -np.exp(np.asarray(a_log, np.float64))
    hstate = np.zeros((bsz, h, p, n))
    ys = []
    xn = np.asarray(x, np.float64)
    dtn = np.asarray(dt, np.float64)
    bn = np.asarray(b, np.float64)
    cn = np.asarray(c, np.float64)
    for t in range(s):
        da = np.exp(dtn[:, t] * a[None, :])                      # [B,H]
        hstate = hstate * da[..., None, None] + np.einsum(
            "bh,bn,bhp->bhpn", dtn[:, t], bn[:, t], xn[:, t])
        ys.append(np.einsum("bhpn,bn->bhp", hstate, cn[:, t]))
    return np.stack(ys, 1), hstate


def test_ssd_scan_matches_naive_recurrence():
    bsz, s, h, p, n = 2, 64, 3, 4, 8
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (bsz, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (bsz, s, h))) * 0.5
    a_log = jnp.log(jnp.linspace(0.5, 2.0, h))
    b = jax.random.normal(jax.random.PRNGKey(2), (bsz, s, n)) * 0.5
    c = jax.random.normal(jax.random.PRNGKey(3), (bsz, s, n)) * 0.5
    y, final = S.ssd_scan(x, dt, a_log, b, c, chunk=16)
    y_ref, final_ref = naive_ssd(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(final), final_ref, rtol=2e-3,
                               atol=2e-3)


def test_ssd_decode_continues_scan():
    """decode(state_from_scan, x_t) == scan over s+1 at position s."""
    bsz, s, h, p, n = 1, 32, 2, 4, 8
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (bsz, s + 1, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (bsz, s + 1, h))) * 0.5
    a_log = jnp.log(jnp.linspace(0.5, 2.0, h))
    b = jax.random.normal(jax.random.PRNGKey(2), (bsz, s + 1, n)) * 0.5
    c = jax.random.normal(jax.random.PRNGKey(3), (bsz, s + 1, n)) * 0.5
    y_full, _ = S.ssd_scan(x, dt, a_log, b, c, chunk=16)
    _, state = S.ssd_scan(x[:, :s], dt[:, :s], a_log, b[:, :s], c[:, :s],
                          chunk=16)
    y_dec, _ = S.ssd_decode_step(state, x[:, s], dt[:, s], a_log,
                                 b[:, s], c[:, s])
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, s]),
                               rtol=2e-3, atol=2e-3)


def test_moe_outputs_conserve_gates():
    """With identical experts, MoE output must equal the dense MLP output
    regardless of routing (gates sum to 1)."""
    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    rng = jax.random.PRNGKey(0)
    p = L.init_moe(cfg, rng)
    # make all experts identical
    p["wi"] = jnp.broadcast_to(p["wi"][:1], p["wi"].shape)
    p["wo"] = jnp.broadcast_to(p["wo"][:1], p["wo"].shape)
    x = jax.random.normal(rng, (2, 16, cfg.d_model), dtype=jnp.float32)
    out, _, aux = L.moe_forward(cfg, p, x)
    gate, up = jnp.split(x @ p["wi"][0], 2, axis=-1)
    dense = (jax.nn.silu(gate) * up) @ p["wo"][0]
    assert float(aux["dropped_frac"]) < 0.3
    # compare only where nothing was dropped: use generous tolerance
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=0.35, atol=0.35)

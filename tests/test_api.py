"""Compositional registry contracts (ISSUE 5):

* every registered algorithm runs one sampled round on the vmap engine,
  its message matches its declared wire spec, and per-round
  ``bytes_up``/``bytes_down`` metrics match the eval_shape accounting;
* the 14 paper compositions reproduce the FROZEN pre-compositional
  closures (tests/legacy_zoo.py) BITWISE — params, server state, and the
  whole client bank;
* hparam declarations are enforced: perturbing any UNdeclared HParams
  field leaves the round bitwise unchanged;
* wire transforms (bf16 / top-k / gram sketch) stay pure pytrees — a
  transform-bearing algorithm still satisfies the scanned-vs-per-round
  bit-for-bit contract — and their encode/decode round-trips behave;
* the mesh-sharded engine runs the full registry too (8-fake-device
  subprocess, with a legacy-bitwise spot check).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api
from repro.core.algorithms import (ALGORITHMS, HParams, Participation,
                                   get_algorithm)
from repro.data import (FederatedDataset, make_clustered_classification,
                        make_libsvm_like)
from repro.data.federated import build_round_batches
from repro.fl.simulate import FedSim, round_keys
from repro.fl.tasks import ConvexTask, DNNTask
from repro.models.simple import LogisticModel, MLPModel

from legacy_zoo import LEGACY_ALGORITHMS

N = 8
PARTICIPANTS = np.array([0, 2, 5, 6])


@pytest.fixture(scope="module")
def convex():
    data = make_libsvm_like("a9a", seed=0)
    ds = FederatedDataset.from_arrays(data, N, alpha=0.0, seed=0,
                                      test_frac=0.1)
    task = ConvexTask(LogisticModel(d=data["x"].shape[1], lam=1e-3))
    return dict(task=task, batches=ds.client_full_batches(k_steps=1))


@pytest.fixture(scope="module")
def dnn():
    data = make_clustered_classification(1200, 16, 4, seed=0)
    ds = FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)
    task = DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4))
    batches = build_round_batches(ds, 2, 16, np.random.default_rng(0))
    return dict(task=task, batches=batches, ds=ds)


def _setup_for(algo, convex, dnn):
    if algo.needs_grams:
        return dnn["task"], dnn["batches"], HParams(lr=0.3, damping=1.0)
    return convex["task"], convex["batches"], HParams(lr=0.1, damping=1e-2)


def _one_round(task, algo, hp, batches, participants=PARTICIPANTS):
    sim = FedSim(task, algo, hp, N)
    st = sim.init(jax.random.PRNGKey(0))
    return sim.round(st, batches, jax.random.PRNGKey(1),
                     participants=participants)


def _assert_states_equal(a, b, tag=""):
    for name in ("params", "server", "clients"):
        for x, y in zip(jax.tree.leaves(getattr(a, name)),
                        jax.tree.leaves(getattr(b, name))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"{tag}:{name}")


# ------------------------------------------------------- full-registry sweep

@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_registry_sweep_vmap(name, convex, dnn):
    """One sampled round of EVERY registered algorithm: finite outputs,
    wire spec honored, comm metrics match the eval_shape accounting, and
    (for the 14 paper algorithms) bitwise equality with the frozen
    monolithic closures."""
    algo = ALGORITHMS[name]
    task, batches, hp = _setup_for(algo, convex, dnn)

    # --- declared wire spec: message class carries exactly mixer.needs
    # as WIRE and the local solver's metric fields ---------------------
    assert algo.message_cls is not None
    assert tuple(algo.message_cls.WIRE) == tuple(algo.mixer.needs)
    assert tuple(algo.message_cls.METRICS) == tuple(algo.local.metrics)

    one_batch = jax.tree.map(lambda x: x[0], batches)
    cost = api.comm_cost(algo, task, hp, one_batch, s=len(PARTICIPANTS))
    msg = api.message_struct(
        algo, task, hp,
        jax.eval_shape(task.init, jax.random.PRNGKey(0)),
        jax.eval_shape(lambda p: algo.init_client(task, p),
                       jax.eval_shape(task.init, jax.random.PRNGKey(0))),
        jax.eval_shape(lambda p: algo.init_server(task, hp, p),
                       jax.eval_shape(task.init, jax.random.PRNGKey(0))),
        one_batch)
    assert isinstance(msg, algo.message_cls), (name, type(msg))

    st, metrics = _one_round(task, algo, hp, batches)
    for leaf in jax.tree.leaves(st.params):
        assert np.isfinite(np.asarray(leaf)).all(), name
    assert metrics["bytes_up"] == cost["bytes_up"] > 0, name
    assert metrics["bytes_down"] == cost["bytes_down"] > 0, name
    if "loss" in algo.message_cls.METRICS:
        assert np.isfinite(float(metrics["client_loss"])), name

    if name in LEGACY_ALGORITHMS:
        st_old, _ = _one_round(task, LEGACY_ALGORITHMS[name], hp, batches)
        _assert_states_equal(st, st_old, tag=name)


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_hparam_declarations_cover_all_reads(name, convex, dnn):
    """Perturbing every HParams field the algorithm does NOT declare must
    leave the round bitwise unchanged — the declaration IS the contract,
    not documentation."""
    algo = ALGORITHMS[name]
    task, batches, hp = _setup_for(algo, convex, dnn)
    poison = dict(local_steps=5, damping=0.271828, clip=7.5,
                  weight_decay=0.0123, momentum=0.77, server_lr=0.55,
                  prox_mu=0.031, beta1=0.81, beta2=0.87, tau=0.0271,
                  sketch=17, inverse_method="ns", ns_iters=7,
                  foof_timing="start", sophia_gamma=0.09, lr=0.0917,
                  stale_decay=0.321)
    declared = set(algo.hparams)
    hp_poisoned = dataclasses.replace(
        hp, **{k: v for k, v in poison.items() if k not in declared})
    assert api.unused_hparams(algo, hp_poisoned) != ()
    st, _ = _one_round(task, algo, hp, batches)
    st_p, _ = _one_round(task, algo, hp_poisoned, batches)
    _assert_states_equal(st, st_p, tag=name)


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_staleness_poison_only_declared_damping_reacts(name, convex, dnn):
    """``Participation.staleness`` is governed by the SAME declared-hook
    discipline as hparams: a mixer without a ``ServerMixer.damping``
    declaration must be bitwise blind to staleness (poisoning it changes
    nothing), and a mixer WITH the declaration must react to it.  A
    mixer that reads ``part.staleness`` without declaring the hook fails
    the blind half of this sweep."""
    algo = ALGORITHMS[name]
    task, batches, hp = _setup_for(algo, convex, dnn)
    sim = FedSim(task, algo, hp, N)
    st = sim.init(jax.random.PRNGKey(0))
    idx = np.asarray(PARTICIPANTS)
    gathered = jax.tree.map(lambda x: x[idx], st.clients)
    cb = jax.tree.map(lambda x: x[idx], batches)
    rngs = jax.random.split(jax.random.PRNGKey(1), idx.shape[0])
    msgs, _ = jax.vmap(
        lambda cs, b, r: algo.client(task, hp, st.params, cs, st.server,
                                     b, r))(gathered, cb, rngs)
    w = jnp.ones((idx.shape[0],), jnp.float32)

    def srv(stale):
        part = Participation(weights=w, n_total=N, staleness=stale)
        return algo.server(task, hp, st.params, st.server, msgs, part)

    base = srv(None)
    poisoned = srv(jnp.array([3, 0, 7, 1], jnp.int32))
    leaves = list(zip(jax.tree.leaves(base), jax.tree.leaves(poisoned)))
    if algo.mixer.damping is None:
        for x, y in leaves:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=name)
    else:
        assert any(not np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in leaves), \
            f"{name}: declared damping hook ignored staleness"


def test_registry_validation_errors():
    with pytest.raises(ValueError, match="does not provide"):
        api.register("bogus_compose", "FOPM", "grad_only", "mean")
    with pytest.raises(ValueError, match="already registered"):
        api.register("fedavg", "FOPM", "sgd", "mean")
    with pytest.raises(ValueError, match="category"):
        api.register("bogus_cat", "XXXX", "sgd", "mean")
    assert "bogus_compose" not in ALGORITHMS
    assert "bogus_cat" not in ALGORITHMS
    with pytest.raises(KeyError, match="unknown algorithm"):
        get_algorithm("nope")


def test_unused_hparams_lint(convex):
    fedavg = ALGORITHMS["fedavg"]
    assert api.unused_hparams(fedavg, HParams(lr=0.2)) == ()
    assert api.unused_hparams(fedavg, HParams(damping=0.5)) == ("damping",)
    pm = ALGORITHMS["fedpm_foof"]
    assert api.unused_hparams(pm, HParams(damping=0.5, lr=0.3)) == ()


# ----------------------------------------------------------- comm accounting

def test_comm_cost_shapes(convex):
    task, batches = convex["task"], convex["batches"]
    one = jax.tree.map(lambda x: x[0], batches)
    d = one["x"].shape[-1]            # flat θ ∈ R^d
    hp = HParams()
    up1 = api.comm_cost("psgd", task, hp, one)["bytes_up_per_client"]
    assert up1 == d * 4               # one fp32 gradient
    # scaffold: theta + dc up; params + broadcast control variate down
    c = api.comm_cost("scaffold", task, hp, one)
    assert c["bytes_up_per_client"] == 2 * d * 4
    assert c["bytes_down_per_client"] == 2 * d * 4
    # fedns downlink carries the shared sketch frame
    ns = api.comm_cost("fedns", task, HParams(sketch=16), one)
    assert ns["bytes_down_per_client"] == d * 4 + d * 16 * 4
    # cohort scaling
    assert api.comm_cost("psgd", task, hp, one, s=5)["bytes_up"] == 5 * up1


# ------------------------------------------------------------ wire transforms

def test_bf16_wire_halves_uplink(convex):
    task, batches = convex["task"], convex["batches"]
    one = jax.tree.map(lambda x: x[0], batches)
    hp = HParams(lr=0.1)
    plain = api.comm_cost("fedavg", task, hp, one)["bytes_up_per_client"]
    cast = api.comm_cost("fedavg_bf16", task, hp, one)["bytes_up_per_client"]
    assert cast * 2 == plain


def test_topk_wire_roundtrip():
    tr = api.TopKWire(frac=0.25, fields=("delta",))
    cls = api.message_cls(("delta",), ())
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 5)).astype(np.float32))
    enc = tr.encode(cls(delta={"w": x}))
    assert set(enc.delta["w"]) == {"v", "i"}
    assert enc.delta["w"]["v"].shape == (5,)          # 25% of 20
    # decode expects a stacked message (leading participant axis)
    stacked = jax.tree.map(lambda a: a[None], enc)
    dec = tr.decode(stacked, {"w": x})
    dense = np.asarray(dec.delta["w"][0])
    flat = np.asarray(x).reshape(-1)
    top = np.argsort(-np.abs(flat))[:5]
    np.testing.assert_array_equal(dense.reshape(-1)[top], flat[top])
    mask = np.ones_like(flat, bool)
    mask[top] = False
    assert (dense.reshape(-1)[mask] == 0).all()
    assert enc.bytes_on_wire() < cls(delta={"w": x}).bytes_on_wire()


def test_gram_sketch_full_rank_is_exact():
    rng = np.random.default_rng(1)
    b = rng.normal(size=(3, 6, 6)).astype(np.float32)
    spd = b @ np.swapaxes(b, -1, -2) + 0.1 * np.eye(6, dtype=np.float32)
    cls = api.message_cls(("grams",), ())
    full = api.GramSketchWire(rank=6, fields=("grams",))
    enc = full.encode(cls(grams=jnp.asarray(spd)))
    # rank >= bs compresses nothing: A ships unencoded (and decode's
    # square pass-through leaves it untouched)
    np.testing.assert_array_equal(np.asarray(enc.grams), spd)
    np.testing.assert_array_equal(
        np.asarray(full.decode(enc, None).grams), spd)
    low = api.GramSketchWire(rank=2, fields=("grams",))
    enc2 = low.encode(cls(grams=jnp.asarray(spd)))
    assert set(enc2.grams) == {"ny"}          # marked as encoded
    assert enc2.grams["ny"].shape == (3, 6, 2)
    dec = low.decode(enc2, None)
    assert dec.grams.shape == (3, 6, 6)
    assert np.isfinite(np.asarray(dec.grams)).all()
    # a tall-but-unencoded array (params-shaped field) must pass through
    # decode untouched — only {"ny"}-marked leaves reconstruct
    tall = jnp.asarray(rng.normal(size=(4, 128, 64)).astype(np.float32))
    same = low.decode(low.encode(cls(grams=tall)), None).grams
    np.testing.assert_array_equal(np.asarray(same), np.asarray(tall))
    # rank-r reconstruction of an exactly rank-r SPD matrix is exact
    u = rng.normal(size=(6, 2)).astype(np.float32)
    lowrank = (u @ u.T)[None]
    rec = low.decode(low.encode(cls(grams=jnp.asarray(lowrank))), None).grams
    np.testing.assert_allclose(np.asarray(rec)[0], lowrank[0],
                               rtol=2e-3, atol=2e-3)


def test_wire_transform_scans_bitwise(dnn):
    """A transform-bearing algorithm keeps the scanned-driver contract:
    run_scanned ≡ the banked per-round oracle bit-for-bit (messages stay
    pure pytrees through encode/decode)."""
    task = dnn["task"].with_data(dnn["ds"].device_bank(steps=2, batch=16))
    hp = HParams(lr=0.1)
    rng, rounds = jax.random.PRNGKey(3), 3
    got, _ = FedSim(task, "fedavg_bf16", hp, N).run_scanned(
        rng, rounds, sample_clients=3, eval_every=2)
    sim = FedSim(task, "fedavg_bf16", hp, N)
    k_init, keys = round_keys(rng, rounds)
    st = sim.init(k_init)
    for t in range(rounds):
        st, m = sim.round(st, None, keys[t], sample_clients=3)
        assert m["bytes_up"] > 0            # banked rounds account too
    _assert_states_equal(got, st, tag="bf16-scan")


# ------------------------------------------------- sharded engine (8 dev) --

SHARDED_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src"); sys.path.insert(0, "tests")
import jax, jax.numpy as jnp, numpy as np
from repro.core.algorithms import ALGORITHMS, HParams
from repro.data import (FederatedDataset, make_clustered_classification,
                        make_libsvm_like)
from repro.data.federated import build_round_batches
from repro.fl.simulate import FedSim
from repro.fl.sharded import make_client_mesh
from repro.fl.tasks import ConvexTask, DNNTask
from repro.models.simple import LogisticModel, MLPModel
from legacy_zoo import LEGACY_ALGORITHMS

assert jax.device_count() == 8
mesh = make_client_mesh()
N = 16
participants = np.array([1, 4, 9, 14])

data = make_libsvm_like("a9a", seed=0)
ds = FederatedDataset.from_arrays(data, N, alpha=0.0, seed=0, test_frac=0.1)
cvx = ConvexTask(LogisticModel(d=data["x"].shape[1], lam=1e-3))
cb = ds.client_full_batches(k_steps=1)
ddata = make_clustered_classification(1600, 16, 4, seed=0)
dds = FederatedDataset.from_arrays(ddata, N, alpha=0.5, seed=0)
dnn = DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4))
db = build_round_batches(dds, 2, 16, np.random.default_rng(0))

def one_round(task, algo, hp, batches):
    sim = FedSim(task, algo, hp, N, mesh=mesh)
    st = sim.init(jax.random.PRNGKey(0))
    return sim.round(st, batches, jax.random.PRNGKey(1),
                     participants=participants)

def states_equal(a, b, tag):
    for name in ("params", "server", "clients"):
        for x, y in zip(jax.tree.leaves(getattr(a, name)),
                        jax.tree.leaves(getattr(b, name))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"{tag}:{name}")

results = {}
for name in sorted(ALGORITHMS):
    algo = ALGORITHMS[name]
    if algo.needs_grams:
        task, batches, hp = dnn, db, HParams(lr=0.3, damping=1.0)
    else:
        task, batches, hp = cvx, cb, HParams(lr=0.1, damping=1e-2)
    st, metrics = one_round(task, algo, hp, batches)
    for leaf in jax.tree.leaves(st.params):
        assert np.isfinite(np.asarray(leaf)).all(), name
    assert metrics["bytes_up"] > 0 and metrics["bytes_down"] > 0, name
    results[name] = (task, batches, hp, st)
print("SHARDED-SWEEP-OK")

# legacy bitwise spot check on the sharded engine (stateful client,
# dict-message SOGM, packed preconditioned mixing)
for name in ("scaffold", "fednl", "fedpm_foof"):
    task, batches, hp, st = results[name]
    st_old, _ = one_round(task, LEGACY_ALGORITHMS[name], hp, batches)
    states_equal(st, st_old, name)
print("SHARDED-LEGACY-BITWISE-OK")
print("OK")
'''


def test_sharded_registry_sweep():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("SHARDED-SWEEP-OK", "SHARDED-LEGACY-BITWISE-OK"):
        assert marker in res.stdout, (marker, res.stdout)


# ------------------------------------------------------------- docs freshness

def test_readme_lists_every_algorithm():
    """The README's registry table is generated (scripts/gen_alg_table.py)
    — forgetting to regenerate it after a registration shows up here."""
    root = os.path.join(os.path.dirname(__file__), "..")
    with open(os.path.join(root, "README.md")) as f:
        readme = f.read()
    for name in ALGORITHMS:
        assert f"`{name}`" in readme, f"README table missing {name!r}"

"""§Perf A1: the shard_map MoE island must match the GSPMD-auto MoE exactly
(separate process with 8 fake host devices — device count is locked at jax
init, so this runs as a subprocess)."""
import subprocess
import sys
import os

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import layers as L
from jax.sharding import NamedSharding, PartitionSpec as P

cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
cfg1 = dataclasses.replace(cfg, capacity_factor=16.0)
cfg2 = dataclasses.replace(cfg1, moe_shard_map=True)
rng = jax.random.PRNGKey(0)
p = L.init_moe(cfg, rng)
x = jax.random.normal(rng, (4, 16, cfg.d_model), dtype=jnp.float32)
out1, g1, _ = L.moe_forward(cfg1, p, x, collect=True)
from repro.distributed.axes import make_auto_mesh, use_mesh
mesh = make_auto_mesh((4, 2), ("data", "model"))
with use_mesh(mesh):
    f = jax.jit(lambda p, x: L.moe_forward(cfg2, p, x, collect=True),
                in_shardings=({"router": NamedSharding(mesh, P()),
                               "wi": NamedSharding(mesh, P("model", None, None)),
                               "wo": NamedSharding(mesh, P("model", None, None))},
                              NamedSharding(mesh, P("data", None, None))))
    out2, g2, _ = f(p, x)
err = float(jnp.max(jnp.abs(out1 - out2)))
gerr = float(jnp.max(jnp.abs(g1["wo"] - g2["wo"])))
assert err < 1e-5, err
assert gerr < 1e-6, gerr
print("OK", err, gerr)
'''


def test_moe_shardmap_matches_auto():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         capture_output=True, text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout

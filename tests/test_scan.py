"""Scan-compiled multi-round driver contracts (ISSUE 4):

* ``run_scanned`` ≡ the per-round banked ``round()`` oracle BIT-FOR-BIT
  after R rounds (params/server/clients) at fixed seeds — in-graph cohort
  sampling, scheduled cohorts (including an EMPTY round inside a chunk),
  and full participation, on the vmap engine here and the mesh-sharded
  engine in an 8-fake-device subprocess;
* eval_every chunk boundaries don't change the trajectory (chunk sizes
  1, 3, R all bitwise-identical);
* the scan jit cache keys once per (chunk length, S), not per chunk;
* the per-round jits DONATE params/server/clients: the [N, ...] client
  bank is single-buffered (input-output aliasing covers the bank bytes)
  and a state is consumed by the round it enters.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import HParams
from repro.data import FederatedDataset, make_clustered_classification
from repro.fl.simulate import FedSim, round_keys
from repro.fl.tasks import DNNTask
from repro.models.simple import MLPModel

N, R = 8, 5


@pytest.fixture(scope="module")
def task():
    data = make_clustered_classification(1200, 16, 4, seed=0)
    ds = FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)
    return DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4)
                   ).with_data(ds.device_bank(steps=2, batch=16))


def _assert_states_equal(a, b):
    for name in ("params", "server", "clients"):
        for x, y in zip(jax.tree.leaves(getattr(a, name)),
                        jax.tree.leaves(getattr(b, name))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=name)


def _oracle(task, algo, hp, rng, *, sample_clients=0, cohorts=None):
    """The documented per-round oracle: banked ``round()`` over
    ``round_keys`` keys (empty scheduled rows are skipped)."""
    sim = FedSim(task, algo, hp, N)
    k_init, keys = round_keys(rng, R)
    st = sim.init(k_init)
    for t in range(R):
        if cohorts is not None:
            row = cohorts[t]
            st, _ = sim.round(st, None, keys[t],
                              participants=row[row >= 0])
        elif sample_clients:
            st, _ = sim.round(st, None, keys[t],
                              sample_clients=sample_clients)
        else:
            st, _ = sim.round(st, None, keys[t])
    return st


# ------------------------------------------- scanned ≡ per-round oracle ----

@pytest.mark.parametrize("algo,hp", [
    ("scaffold", HParams(lr=0.1)),                   # stateful clients
    ("fedpm_foof", HParams(lr=0.3, damping=1.0)),    # preconditioned mixing
])
def test_scanned_matches_oracle_sampled(task, algo, hp):
    rng = jax.random.PRNGKey(0)
    got, _ = FedSim(task, algo, hp, N).run_scanned(rng, R, sample_clients=3,
                                                   eval_every=2)
    want = _oracle(task, algo, hp, rng, sample_clients=3)
    _assert_states_equal(got, want)


def test_scanned_matches_oracle_full_cohort(task):
    rng = jax.random.PRNGKey(1)
    hp = HParams(lr=0.1)
    got, _ = FedSim(task, "fedavg", hp, N).run_scanned(rng, R, eval_every=2)
    want = _oracle(task, "fedavg", hp, rng)
    _assert_states_equal(got, want)


def test_scheduled_cohorts_and_empty_round_inside_chunk(task):
    """An all--1 cohort row inside a chunk is a skipped round — the
    scanned chunk must land exactly where the oracle loop (which skips
    that round()) lands."""
    rng = jax.random.PRNGKey(2)
    hp = HParams(lr=0.1)
    np_rng = np.random.default_rng(7)
    cohorts = np.stack([np.sort(np_rng.choice(N, 3, replace=False))
                        for _ in range(R)]).astype(np.int32)
    cohorts[2] = -1                       # empty round mid-chunk
    got, _ = FedSim(task, "scaffold", hp, N).run_scanned(
        rng, R, cohorts=cohorts, eval_every=R)
    want = _oracle(task, "scaffold", hp, rng, cohorts=cohorts)
    _assert_states_equal(got, want)


def test_empty_round_in_full_width_schedule_is_skipped(task):
    """A schedule as wide as N (full-participation rounds) must still
    SKIP its all--1 rows — regression: the empty-row cond used to be
    dropped for S == N, silently training everyone on the idle round."""
    rng = jax.random.PRNGKey(5)
    hp = HParams(lr=0.1)
    cohorts = np.tile(np.arange(N, dtype=np.int32), (R, 1))
    cohorts[1] = -1
    got, _ = FedSim(task, "scaffold", hp, N).run_scanned(
        rng, R, cohorts=cohorts, eval_every=R)
    want = _oracle(task, "scaffold", hp, rng, cohorts=cohorts)
    _assert_states_equal(got, want)


def test_mixed_empty_cohort_row_rejected(task):
    """A row mixing -1 with real ids is ambiguous (the scan would skip
    what the oracle would partially train) — must raise, not silently
    skip."""
    sim = FedSim(task, "fedavg", HParams(), N)
    cohorts = np.array([[0, 1, 2], [-1, 2, 5]], np.int32)
    with pytest.raises(ValueError, match="ALL -1"):
        sim.run_scanned(jax.random.PRNGKey(0), 2, cohorts=cohorts)


def test_chunk_boundaries_do_not_change_trajectory(task):
    """eval_every ∈ {1, 3, R} (ragged last chunk included) are all
    bitwise-identical runs; history is bookkeeping only."""
    rng = jax.random.PRNGKey(3)
    hp = HParams(lr=0.3, damping=1.0)
    runs = {}
    for ee in (1, 3, R):
        runs[ee] = FedSim(task, "fedpm_foof", hp, N).run_scanned(
            rng, R, sample_clients=3, eval_every=ee,
            eval_fn=lambda p: 0.0)
    _assert_states_equal(runs[1][0], runs[3][0])
    _assert_states_equal(runs[1][0], runs[R][0])
    assert runs[1][1]["round"] == [0, 1, 2, 3, 4]
    assert runs[3][1]["round"] == [2, 4]              # chunks 3 + ragged 2
    assert runs[R][1]["round"] == [4]


def test_scan_jit_cache_keys_once_per_chunk_and_s(task):
    sim = FedSim(task, "fedavg", HParams(lr=0.1), N)
    rng = jax.random.PRNGKey(4)
    sim.run_scanned(rng, 6, sample_clients=3, eval_every=3)   # chunks 3,3
    n0 = sim._scan_jit._cache_size()
    assert n0 == 1                                    # one (chunk=3, S=3)
    sim.run_scanned(rng, 6, sample_clients=3, eval_every=3)   # same key
    assert sim._scan_jit._cache_size() == n0
    sim.run_scanned(rng, 7, sample_clients=3, eval_every=3)   # ragged +1
    assert sim._scan_jit._cache_size() == n0 + 1
    sim.run_scanned(rng, 6, sample_clients=4, eval_every=3)   # new S +1
    assert sim._scan_jit._cache_size() == n0 + 2


def test_round_rejects_sample_clients_with_explicit_batches(task):
    """sample_clients= is the banked round's in-graph draw — with
    explicit batches it must raise, not silently run a full round."""
    sim = FedSim(task, "fedavg", HParams(), N)
    st = sim.init(jax.random.PRNGKey(0))
    batches = task.data.sample(jax.random.PRNGKey(1),
                               jnp.arange(N, dtype=jnp.int32))
    with pytest.raises(ValueError, match="banked round"):
        sim.round(st, batches, jax.random.PRNGKey(2), sample_clients=3)


def test_run_scanned_requires_bank_and_valid_cohorts(task):
    bare = DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4))
    with pytest.raises(ValueError, match="resident data bank"):
        FedSim(bare, "fedavg", HParams(), N).run_scanned(
            jax.random.PRNGKey(0), 2)
    sim = FedSim(task, "fedavg", HParams(), N)
    with pytest.raises(ValueError, match="sorted unique"):
        sim.run_scanned(jax.random.PRNGKey(0), 2,
                        cohorts=np.array([[3, 1, 2], [0, 1, 2]]))
    with pytest.raises(ValueError, match="rounds"):
        sim.run_scanned(jax.random.PRNGKey(0), 2,
                        cohorts=np.array([[0, 1, 2]]))
    with pytest.raises(ValueError, match="eval_every"):
        sim.run_scanned(jax.random.PRNGKey(0), 2, eval_every=0)


# -------------------------------------------------- donation invariants ----

def test_round_jit_single_buffers_client_bank(task):
    """The per-round jit declares input-output aliasing that covers (at
    least) the client bank — the scatter updates the [N, ...] bank in
    place instead of allocating a second copy."""
    sim = FedSim(task, "scaffold", HParams(lr=0.1), N)
    st = sim.init(jax.random.PRNGKey(0))
    bank = sim.task.data
    idx = jnp.arange(3, dtype=jnp.int32)
    batches = bank.sample(jax.random.PRNGKey(1), idx)
    lowered = sim._round_jit.lower(
        st.params, st.server, st.clients, batches, jax.random.PRNGKey(2),
        idx, jnp.ones((3,), jnp.float32), full=False)
    ma = lowered.compile().memory_analysis()
    bank_bytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(st.clients))
    state_bytes = bank_bytes + sum(
        x.size * x.dtype.itemsize
        for t in (st.params, st.server) for x in jax.tree.leaves(t))
    assert ma.alias_size_in_bytes >= bank_bytes, \
        (ma.alias_size_in_bytes, bank_bytes)
    # and the declared aliasing covers the whole donated carry
    assert ma.alias_size_in_bytes >= state_bytes, \
        (ma.alias_size_in_bytes, state_bytes)


def test_round_consumes_state_and_copy_survives(task):
    """Donation semantics: the input state's buffers are deleted by the
    round (proof the runtime actually aliased them), FedState.copy gives
    a reusable snapshot, and an empty-cohort round (no jit dispatch)
    leaves the state alive."""
    sim = FedSim(task, "scaffold", HParams(lr=0.1), N)
    st = sim.init(jax.random.PRNGKey(0))
    keep = st.copy()
    leaf = jax.tree.leaves(st.clients)[0]
    st1, _ = sim.round(st, None, jax.random.PRNGKey(1), sample_clients=3)
    assert leaf.is_deleted()
    assert not jax.tree.leaves(keep.clients)[0].is_deleted()
    st2, _ = sim.round(keep, None, jax.random.PRNGKey(2),
                       participants=np.array([], np.int32))
    assert not jax.tree.leaves(st2.clients)[0].is_deleted()


# ------------------------------------------------- sharded engine (8 dev) --

SHARDED_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.core.algorithms import HParams
from repro.data import FederatedDataset, make_clustered_classification, \
    make_libsvm_like
from repro.fl.simulate import FedSim, round_keys
from repro.fl.sharded import make_client_mesh
from repro.fl.tasks import ConvexTask, DNNTask
from repro.models.simple import LogisticModel, MLPModel

assert jax.device_count() == 8
mesh = make_client_mesh()
N, R = 16, 4

data = make_clustered_classification(1600, 16, 4, seed=0)
ds = FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)
dnn = DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4)
              ).with_data(ds.device_bank(steps=2, batch=16))
cdata = make_libsvm_like("a9a", seed=0)
cds = FederatedDataset.from_arrays(cdata, N, alpha=0.0, seed=0,
                                   test_frac=0.1)
cvx = ConvexTask(LogisticModel(d=cdata["x"].shape[1], lam=1e-3)
                 ).with_data(cds.device_bank(steps=1, batch=0))

def check_equal(a, b, tag):
    for name in ("params", "server", "clients"):
        for x, y in zip(jax.tree.leaves(getattr(a, name)),
                        jax.tree.leaves(getattr(b, name))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"{tag}:{name}")

def oracle(task, algo, hp, rng, sample_clients=0, cohorts=None):
    sim = FedSim(task, algo, hp, N, mesh=mesh)
    k_init, keys = round_keys(rng, R)
    st = sim.init(k_init)
    for t in range(R):
        if cohorts is not None:
            row = cohorts[t]
            st, _ = sim.round(st, None, keys[t], participants=row[row >= 0])
        elif sample_clients:
            st, _ = sim.round(st, None, keys[t],
                              sample_clients=sample_clients)
        else:
            st, _ = sim.round(st, None, keys[t])
    return st

rng = jax.random.PRNGKey(0)
np_rng = np.random.default_rng(5)
cohorts = np.stack([np.sort(np_rng.choice(N, 5, replace=False))
                    for _ in range(R)]).astype(np.int32)
cohorts[1] = -1                                  # empty round mid-chunk

for tag, task, algo, hp, kw in [
    ("scaffold-S5", cvx, "scaffold", HParams(lr=0.3),
     dict(sample_clients=5)),
    ("fedpm-S5", cvx, "fedpm", HParams(lr=1.0, damping=1e-2),
     dict(sample_clients=5)),
    ("foof-full", dnn, "fedpm_foof", HParams(lr=0.3, damping=1.0), {}),
    ("sched-empty", cvx, "scaffold", HParams(lr=0.3),
     dict(cohorts=cohorts)),
]:
    got, _ = FedSim(task, algo, hp, N, mesh=mesh).run_scanned(
        rng, R, eval_every=2, **kw)
    check_equal(got, oracle(task, algo, hp, rng, **kw), tag)
print("SHARDED-SCAN-EQUIV-OK")

# scan jit cache: one program per (chunk length, S)
sim = FedSim(cvx, "scaffold", HParams(lr=0.3), N, mesh=mesh)
sim.run_scanned(rng, 4, sample_clients=5, eval_every=2)
n0 = sim._scan_sharded_jit._cache_size()
sim.run_scanned(rng, 4, sample_clients=5, eval_every=2)
assert sim._scan_sharded_jit._cache_size() == n0
sim.run_scanned(rng, 4, sample_clients=4, eval_every=2)
assert sim._scan_sharded_jit._cache_size() == n0 + 1
print("SHARDED-SCAN-CACHE-OK")

# donation: the sharded per-round jit consumes its input state too
sim = FedSim(cvx, "scaffold", HParams(lr=0.3), N, mesh=mesh)
st = sim.init(jax.random.PRNGKey(0))
leaf = jax.tree.leaves(st.clients)[0]
st1, _ = sim.round(st, None, jax.random.PRNGKey(1), sample_clients=5)
assert leaf.is_deleted()
print("SHARDED-DONATE-OK")
print("OK")
'''


def test_sharded_scan_contracts():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("SHARDED-SCAN-EQUIV-OK", "SHARDED-SCAN-CACHE-OK",
                   "SHARDED-DONATE-OK"):
        assert marker in res.stdout, (marker, res.stdout)

"""Buffered-async round contracts (ISSUE 8):

* ``CohortSchedule`` protocol: raw-array path ≡ ``ArraySchedule`` path
  BIT-FOR-BIT; seeded generators and registered availability traces all
  produce one valid host array; the shape / dead-row / sortedness
  validation lives in ``repro.fl.schedule`` (one contract for every
  consumer);
* ``BufferedSchedule`` event process: FIFO buffer fills and flushes at
  exactly ``goal`` reports, staleness = flush round − dispatch round, a
  flush row never repeats an id (a client is busy until it reports), and
  ``resolve`` sizes the params ring at max staleness + 1;
* the HARD equivalence contract: zero-staleness async (``delay=0,
  concurrency == goal``) reproduces the synchronous engine BITWISE on
  the vmap engine — params, server, the whole client bank — and to fp32
  mixing tolerance on the 8-fake-device mesh engine (subprocess);
* non-reporting clients are untouched: a client never flushed keeps its
  init state row bitwise;
* paged client/data banks compose with the async engine bitwise vs the
  resident async run;
* the ``bucket_cohort`` mis-bucketing bug: unsorted cohort rows silently
  DROP participants in-graph (slot collisions), so unsorted explicit
  schedules are rejected at the host boundary with a clear error.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import HParams
from repro.data import FederatedDataset, make_clustered_classification
from repro.fl import schedule as SCH
from repro.fl.sharded import bucket_cohort
from repro.fl.simulate import FedSim, round_keys
from repro.fl.tasks import DNNTask
from repro.models.simple import MLPModel

N, R = 8, 6


@pytest.fixture(scope="module")
def task():
    data = make_clustered_classification(1200, 16, 4, seed=0)
    ds = FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)
    return DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4)
                   ).with_data(ds.device_bank(steps=2, batch=16))


@pytest.fixture(scope="module")
def ds():
    data = make_clustered_classification(1200, 16, 4, seed=0)
    return FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)


def _assert_states_equal(a, b, tag=""):
    ca = a.clients.bank if hasattr(a.clients, "bank") else a.clients
    cb = b.clients.bank if hasattr(b.clients, "bank") else b.clients
    for name, x, y in (("params", a.params, b.params),
                       ("server", a.server, b.server),
                       ("clients", ca, cb)):
        for u, v in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                          err_msg=f"{tag}:{name}")


# ------------------------------------------------ schedule validation ----

def test_validate_cohorts_contract():
    good = np.array([[0, 2, 5], [-1, -1, -1], [1, 3, 7]], np.int32)
    out = SCH.validate_cohorts(good, 3, N)
    np.testing.assert_array_equal(out, good)
    with pytest.raises(ValueError, match="rounds"):
        SCH.validate_cohorts(good, 4, N)
    with pytest.raises(ValueError, match="sorted unique"):
        SCH.validate_cohorts([[5, 0, 2]], 1, N)          # unsorted
    with pytest.raises(ValueError, match="sorted unique"):
        SCH.validate_cohorts([[0, 2, 2]], 1, N)          # duplicate
    with pytest.raises(ValueError, match="sorted unique"):
        SCH.validate_cohorts([[0, 2, 8]], 1, N)          # out of range
    with pytest.raises(ValueError, match="ALL -1"):
        SCH.validate_cohorts([[-1, 3, 5]], 1, N)         # mixed dead row


def test_validate_staleness_contract():
    cohorts = np.array([[0, 2], [1, 3], [-1, -1]], np.int32)
    taus = np.array([[0, 0], [1, 0], [0, 0]], np.int32)
    np.testing.assert_array_equal(
        SCH.validate_staleness(taus, cohorts), taus)
    with pytest.raises(ValueError, match="shape"):
        SCH.validate_staleness(np.zeros((3, 3), np.int32), cohorts)
    with pytest.raises(ValueError, match="0 <= tau <= t"):
        SCH.validate_staleness(np.array([[0, -1], [0, 0], [0, 0]]),
                               cohorts)
    with pytest.raises(ValueError, match="0 <= tau <= t"):  # predates run
        SCH.validate_staleness(np.array([[1, 0], [0, 0], [0, 0]]),
                               cohorts)


def test_resolve_plans():
    # None -> in-graph sampling plan, not scheduled, not async
    plan = SCH.resolve(None, rounds=4, n=N, sample_clients=3)
    assert (plan.cohorts is None and not plan.scheduled
            and not plan.is_async and plan.s == 3)
    # raw array -> scheduled sync plan
    raw = np.array([[0, 2, 5]] * 4, np.int32)
    plan = SCH.resolve(raw, rounds=4, n=N)
    assert plan.scheduled and not plan.is_async and plan.s == 3
    # buffered -> async plan, window = max live staleness + 1
    sched = SCH.BufferedSchedule(goal=3, concurrency=6, delay=(1, 3),
                                 seed=2, weight_pow=0.5)
    rows, taus = sched.build(N, 8)
    plan = SCH.resolve(sched, rounds=8, n=N)
    live = rows[:, 0] >= 0
    assert plan.is_async
    assert plan.window == int(taus[live].max()) + 1
    assert plan.weight_pow == 0.5
    np.testing.assert_array_equal(plan.cohorts, rows)
    np.testing.assert_array_equal(plan.staleness, taus)


# -------------------------------------------------- schedule builders ----

def test_sampled_schedule_valid_and_deterministic():
    a = SCH.SampledSchedule(s=3, seed=7).build(N, 5)
    b = SCH.SampledSchedule(s=3, seed=7).build(N, 5)
    np.testing.assert_array_equal(a, b)
    SCH.validate_cohorts(a, 5, N)
    assert (a >= 0).all()
    with pytest.raises(ValueError, match="0 < s <= n"):
        SCH.SampledSchedule(s=9).build(N, 5)


@pytest.mark.parametrize("name,kw", [("diurnal", dict(period=6)),
                                     ("dropout_midround",
                                      dict(drop_prob=0.4))])
def test_traces_produce_valid_schedules(name, kw):
    rows = SCH.trace(name, 5, seed=3, **kw).build(N, 24)
    SCH.validate_cohorts(rows, 24, N)       # sorted unique or all -1
    live = rows[:, 0] >= 0
    assert live.any(), "trace produced no live rounds"
    assert (~live).any(), f"{name} never lost quorum at these settings"


def test_trace_unknown_name():
    with pytest.raises(ValueError, match="unknown trace"):
        SCH.trace("nope", 4)


def test_buffered_schedule_event_process():
    goal, conc, delay, rounds = 3, 6, 2, 12
    rows, taus = SCH.BufferedSchedule(goal=goal, concurrency=conc,
                                      delay=delay, seed=0).build(N, rounds)
    SCH.validate_cohorts(rows, rounds, N)
    SCH.validate_staleness(taus, rows)
    live = rows[:, 0] >= 0
    # nothing can report before `delay` rounds have passed
    assert not live[:delay].any()
    # first arrivals: all `conc` dispatches land at t=delay; the buffer
    # flushes at most one goal-sized batch per round, so t=delay and
    # t=delay+1 both flush (conc = 2*goal reports queued FIFO)
    assert live[delay] and live[delay + 1]
    np.testing.assert_array_equal(taus[delay], delay)
    np.testing.assert_array_equal(taus[delay + 1], delay + 1)
    # a flush row never repeats an id, and a client is busy from
    # dispatch to flush: replay busy intervals from the tau record
    for t in np.flatnonzero(live):
        ids = rows[t]
        assert len(set(ids.tolist())) == goal
    busy_until = np.full(N, -1)
    for t in np.flatnonzero(live):
        for c, tau in zip(rows[t], taus[t]):
            t0 = t - tau
            assert t0 > busy_until[c], \
                f"client {c} re-dispatched at {t0} while busy"
            busy_until[c] = t


def test_buffered_schedule_zero_delay_degenerates():
    rows, taus = SCH.BufferedSchedule(goal=3, concurrency=3, delay=0,
                                      seed=1).build(N, R)
    assert (rows >= 0).all(), "every round flushes a fresh cohort"
    assert (taus == 0).all()
    assert SCH.resolve(SCH.BufferedSchedule(goal=3, concurrency=3,
                                            delay=0, seed=1),
                       rounds=R, n=N).window == 1


def test_buffered_schedule_validation():
    with pytest.raises(ValueError, match="goal"):
        SCH.BufferedSchedule(goal=0, concurrency=3).build(N, 4)
    with pytest.raises(ValueError, match="never reach"):
        SCH.BufferedSchedule(goal=4, concurrency=3).build(N, 4)
    with pytest.raises(ValueError, match="population"):
        SCH.BufferedSchedule(goal=3, concurrency=9).build(N, 4)
    with pytest.raises(ValueError, match="delay"):
        SCH.BufferedSchedule(goal=3, concurrency=3,
                             delay=(2, 1)).build(N, 4)


# ----------------------------------- bucket_cohort mis-bucketing (bug) ----

def test_bucket_cohort_unsorted_misbuckets():
    """The in-graph rank-within-shard slot math silently DROPS a
    participant when the cohort is unsorted (slot collision overwrites a
    bucket entry) — the reason unsorted explicit schedules are rejected
    at the host boundary instead of 'fixed' in-graph."""
    ones = jnp.ones((4,), jnp.float32)
    _, _, w_ok = bucket_cohort(jnp.array([0, 1, 6, 7]), ones, N, 4)
    assert float(w_ok.sum()) == 4.0          # all four weights survive
    _, _, w_bad = bucket_cohort(jnp.array([0, 6, 1, 7]), ones, N, 4)
    assert float(w_bad.sum()) < 4.0          # collision lost reports


def test_unsorted_explicit_schedule_rejected(task):
    sim = FedSim(task, "fedavg", HParams(lr=0.1), N)
    bad = np.array([[5, 0, 2]] * R, np.int32)
    with pytest.raises(ValueError, match="sorted unique"):
        sim.run_scanned(jax.random.PRNGKey(0), R, cohorts=bad)


# ------------------------------------------------- engine equivalences ----

def test_array_schedule_matches_raw_array_bitwise(task):
    raw = SCH.SampledSchedule(s=3, seed=5).build(N, R)
    rng = jax.random.PRNGKey(3)
    st_raw, _ = FedSim(task, "fedpm_foof", HParams(lr=0.1), N).run_scanned(
        rng, R, cohorts=raw, eval_every=2)
    st_sch, _ = FedSim(task, "fedpm_foof", HParams(lr=0.1), N).run_scanned(
        rng, R, cohorts=SCH.ArraySchedule(raw), eval_every=2)
    _assert_states_equal(st_raw, st_sch, tag="array-schedule")


@pytest.mark.parametrize("algo", ["fedavg", "scaffold", "fedpm_foof"])
def test_zero_staleness_async_is_sync_bitwise(task, algo):
    """THE contract: delay=0, concurrency == goal makes every round a
    fresh zero-staleness cohort, and the async engine must then
    reproduce the synchronous engine bitwise — params, server state and
    the whole client bank."""
    sched = SCH.BufferedSchedule(goal=3, concurrency=3, delay=0, seed=1)
    rows, taus = sched.build(N, R)
    assert (taus[rows >= 0] == 0).all()
    rng = jax.random.PRNGKey(7)
    hp = HParams(lr=0.1)
    st_a, _ = FedSim(task, algo, hp, N).run_scanned(
        rng, R, cohorts=sched, eval_every=2)
    st_s, _ = FedSim(task, algo, hp, N).run_scanned(
        rng, R, cohorts=rows, eval_every=2)
    _assert_states_equal(st_a, st_s, tag=algo)


def test_stale_run_finite_and_staleness_matters(task):
    """A genuinely stale run (delay > 0) stays finite, and staleness is
    LOAD-BEARING: the same cohort rows with their true staleness produce
    a different trajectory than the sync engine pretending the reports
    are fresh (params ring + damping hook engaged)."""
    sched = SCH.BufferedSchedule(goal=3, concurrency=6, delay=(1, 3),
                                 seed=2, weight_pow=0.5)
    rows, taus = sched.build(N, R + 2)
    assert taus[rows >= 0].max() > 0
    rng = jax.random.PRNGKey(7)
    hp = HParams(lr=0.1)
    st_a, _ = FedSim(task, "fedpm_foof", hp, N).run_scanned(
        rng, R + 2, cohorts=sched)
    for x in jax.tree.leaves(st_a.params):
        assert np.isfinite(np.asarray(x)).all()
    st_s, _ = FedSim(task, "fedpm_foof", hp, N).run_scanned(
        rng, R + 2, cohorts=rows)
    diff = max(float(np.abs(np.asarray(x) - np.asarray(y)).max())
               for x, y in zip(jax.tree.leaves(st_a.params),
                               jax.tree.leaves(st_s.params)))
    assert diff > 0


def test_nonreporting_clients_untouched(task):
    """A client that never flushes keeps its init state row bitwise —
    in-flight and never-dispatched clients alike are spectators to every
    flush round's scatter."""
    sched = SCH.BufferedSchedule(goal=2, concurrency=2, delay=(1, 2),
                                 seed=4)
    rows, _ = sched.build(N, R)
    reported = np.unique(rows[rows >= 0])
    silent = np.setdiff1d(np.arange(N), reported)
    assert silent.size, "seed produced full participation; pick another"
    rng = jax.random.PRNGKey(9)
    sim = FedSim(task, "scaffold", HParams(lr=0.1), N)
    k_init, _ = round_keys(rng, R)
    init_rows = jax.tree.map(lambda x: np.asarray(x)[silent],
                             sim.init(k_init).clients)
    st, _ = FedSim(task, "scaffold", HParams(lr=0.1), N).run_scanned(
        rng, R, cohorts=sched)
    for x, y in zip(jax.tree.leaves(init_rows),
                    jax.tree.leaves(jax.tree.map(
                        lambda x: np.asarray(x)[silent], st.clients))):
        np.testing.assert_array_equal(x, y, err_msg="silent client moved")


def test_paged_async_matches_resident_async(ds):
    """Host-paged client/data banks compose with the buffered-async
    engine: same trajectory bitwise as the resident async run (the
    chunk union dedups the overlapping cohorts — see
    ``repro.fl.store.plan_chunk``)."""
    base = DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4))
    res = base.with_data(ds.device_bank(steps=2, batch=16))
    pag = base.with_data(ds.paged_bank(steps=2, batch=16))
    sched = SCH.BufferedSchedule(goal=3, concurrency=6, delay=(1, 3),
                                 seed=2, weight_pow=0.5)
    rng = jax.random.PRNGKey(7)
    hp = HParams(lr=0.1)
    st_r, _ = FedSim(res, "fedpm_foof", hp, N).run_scanned(
        rng, R + 2, cohorts=sched, eval_every=4)
    st_p, _ = FedSim(pag, "fedpm_foof", hp, N).run_scanned(
        rng, R + 2, cohorts=sched, eval_every=4)
    _assert_states_equal(st_r, st_p, tag="paged-async")


# ------------------------------------------- sharded engine (8 devices) ----

ASYNC_SHARDED_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.core.algorithms import HParams
from repro.data import FederatedDataset, make_clustered_classification
from repro.fl.simulate import FedSim
from repro.fl.sharded import make_client_mesh
from repro.fl.tasks import DNNTask
from repro.models.simple import MLPModel
from repro.fl import schedule as SCH

assert jax.device_count() == 8
mesh = make_client_mesh()
N, R, S = 16, 6, 4
data = make_clustered_classification(1600, 16, 4, seed=0)
ds = FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)
task = DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4)
               ).with_data(ds.device_bank(steps=2, batch=16))
hp = HParams(lr=0.1)

def close(a, b, tag):
    for name in ("params", "server", "clients"):
        for u, v in zip(jax.tree.leaves(getattr(a, name)),
                        jax.tree.leaves(getattr(b, name))):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=2e-6, atol=2e-6,
                                       err_msg=f"{tag}:{name}")

sched = SCH.BufferedSchedule(goal=S, concurrency=S, delay=0, seed=1)
rows, taus = sched.build(N, R)
assert (np.asarray(taus)[np.asarray(rows) >= 0] == 0).all()
rng = jax.random.PRNGKey(7)
for alg in ["scaffold", "fedpm_foof"]:
    st_a, _ = FedSim(task, alg, hp, N, mesh=mesh).run_scanned(
        rng, R, cohorts=sched, eval_every=3)
    st_s, _ = FedSim(task, alg, hp, N, mesh=mesh).run_scanned(
        rng, R, cohorts=rows, eval_every=3)
    close(st_a, st_s, alg)
print("ASYNC-SHARDED-EQUIV-OK")

stale = SCH.BufferedSchedule(goal=S, concurrency=8, delay=(1, 3), seed=2,
                             weight_pow=0.5)
srows, staus = stale.build(N, R)
assert np.asarray(staus)[np.asarray(srows) >= 0].max() > 0
st, _ = FedSim(task, "fedpm_foof", hp, N, mesh=mesh).run_scanned(
    rng, R, cohorts=stale)
for x in jax.tree.leaves(st.params):
    assert np.isfinite(np.asarray(x)).all()
print("ASYNC-SHARDED-STALE-OK")
print("OK")
'''


def test_sharded_async_contracts():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", ASYNC_SHARDED_SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("ASYNC-SHARDED-EQUIV-OK", "ASYNC-SHARDED-STALE-OK"):
        assert marker in res.stdout, (marker, res.stdout)

"""Per-kernel shape/dtype sweeps: pallas_call (interpret=True on CPU)
against the pure-jnp oracles (spec §c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.kernels.gram import ops as gram_ops
from repro.kernels.gram.ref import gram_blocks_ref
from repro.kernels.nschulz import ops as ns_ops
from repro.kernels.nschulz.ref import ns_inverse_ref


@pytest.mark.parametrize("t,d,block", [
    (128, 128, 128), (256, 256, 128), (512, 128, 64),
    (384, 512, 256), (64, 64, 64), (100, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_kernel_matches_ref(t, d, block, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d), dtype=dtype)
    got = gram_ops.gram(x, block, damping=0.01, use_pallas=True)
    want = gram_blocks_ref(x, block, damping=0.01)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@settings(max_examples=8, deadline=None)
@given(nbt=st.integers(1, 4), block=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 99))
def test_gram_kernel_property(nbt, block, seed):
    """PSD + exact diagonal scaling under random shapes."""
    t = 128 * nbt
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, block * 2))
    a = gram_ops.gram(x, block, use_pallas=True)
    want = gram_blocks_ref(x, block)
    np.testing.assert_allclose(np.asarray(a), np.asarray(want), rtol=1e-5,
                               atol=1e-5)
    eig = np.linalg.eigvalsh(np.asarray(a))
    assert (eig > -1e-4).all()          # PSD


@pytest.mark.parametrize("nb,bs", [(1, 32), (4, 64), (2, 128), (3, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ns_kernel_matches_ref_and_truth(nb, bs, dtype):
    m = jax.random.normal(jax.random.PRNGKey(1), (nb, bs, bs), dtype=dtype)
    a = (jnp.einsum("nij,nkj->nik", m.astype(jnp.float32), m.astype(jnp.float32))
         / bs + 0.1 * jnp.eye(bs))
    got = ns_ops.ns_inverse(a, iters=25, use_pallas=True)
    ref = ns_inverse_ref(a, iters=25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    tru = np.linalg.inv(np.asarray(a))
    np.testing.assert_allclose(np.asarray(got), tru, rtol=1e-2, atol=1e-3)


def test_ns_kernel_damping_fused():
    rng = jax.random.PRNGKey(2)
    m = jax.random.normal(rng, (2, 64, 64))
    a = jnp.einsum("nij,nkj->nik", m, m) / 64
    got = ns_ops.ns_inverse(a, iters=25, damping=0.5, use_pallas=True)
    tru = np.linalg.inv(np.asarray(a + 0.5 * jnp.eye(64)))
    np.testing.assert_allclose(np.asarray(got), tru, rtol=1e-2, atol=1e-3)


def test_ns_kernel_batched_leading_dims():
    rng = jax.random.PRNGKey(3)
    m = jax.random.normal(rng, (2, 3, 32, 32))
    a = jnp.einsum("unij,unkj->unik", m, m) / 32 + 0.2 * jnp.eye(32)
    got = ns_ops.ns_inverse(a, iters=25, use_pallas=True)
    assert got.shape == a.shape
    tru = np.linalg.inv(np.asarray(a))
    np.testing.assert_allclose(np.asarray(got), tru, rtol=1e-2, atol=1e-3)

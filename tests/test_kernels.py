"""Per-kernel shape/dtype sweeps: pallas_call (interpret=True on CPU)
against the pure-jnp oracles (spec §c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.kernels.cholesky import ops as chol_ops
from repro.kernels.cholesky.ref import chol_inverse_ref, chol_solve_ref
from repro.kernels.gram import ops as gram_ops
from repro.kernels.gram.ref import gram_blocks_ref
from repro.kernels.mix import ops as mix_ops
from repro.kernels.mix.ref import mix_ref
from repro.kernels.nschulz import ops as ns_ops
from repro.kernels.nschulz.ref import ns_inverse_ref, ns_solve_ref


def _spd(key, nb, bs, dtype=jnp.float32, damp=0.1):
    m = jax.random.normal(key, (nb, bs, bs), dtype=dtype)
    a = (jnp.einsum("nij,nkj->nik", m.astype(jnp.float32),
                    m.astype(jnp.float32)) / bs + damp * jnp.eye(bs))
    return a.astype(dtype)


@pytest.mark.parametrize("t,d,block", [
    (128, 128, 128), (256, 256, 128), (512, 128, 64),
    (384, 512, 256), (64, 64, 64), (100, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_kernel_matches_ref(t, d, block, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d), dtype=dtype)
    got = gram_ops.gram(x, block, damping=0.01, use_pallas=True)
    want = gram_blocks_ref(x, block, damping=0.01)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@settings(max_examples=8, deadline=None)
@given(nbt=st.integers(1, 4), block=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 99))
def test_gram_kernel_property(nbt, block, seed):
    """PSD + exact diagonal scaling under random shapes."""
    t = 128 * nbt
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, block * 2))
    a = gram_ops.gram(x, block, use_pallas=True)
    want = gram_blocks_ref(x, block)
    np.testing.assert_allclose(np.asarray(a), np.asarray(want), rtol=1e-5,
                               atol=1e-5)
    eig = np.linalg.eigvalsh(np.asarray(a))
    assert (eig > -1e-4).all()          # PSD


@pytest.mark.parametrize("nb,bs", [(1, 32), (4, 64), (2, 128), (3, 256),
                                   (3, 48), (2, 96), (1, 200), (1, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ns_kernel_matches_ref_and_truth(nb, bs, dtype):
    """Includes block sizes that do NOT divide the 128 MXU lane (48, 96,
    200) and the B=1 degenerate bank."""
    m = jax.random.normal(jax.random.PRNGKey(1), (nb, bs, bs), dtype=dtype)
    a = (jnp.einsum("nij,nkj->nik", m.astype(jnp.float32), m.astype(jnp.float32))
         / bs + 0.1 * jnp.eye(bs))
    got = ns_ops.ns_inverse(a, iters=25, use_pallas=True)
    ref = ns_inverse_ref(a, iters=25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    tru = np.linalg.inv(np.asarray(a))
    np.testing.assert_allclose(np.asarray(got), tru, rtol=1e-2, atol=1e-3)


def test_ns_kernel_damping_fused():
    rng = jax.random.PRNGKey(2)
    m = jax.random.normal(rng, (2, 64, 64))
    a = jnp.einsum("nij,nkj->nik", m, m) / 64
    got = ns_ops.ns_inverse(a, iters=25, damping=0.5, use_pallas=True)
    tru = np.linalg.inv(np.asarray(a + 0.5 * jnp.eye(64)))
    np.testing.assert_allclose(np.asarray(got), tru, rtol=1e-2, atol=1e-3)


def test_ns_kernel_batched_leading_dims():
    rng = jax.random.PRNGKey(3)
    m = jax.random.normal(rng, (2, 3, 32, 32))
    a = jnp.einsum("unij,unkj->unik", m, m) / 32 + 0.2 * jnp.eye(32)
    got = ns_ops.ns_inverse(a, iters=25, use_pallas=True)
    assert got.shape == a.shape
    tru = np.linalg.inv(np.asarray(a))
    np.testing.assert_allclose(np.asarray(got), tru, rtol=1e-2, atol=1e-3)


# ------------------------------------------- fused invert-and-apply --------

@pytest.mark.parametrize("nb,bs,k", [(1, 32, 8), (4, 64, 16), (2, 128, 64),
                                     (3, 48, 5), (2, 96, 33), (1, 200, 17)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ns_solve_fused_matches_oracle(nb, bs, k, dtype):
    """The packed-bank invert-and-apply kernel (X computed and consumed in
    VMEM) vs the jnp oracle (explicit inverse then matmul); sweeps block
    sizes off the 128 lane and a B=1 bank."""
    m = jax.random.normal(jax.random.PRNGKey(5), (nb, bs, bs), dtype=dtype)
    a = (jnp.einsum("nij,nkj->nik", m.astype(jnp.float32),
                    m.astype(jnp.float32)) / bs + 0.1 * jnp.eye(bs))
    b = jax.random.normal(jax.random.PRNGKey(6), (nb, bs, k), dtype=dtype)
    got = ns_ops.ns_solve(a, b, iters=25, use_pallas=True)
    ref = ns_solve_ref(a, b, iters=25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    tru = np.linalg.solve(np.asarray(a), np.asarray(b, dtype=np.float32))
    np.testing.assert_allclose(np.asarray(got), tru, rtol=1e-2, atol=1e-3)


def test_ns_solve_fused_damping():
    m = jax.random.normal(jax.random.PRNGKey(7), (3, 48, 48))
    a = jnp.einsum("nij,nkj->nik", m, m) / 48
    b = jax.random.normal(jax.random.PRNGKey(8), (3, 48, 7))
    got = ns_ops.ns_solve(a, b, iters=25, damping=0.5, use_pallas=True)
    tru = np.linalg.solve(np.asarray(a + 0.5 * jnp.eye(48)), np.asarray(b))
    np.testing.assert_allclose(np.asarray(got), tru, rtol=1e-2, atol=1e-3)


def test_ns_solve_broadcast_and_wide_fallback():
    """Leading-dim broadcast plus the wide-k VMEM fallback path agree with
    the oracle."""
    m = jax.random.normal(jax.random.PRNGKey(9), (2, 16, 16))
    a = jnp.einsum("nij,nkj->nik", m, m) / 16 + 0.2 * jnp.eye(16)
    b = jax.random.normal(jax.random.PRNGKey(10), (5, 2, 16, 9))
    got = ns_ops.ns_solve(a, b, iters=25, use_pallas=True)
    assert got.shape == (5, 2, 16, 9)
    ref = ns_solve_ref(jnp.broadcast_to(a, (5, 2, 16, 16)), b, iters=25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # wide k: interpret-mode cap routes through ns_inverse + matmul
    bw = jax.random.normal(jax.random.PRNGKey(11), (2, 16, 8192))
    gw = ns_ops.ns_solve(a, bw, iters=25, use_pallas=False)
    rw = ns_solve_ref(a, bw, iters=25)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nb,bs,k", [(2, 32, 8), (1, 64, 100), (3, 48, 7)])
def test_ns_solve_mxu_pad_equals_unpadded(nb, bs, k):
    """On TPU, ns_solve zero-pads the RHS lane to the 128-wide MXU tile
    before the kernel and slices after.  This asserts the invariant that
    padding relies on, on the same kernel the TPU runs: a zero-padded
    RHS's first k output columns are IDENTICAL to the unpadded solve
    (zero columns can't perturb X@B), and both match the oracle."""
    m = jax.random.normal(jax.random.PRNGKey(20), (nb, bs, bs))
    a = jnp.einsum("nij,nkj->nik", m, m) / bs + 0.1 * jnp.eye(bs)
    b = jax.random.normal(jax.random.PRNGKey(21), (nb, bs, k))
    got = ns_ops.ns_solve(a, b, iters=25, use_pallas=True)
    kp = -(-k // 128) * 128
    bp = jnp.concatenate([b, jnp.zeros((nb, bs, kp - k))], axis=-1)
    padded = ns_ops.ns_solve(a, bp, iters=25, use_pallas=True)[..., :k]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(padded))
    ref = ns_solve_ref(a, b, iters=25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------- blocked Cholesky --------------

@pytest.mark.parametrize("nb,bs", [(1, 32), (3, 48), (4, 64), (2, 96),
                                   (2, 128), (1, 200), (1, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chol_inverse_kernel_matches_lapack(nb, bs, dtype):
    """The Schur-recursive kernel (interpret on CPU — the exact TPU
    program) vs the LAPACK oracle, fp32 accumulation from bf16 inputs."""
    a = _spd(jax.random.PRNGKey(30), nb, bs, dtype, damp=0.2)
    got = chol_ops.chol_inverse(a, damping=0.05, use_pallas=True)
    want = chol_inverse_ref(a, damping=0.05)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nb,bs,k", [(2, 32, 8), (3, 48, 5), (2, 96, 33),
                                     (1, 128, 96), (1, 200, 17)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chol_solve_fused_matches_lapack(nb, bs, k, dtype):
    a = _spd(jax.random.PRNGKey(31), nb, bs, dtype, damp=0.2)
    b = jax.random.normal(jax.random.PRNGKey(32), (nb, bs, k), dtype=dtype)
    got = chol_ops.chol_solve(a, b, damping=0.05, use_pallas=True)
    want = chol_solve_ref(a, b, damping=0.05)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_chol_cpu_schur_dispatch_matches_lapack():
    """The CPU auto path (use_pallas=None → Schur restructuring with
    LAPACK leaf tiles at bs >= 65) must be numerically interchangeable
    with the plain LAPACK reference at the roofline gate shape."""
    a = _spd(jax.random.PRNGKey(33), 16, 128)
    b = jax.random.normal(jax.random.PRNGKey(34), (16, 128, 96))
    np.testing.assert_allclose(
        np.asarray(chol_ops.chol_inverse(a, damping=0.1)),
        np.asarray(chol_inverse_ref(a, damping=0.1)), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(chol_ops.chol_solve(a, b, damping=0.1)),
        np.asarray(chol_solve_ref(a, b, damping=0.1)), rtol=1e-4, atol=1e-4)


def test_chol_solve_broadcast_leading_dims():
    """One bank applied to many RHS stacks routes through chol_inverse +
    a broadcasting matmul."""
    a = _spd(jax.random.PRNGKey(35), 2, 16)
    b = jax.random.normal(jax.random.PRNGKey(36), (5, 2, 16, 9))
    got = chol_ops.chol_solve(a, b, damping=0.1, use_pallas=True)
    assert got.shape == (5, 2, 16, 9)
    want = chol_solve_ref(jnp.broadcast_to(a, (5, 2, 16, 16)), b,
                          damping=0.1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_chol_solve_mxu_pad_equals_unpadded():
    """Same invariant the TPU-side RHS lane padding relies on, asserted on
    the kernel itself: zero columns cannot perturb X@B."""
    a = _spd(jax.random.PRNGKey(37), 2, 48)
    b = jax.random.normal(jax.random.PRNGKey(38), (2, 48, 7))
    got = chol_ops.chol_solve(a, b, damping=0.1, use_pallas=True)
    bp = jnp.concatenate([b, jnp.zeros((2, 48, 128 - 7))], axis=-1)
    padded = chol_ops.chol_solve(a, bp, damping=0.1,
                                 use_pallas=True)[..., :7]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(padded))


# ------------------------------------------- fused Eq. 12 mixing -----------

@pytest.mark.parametrize("solver", ["ns", "chol"])
@pytest.mark.parametrize("s,r,bs,k", [(3, 4, 32, 8), (2, 2, 48, 5),
                                      (1, 3, 96, 17), (4, 1, 64, 9)])
def test_mix_kernel_matches_unfused(solver, s, r, bs, k):
    """Fused reduce → invert → apply vs the unfused cholesky chain, both
    solvers, including S=1 and R=1 degenerate stacks and off-lane block
    sizes."""
    ka, kt, kw = jax.random.split(jax.random.PRNGKey(40), 3)
    m = jax.random.normal(ka, (s, r, bs, bs))
    a = jnp.einsum("srij,srkj->srik", m, m) / bs + 0.1 * jnp.eye(bs)
    t = jax.random.normal(kt, (s, r, bs, k))
    w = jax.nn.softmax(jax.random.normal(kw, (s,)))
    got = mix_ops.mix_precond(a, t, w, damping=0.1, solver=solver)
    want = mix_ref(a, t, w, damping=0.1, method="cholesky")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_mix_kernel_bf16_inputs_fp32_out():
    s, r, bs, k = 2, 3, 32, 8
    ka, kt = jax.random.split(jax.random.PRNGKey(41))
    m = jax.random.normal(ka, (s, r, bs, bs))
    a32 = jnp.einsum("srij,srkj->srik", m, m) / bs + 0.2 * jnp.eye(bs)
    a = a32.astype(jnp.bfloat16)
    t = jax.random.normal(kt, (s, r, bs, k), dtype=jnp.bfloat16)
    w = jnp.full((s,), 1.0 / s)
    got = mix_ops.mix_precond(a, t, w, damping=0.1, solver="ns")
    assert got.dtype == jnp.float32
    want = mix_ref(a, t, w, damping=0.1, method="cholesky")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_mix_kernel_weights_matter():
    """A one-hot weight vector must reproduce that single client's solve
    (sanity that the kernel actually consumes w)."""
    s, r, bs, k = 3, 2, 16, 4
    ka, kt = jax.random.split(jax.random.PRNGKey(42))
    m = jax.random.normal(ka, (s, r, bs, bs))
    a = jnp.einsum("srij,srkj->srik", m, m) / bs + 0.1 * jnp.eye(bs)
    t = jax.random.normal(kt, (s, r, bs, k))
    w = jnp.array([0.0, 1.0, 0.0])
    got = mix_ops.mix_precond(a, t, w, damping=0.1, solver="ns")
    want = chol_solve_ref(a[1], (a[1] + 0.1 * jnp.eye(bs)) @ t[1],
                          damping=0.1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_gram_kernel_batched_leading_dims():
    """gram() over [..., T, d] builds the whole bank in one call."""
    x = jax.random.normal(jax.random.PRNGKey(12), (3, 2, 128, 64))
    got = gram_ops.gram(x, 32, damping=0.01, use_pallas=True)
    assert got.shape == (3, 2, 2, 32, 32)
    for i in range(3):
        for j in range(2):
            want = gram_blocks_ref(x[i, j], 32, damping=0.01)
            np.testing.assert_allclose(np.asarray(got[i, j]),
                                       np.asarray(want), rtol=1e-5, atol=1e-5)

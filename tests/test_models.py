"""Per-architecture smoke tests (spec §f): every assigned arch instantiates
a REDUCED variant (≤2 scan units, d_model ≤ 128, ≤4 experts) and runs one
forward + one fused-K1 FedPM train step on CPU, asserting shapes + finite."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.core.algorithms import HParams
from repro.fl import distributed as D
from repro.models import transformer as T

B, S = 2, 64


def make_batch(cfg, rng):
    if cfg.frontend == "audio_stub":
        return {"embeds": jax.random.normal(rng, (B, S, cfg.d_model),
                                            dtype=jnp.float32),
                "labels": jax.random.randint(rng, (B, S, cfg.num_codebooks),
                                             0, cfg.vocab_size)}
    if cfg.frontend == "vision_stub":
        p = cfg.frontend_tokens
        return {"tokens": jax.random.randint(rng, (B, S - p), 0,
                                             cfg.vocab_size),
                "patches": jax.random.normal(rng, (B, p, cfg.d_model),
                                             dtype=jnp.float32),
                "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
                "loss_mask": jnp.concatenate(
                    [jnp.zeros((B, p)), jnp.ones((B, S - p))], axis=1)}
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, rng)
    batch = make_batch(cfg, rng)
    x, grams, _, _ = T.forward(cfg, params, batch, collect_foof=True)
    assert x.shape == (B, S, cfg.d_model)
    assert jnp.all(jnp.isfinite(x))
    # gram tree mirrors params structure
    assert jax.tree.structure(grams) == jax.tree.structure(
        jax.tree.map(lambda _: 0, params))

    step = jax.jit(D.make_fused_k1_step(cfg, HParams(lr=0.1, damping=1.0)))
    p2, m = step(params, batch)
    assert jnp.isfinite(m["loss"])
    for leaf in jax.tree.leaves(p2):
        assert jnp.all(jnp.isfinite(leaf))
    # params actually moved
    moved = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert moved > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_decode_step(arch, rng):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, rng)
    cache = T.init_cache(cfg, B, S)
    if cfg.frontend == "audio_stub":
        batch = {"embeds": jax.random.normal(rng, (B, 1, cfg.d_model),
                                             dtype=jnp.float32)}
    else:
        batch = {"tokens": jax.random.randint(rng, (B, 1), 0,
                                              cfg.vocab_size)}
    logits, cache2 = jax.jit(T.decode_step, static_argnums=0)(
        cfg, params, cache, batch, jnp.int32(5))
    nq = max(cfg.num_codebooks, 1)
    assert logits.shape == (B, 1, cfg.vocab_size * nq)
    assert jnp.all(jnp.isfinite(logits))
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


# NOTE: MoE archs (deepseek, qwen3) are excluded: capacity-based routing is
# not teacher-forcing-consistent by construction (a token's expert slot
# depends on the other tokens in the batch).  The MLA attention layer itself
# is verified exactly in test_mla_decode_consistency below.
@pytest.mark.parametrize("arch", ["olmo-1b", "gemma3-12b", "mamba2-1.3b",
                                  "zamba2-7b", "command-r-35b"])
def test_prefill_then_decode_matches_forward(arch, rng):
    """Teacher-forcing consistency: hidden state for position t computed by
    (prefill up to t) + (decode of token t) must match the full forward."""
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, rng)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    # full forward logits at last position
    x_full, _, _, _ = T.forward(cfg, params, {"tokens": toks},
                                want_cache=False)
    logits_full = (x_full[:, -1:] @ params["head"]["w"]).astype(jnp.float32)
    # prefill on S-1 tokens, then decode token S-1
    _, cache = T.prefill(cfg, params, {"tokens": toks[:, :S - 1]})
    cache = _pad_cache(cfg, cache, S)
    logits_dec, _ = T.decode_step(cfg, params, cache,
                                  {"tokens": toks[:, S - 1:]},
                                  jnp.int32(S - 1))
    import numpy as np
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full),
                               rtol=2e-2, atol=2e-2)


def _pad_cache(cfg, cache, target):
    """prefill(S-1) produced caches sized S-1; pad seq dims to target."""
    def pad(leaf):
        # KV caches: [..., seq, hd] or latent [..., seq, r]
        for axis in range(leaf.ndim):
            if leaf.shape[axis] == target - 1:
                pads = [(0, 0)] * leaf.ndim
                pads[axis] = (0, 1)
                return jnp.pad(leaf, pads)
        return leaf
    return jax.tree.map(pad, cache)


def test_mla_decode_consistency(rng):
    """Absorbed MLA decode (latent-space attention, DESIGN §5) must match
    the direct training-path MLA exactly."""
    from repro.models import layers as L
    cfg = get_config("deepseek-v2-236b", reduced=True)
    p = L.init_mla(cfg, rng)
    bsz, s = 2, 16
    x = jax.random.normal(rng, (bsz, s, cfg.d_model), dtype=jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (bsz, s))
    out_full, _, (ckv, krope) = L.mla_forward(cfg, p, x, pos)
    _, _, (ckv_p, krope_p) = L.mla_forward(cfg, p, x[:, :s - 1],
                                           pos[:, :s - 1])
    ckv_c = jnp.pad(ckv_p, ((0, 0), (0, 1), (0, 0)))
    kr_c = jnp.pad(krope_p, ((0, 0), (0, 1), (0, 0)))
    out_dec, ckv2, kr2 = L.mla_decode(cfg, p, x[:, s - 1:], s - 1,
                                      ckv_c, kr_c)
    import numpy as np
    np.testing.assert_allclose(np.asarray(ckv2), np.asarray(ckv), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                               np.asarray(out_full[:, -1]),
                               rtol=1e-4, atol=1e-5)

"""Algebraic invariants of FedPM + convergence-class behavior of the zoo
(paper Theorem 1, Eq. 6/7/9, Table 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import ALGORITHMS, HParams
from repro.data import make_libsvm_like, FederatedDataset
from repro.fl.simulate import FedSim
from repro.fl.tasks import ConvexTask
from repro.models.simple import LogisticModel


@pytest.fixture(scope="module")
def convex_setup():
    data = make_libsvm_like("a9a", seed=0)
    ds = FederatedDataset.from_arrays(data, 16, alpha=0.0, seed=0,
                                      test_frac=0.1)
    d = data["x"].shape[1]
    model = LogisticModel(d=d, lam=1e-3)
    task = ConvexTask(model)
    batches = ds.client_full_batches(k_steps=1)
    ux = np.asarray(batches["x"][:, 0]).reshape(-1, d)
    uy = np.asarray(batches["y"][:, 0]).reshape(-1)
    full = {"x": jnp.asarray(ux), "y": jnp.asarray(uy)}
    theta = jnp.zeros(d)
    for _ in range(25):
        theta = theta - jnp.linalg.solve(model.hessian(theta, full),
                                         model.grad(theta, full))
    return dict(ds=ds, model=model, task=task, batches=batches,
                theta_star=theta, d=d)


def _run(setup, algo, hp, rounds=6, init_scale=0.1):
    task, ds = setup["task"], setup["ds"]
    sim = FedSim(task, algo, hp, ds.n_clients)
    rng = jax.random.PRNGKey(0)
    st = sim.init(rng)
    st.params = setup["theta_star"] + init_scale * jax.random.normal(
        rng, (setup["d"],))
    errs = []
    for t in range(rounds):
        st, _ = sim.round(st, setup["batches"], jax.random.PRNGKey(t))
        errs.append(float(jnp.linalg.norm(st.params - setup["theta_star"])))
    return errs, st.params


def test_fedpm_k1_equals_fednl(convex_setup):
    """Eq. 9 with K=1 IS the ideal global second-order step (Eq. 6) — the
    paper's central algebraic identity."""
    hp = HParams(lr=1.0, damping=0.0)
    e_pm, p_pm = _run(convex_setup, "fedpm", hp, rounds=3)
    e_nl, p_nl = _run(convex_setup, "fednl", hp, rounds=3)
    np.testing.assert_allclose(np.asarray(p_pm), np.asarray(p_nl),
                               rtol=2e-4, atol=2e-5)


def test_fedpm_superlinear(convex_setup):
    """Theorem 1: the per-round contraction factor itself shrinks."""
    errs, _ = _run(convex_setup, "fedpm", HParams(lr=1.0, damping=0.0),
                   rounds=4)
    r1 = errs[1] / errs[0]
    r0 = errs[0] / 1.1   # ≈ init error
    assert errs[1] < 1e-2
    assert r1 < r0, (errs, r0, r1)


def test_sopm_simple_mixing_plateaus_above_fedpm(convex_setup):
    """LocalNewton's locally-preconditioned mixing (Eq. 7) converges to a
    biased point; FedPM does not (paper Sec 2.2 analysis)."""
    e_ln, _ = _run(convex_setup, "localnewton", HParams(lr=1.0, damping=0.0),
                   rounds=6)
    e_pm, _ = _run(convex_setup, "fedpm", HParams(lr=1.0, damping=0.0),
                   rounds=6)
    assert e_pm[-1] < e_ln[-1] / 50


def test_first_order_methods_converge_slowly(convex_setup):
    for algo in ("psgd", "fedavg", "fedavgm", "scaffold", "fedadam"):
        errs, _ = _run(convex_setup, algo, HParams(lr=0.3), rounds=4)
        assert np.isfinite(errs).all(), algo
        assert errs[-1] < 1.6, (algo, errs)          # no divergence
        assert errs[-1] > 1e-3, (algo, errs)         # but not superlinear


def test_fedns_matches_newton_rate(convex_setup):
    errs, _ = _run(convex_setup, "fedns", HParams(lr=1.0, damping=1e-3),
                   rounds=5)
    assert errs[-1] < 1e-4, errs


def test_client_sampling_mask(convex_setup):
    """Server aggregation with a mask == aggregation of the subset."""
    task, ds = convex_setup["task"], convex_setup["ds"]
    hp = HParams(lr=1.0, damping=0.0)
    sim = FedSim(task, "fedpm", hp, ds.n_clients)
    rng = jax.random.PRNGKey(0)
    st = sim.init(rng)
    st.params = convex_setup["theta_star"] + 0.05
    mask = jnp.zeros((ds.n_clients,)).at[jnp.arange(8)].set(1.0)
    params0 = jax.tree.map(jnp.copy, st.params)   # round() donates st
    st2, _ = sim.round(st, convex_setup["batches"], rng, mask)
    # manual: run the algorithm on only the first 8 clients
    sub = FedSim(task, "fedpm", hp, 8)
    sts = sub.init(rng)
    sts.params = params0
    sub_batches = jax.tree.map(lambda x: x[:8], convex_setup["batches"])
    st3, _ = sub.round(sts, sub_batches, rng)
    np.testing.assert_allclose(np.asarray(st2.params), np.asarray(st3.params),
                               rtol=1e-4, atol=1e-5)


def test_all_algorithms_run_one_round(convex_setup):
    for name in ALGORITHMS:
        if ALGORITHMS[name].needs_grams:
            continue  # foof variants covered in test_foof.py on DNN task
        errs, _ = _run(convex_setup, name,
                       HParams(lr=0.1, damping=1e-2), rounds=1)
        assert np.isfinite(errs).all(), name

"""Degraded hypothesis fallback so the suite collects without the dep.

When ``hypothesis`` is installed (see requirements-dev.txt) this module
re-exports it untouched.  When it is missing, ``@given`` runs the test
body over the cartesian product of two deterministic examples per
strategy (the endpoints) — a fixed smoke sweep instead of a randomized
property search, keeping tier-1 green in minimal environments.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import itertools

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(dict.fromkeys(examples))   # unique, ordered

    class _Strategies:
        @staticmethod
        def sampled_from(xs):
            xs = list(xs)
            return _Strategy([xs[0], xs[-1]])

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy([min_value, max_value])

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy([min_value, max_value])

        @staticmethod
        def booleans():
            return _Strategy([False, True])

    st = _Strategies()

    def settings(*_a, **_kw):
        def deco(fn):
            return fn
        return deco

    def given(**strats):
        keys = list(strats)

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for combo in itertools.product(
                        *(strats[k].examples for k in keys)):
                    fn(*args, **kwargs, **dict(zip(keys, combo)))
            # hide the strategy params so pytest doesn't see them as fixtures
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in strats])
            return wrapper
        return deco

"""DeviceDataBank ragged-sampling edge cases (ISSUE 6 satellite):

FEMNIST-class partitions are RAGGED — shard sizes differ by orders of
magnitude, down to a single example.  The bank pads every client to the
max shard length M, so the failure mode to guard is a draw indexing PAST
a client's true shard size into the (cyclic) padding of a neighbor's
content.  Features here encode the owning sample id, so any cross-shard
leak is detected exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import FederatedDataset


def _ragged_ds():
    """9 samples; shards of size 1 / 3 / 5 — x[i] == i marks ownership."""
    x = np.arange(9, dtype=np.float32)[:, None]
    y = np.arange(9, dtype=np.int32)
    shards = [np.array([0]), np.array([1, 2, 3]),
              np.array([4, 5, 6, 7, 8])]
    return FederatedDataset(x=x, y=y, shards=shards)


def _owners(ds):
    return [set(np.asarray(s).tolist()) for s in ds.shards]


def test_batch_larger_than_smallest_shard_never_leaks():
    """batch · steps ≫ the smallest shard: draws repeat WITHIN the true
    shard (replacement), never reading the cyclic padding rows."""
    ds = _ragged_ds()
    bank = ds.device_bank(steps=2, batch=4)          # need 8 > min size 1
    assert bank.spec.min_size == 1
    out = bank.sample(jax.random.PRNGKey(0), jnp.arange(3))
    ids = np.asarray(out["x"]).reshape(3, -1).astype(np.int64)
    labels = np.asarray(out["y"]).reshape(3, -1)
    np.testing.assert_array_equal(ids, labels)       # x/y rows stay paired
    for c, owned in enumerate(_owners(ds)):
        assert set(ids[c].tolist()) <= owned, f"client {c} leaked"
    # the single-example client sees its one sample, every draw
    np.testing.assert_array_equal(ids[0], 0)


def test_single_example_shard_with_many_participants():
    ds = _ragged_ds()
    bank = ds.device_bank(steps=3, batch=2)
    # different rng keys must still never escape a 1-element shard
    for seed in range(4):
        out = bank.sample(jax.random.PRNGKey(seed),
                          jnp.zeros((2,), jnp.int32))  # client 0 twice
        np.testing.assert_array_equal(np.asarray(out["x"]), 0.0)


def test_batch_zero_full_shard_mode():
    """batch == 0: every step sees the client's FIRST min_size samples —
    deterministic, rng-free, and bounded by the smallest true shard (so
    no client reads padding)."""
    ds = _ragged_ds()
    bank = ds.device_bank(steps=2, batch=0)
    out = bank.sample(jax.random.PRNGKey(0), jnp.arange(3))
    assert out["x"].shape == (3, 2, 1, 1)            # [S, steps, min_size, 1]
    first = {0: 0, 1: 1, 2: 4}                       # each shard's first id
    for c in range(3):
        np.testing.assert_array_equal(np.asarray(out["x"])[c],
                                      float(first[c]))
    # rng-free: a different key draws the identical batches
    out2 = bank.sample(jax.random.PRNGKey(7), jnp.arange(3))
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.asarray(out2["x"]))


def test_paged_staged_view_matches_resident_on_ragged():
    """Staging ragged clients preserves true sizes AND padding layout, so
    staged draws equal resident draws bitwise at the same key."""
    ds = _ragged_ds()
    res = ds.device_bank(steps=2, batch=4)
    pag = ds.paged_bank(steps=2, batch=4)
    rows = np.array([0, 2])
    staged = pag.gather(rows)
    np.testing.assert_array_equal(np.asarray(staged.sizes), [1, 5])
    key = jax.random.PRNGKey(3)
    want = res.sample(key, jnp.asarray(rows))
    got = staged.sample(key, jnp.arange(2))
    for k in ("x", "y"):
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]))


def test_sampling_distribution_covers_whole_shard():
    """Draws below the true size are uniform over the WHOLE shard — a
    clamp-style bug (always row 0) or an off-by-one (size-1 cap) would
    miss ids."""
    ds = _ragged_ds()
    bank = ds.device_bank(steps=4, batch=8)
    seen = set()
    for seed in range(8):
        out = bank.sample(jax.random.PRNGKey(seed),
                          jnp.full((1,), 2, jnp.int32))
        seen |= set(np.asarray(out["x"]).reshape(-1).astype(int).tolist())
    assert seen == {4, 5, 6, 7, 8}

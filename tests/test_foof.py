"""FOOF preconditioning + preconditioned mixing properties (Eq. 11/12),
including hypothesis property tests on the mixing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core import foof as F
from repro.core.inverse import solve
from repro.data import make_clustered_classification, FederatedDataset
from repro.data.federated import build_round_batches
from repro.core.algorithms import HParams
from repro.fl.simulate import FedSim
from repro.fl.tasks import DNNTask
from repro.models.simple import MLPModel


def _rand_spd(rng, nb, bs):
    m = jax.random.normal(rng, (nb, bs, bs))
    return jnp.einsum("nij,nkj->nik", m, m) / bs + 0.05 * jnp.eye(bs)


# ------------------------------------------------------------ properties ---

@settings(max_examples=15, deadline=None)
@given(bs=st.sampled_from([4, 8, 16]), n=st.integers(2, 6),
       damping=st.sampled_from([1e-4, 1e-2, 1.0]), seed=st.integers(0, 999))
def test_mixing_identity_property(bs, n, damping, seed):
    """Preconditioned mixing of IDENTICAL params is the identity, for any
    SPD grams and any damping (δ applied to both sides of Eq. 12)."""
    rng = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(rng)
    theta = jax.random.normal(k1, (bs * 2, 3))
    grams = jax.vmap(lambda k: _rand_spd(k, 2, bs))(jax.random.split(k2, n))
    stack = {"w": jnp.broadcast_to(theta, (n, *theta.shape))}
    mixed = F.mix_preconditioned(stack, {"w": grams}, damping=damping)
    np.testing.assert_allclose(np.asarray(mixed["w"]), np.asarray(theta),
                               rtol=2e-3, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999))
def test_mixing_weights_uniform_equals_default(seed):
    rng = jax.random.PRNGKey(seed)
    n, bs = 4, 8
    thetas = jax.random.normal(rng, (n, bs, 5))
    grams = jax.vmap(lambda k: _rand_spd(k, 1, bs))(jax.random.split(rng, n))
    a = F.mix_preconditioned({"w": thetas}, {"w": grams}, damping=0.1)
    b = F.mix_preconditioned({"w": thetas}, {"w": grams}, damping=0.1,
                             weights=jnp.ones((n,)))
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]),
                               rtol=1e-5, atol=1e-6)


def test_mixing_recovers_ideal_newton_combination():
    """Eq. 8: mixing clients' one-step-Newton params with their Hessians as
    grams equals the globally preconditioned global step."""
    rng = jax.random.PRNGKey(0)
    n, bs = 5, 12
    theta0 = jax.random.normal(rng, (bs, 1))
    grams = jax.vmap(lambda k: _rand_spd(k, 1, bs))(jax.random.split(rng, n))
    gs = jax.random.normal(jax.random.PRNGKey(1), (n, bs, 1))
    eta = 0.7
    # client updates: θ_i = θ0 − η P_i⁻¹ g_i
    thetas = jax.vmap(lambda a, g: theta0 - eta * solve(a[0], g))(grams, gs)
    mixed = F.mix_preconditioned({"w": thetas}, {"w": grams}, damping=0.0)
    pbar = jnp.mean(grams[:, 0], axis=0)
    gbar = jnp.mean(gs, axis=0)
    expected = theta0 - eta * solve(pbar, gbar)
    np.testing.assert_allclose(np.asarray(mixed["w"]), np.asarray(expected),
                               rtol=2e-3, atol=2e-4)


def test_precondition_tree_matches_direct_solve():
    rng = jax.random.PRNGKey(0)
    bs, dout = 16, 7
    a = _rand_spd(rng, 2, bs)
    g = jax.random.normal(rng, (2 * bs, dout))
    params = {"wqkv": jnp.zeros((2 * bs, dout))}
    out = F.precondition_tree(params, {"wqkv": g}, {"wqkv": a}, damping=0.1)
    gb = g.reshape(2, bs, dout)
    expected = jnp.stack([solve(a[i], gb[i], 0.1) for i in range(2)])
    np.testing.assert_allclose(np.asarray(out["wqkv"]),
                               np.asarray(expected.reshape(2 * bs, dout)),
                               rtol=1e-4, atol=1e-5)


def test_gram_routing_moe_and_diag_embed():
    rng = jax.random.PRNGKey(0)
    bs = 8
    a = _rand_spd(rng, 1, bs)
    params = {"moe": {"router": jnp.zeros((bs, 4)),
                      "wi": jnp.zeros((3, bs, 5))},       # expert axis
              "embed": {"w": jnp.zeros((11, 6))}}
    grads = jax.tree.map(lambda p: jnp.ones_like(p), params)
    counts = jnp.arange(11, dtype=jnp.float32) / 11
    grams = {"moe": {"router": a, "wi": jnp.zeros((0,))},
             "embed": {"w": counts}}
    out = F.precondition_tree(params, grads, grams, damping=0.1)
    # router and experts both preconditioned by the router gram
    direct = solve(a[0], jnp.ones((bs, 4)), 0.1)
    np.testing.assert_allclose(np.asarray(out["moe"]["router"]),
                               np.asarray(direct), rtol=1e-4, atol=1e-5)
    exp_direct = solve(a[0], jnp.ones((bs, 5)), 0.1)
    for e in range(3):
        np.testing.assert_allclose(np.asarray(out["moe"]["wi"][e]),
                                   np.asarray(exp_direct), rtol=1e-4,
                                   atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(out["embed"]["w"]),
        np.broadcast_to(np.asarray(1.0 / (counts[:, None] + 0.1)), (11, 6)),
        rtol=1e-5)


def test_ns_inverse_matches_cholesky_path():
    rng = jax.random.PRNGKey(3)
    a = _rand_spd(rng, 3, 32)
    b = jax.random.normal(rng, (3, 32, 4))
    x_ns = solve(a, b, 0.05, method="ns", ns_iters=30)
    x_ch = solve(a, b, 0.05, method="cholesky")
    np.testing.assert_allclose(np.asarray(x_ns), np.asarray(x_ch),
                               rtol=5e-3, atol=5e-4)


# --------------------------------------------------------------- DNN FL ----

def test_fedpm_foof_beats_fedavg_early(nprng):
    """Paper Fig. 2 class claim: faster convergence under α=0.1."""
    data = make_clustered_classification(3000, 32, 10, seed=0, spread=2.0)
    ds = FederatedDataset.from_arrays(data, 8, alpha=0.1, seed=0)
    model = MLPModel(in_dim=32, hidden=(64,), num_classes=10)
    task = DNNTask(model)
    test = ds.test_batch()
    rng = jax.random.PRNGKey(1)

    def run(algo, hp, rounds=6):
        sim = FedSim(task, algo, hp, 8)
        st = sim.init(rng)
        import numpy as _np
        r = _np.random.default_rng(0)
        accs = []
        for t in range(rounds):
            batches = build_round_batches(ds, 8, 64, r)
            st, _ = sim.round(st, batches, jax.random.PRNGKey(t))
            accs.append(float(task.metric(st.params, test)))
        return accs

    acc_pm = run("fedpm_foof", HParams(lr=0.3, damping=1.0))
    acc_avg = run("fedavg", HParams(lr=0.1))
    assert acc_pm[2] > acc_avg[2], (acc_pm, acc_avg)
    assert max(acc_pm) > 0.8


def test_cnn_foof_learns_images(nprng):
    """The paper's 'simple CNN' (conv-as-matmul with exact patch-gram FOOF)
    trains under FedPM on image data — covers the conv gram path."""
    from repro.data import make_image_classification
    from repro.models.simple import CNNModel
    data = make_image_classification(1200, 16, 1, 8, seed=0, noise=0.4)
    ds = FederatedDataset.from_arrays(data, 6, alpha=0.5, seed=0)
    model = CNNModel(in_hw=16, in_ch=1, num_classes=8, foof_block=128)
    task = DNNTask(model)
    sim = FedSim(task, "fedpm_foof",
                 HParams(lr=1.0, damping=1.0, clip=1.0), 6)
    st = sim.init(jax.random.PRNGKey(0))
    test = ds.test_batch()
    import numpy as _np
    r = _np.random.default_rng(0)
    accs = []
    for t in range(6):
        batches = build_round_batches(ds, 5, 32, r)
        st, _ = sim.round(st, batches, jax.random.PRNGKey(t))
        accs.append(float(task.metric(st.params, test)))
    assert max(accs) > 0.5, accs

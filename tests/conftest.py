import os
import sys

# tests must see 1 device (the dry-run sets 512 in its own process only)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def nprng():
    return np.random.default_rng(0)

"""Mesh-sharded engine contract tests (separate process with 8 fake host
devices — device count is locked at jax init, so this runs as a
subprocess, like tests/test_moe_shardmap.py).

Contracts (ISSUE 3 / ROADMAP "Sharded client banks"):

* sharded round ≡ vmap-oracle round to fp32 mixing tolerance, for a
  stateful FOPM method (SCAFFOLD) and the preconditioned-mixing SOPM
  method (FedPM, full-Hessian and FOOF backends), sampled AND full
  cohorts;
* sampled-out clients on remote shards are provably (bitwise) untouched;
* the jit cache keys once per cohort size S, not per random cohort;
* the client bank lives sharded: every device holds N/8 rows;
* pre-gathered [S] participant batches take the same round as the [N]
  bank (the data path that scales with S).
"""
import os
import subprocess
import sys

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.core.algorithms import HParams
from repro.data import (FederatedDataset, make_clustered_classification,
                        make_libsvm_like)
from repro.data.federated import build_round_batches
from repro.fl.simulate import FedSim
from repro.fl.sharded import bank_shard_rows, make_client_mesh
from repro.fl.tasks import ConvexTask, DNNTask
from repro.models.simple import LogisticModel, MLPModel

N = 16
assert jax.device_count() == 8
mesh = make_client_mesh()

def maxerr(a, b):
    return max([float(jnp.max(jnp.abs(jnp.asarray(x, jnp.float32)
                                      - jnp.asarray(y, jnp.float32))))
                for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))],
               default=0.0)

data = make_libsvm_like("a9a", seed=0)
ds = FederatedDataset.from_arrays(data, N, alpha=0.0, seed=0, test_frac=0.1)
convex_task = ConvexTask(LogisticModel(d=data["x"].shape[1], lam=1e-3))
convex_batches = ds.client_full_batches(k_steps=1)

dnn_data = make_clustered_classification(1600, 16, 4, seed=0)
dnn_ds = FederatedDataset.from_arrays(dnn_data, N, alpha=0.5, seed=0)
dnn_task = DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4))
dnn_batches = build_round_batches(dnn_ds, 2, 16, np.random.default_rng(0))

SETUPS = {
    "scaffold": (convex_task, convex_batches, HParams(lr=0.3)),
    "fedpm": (convex_task, convex_batches, HParams(lr=1.0, damping=1e-2)),
    "fedpm_foof": (dnn_task, dnn_batches, HParams(lr=0.3, damping=1.0)),
}

# ---------------- sharded ≡ vmap oracle (sampled + full cohorts) ----------
participants = np.array([1, 4, 6, 11, 13])
for algo, (task, batches, hp) in SETUPS.items():
    ref, sh = (FedSim(task, algo, hp, N),
               FedSim(task, algo, hp, N, mesh=mesh))
    st_r, st_s = ref.init(jax.random.PRNGKey(0)), sh.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(7)
    a, _ = ref.round(st_r, batches, rng, participants=participants)
    b, _ = sh.round(st_s, batches, rng, participants=participants)
    assert maxerr(a.params, b.params) < 2e-4, algo
    assert maxerr(a.server, b.server) < 2e-4, algo
    assert maxerr(a.clients, b.clients) < 2e-4, algo
    a2, _ = ref.round(a, batches, rng)                  # full cohort
    b2, _ = sh.round(b, batches, rng)
    assert maxerr(a2.params, b2.params) < 4e-4, algo
print("EQUIV-OK")

# ---------------- per-device bank memory: N/8 rows each -------------------
sim = FedSim(convex_task, "scaffold", HParams(lr=0.3), N, mesh=mesh)
st = sim.init(jax.random.PRNGKey(0))
rows = bank_shard_rows(st.clients)
assert len(rows) == 8 and all(r[0] == N // 8 for r in rows), rows
print("SHARD-OK")

# ------------- sampled-out clients on remote shards bit-untouched ---------
# participants live on shards 0 and 2 only; every other shard's states
# (and the non-participant slots of shards 0/2) must be bit-identical
part = np.array([0, 4, 5])          # shard 0: local 0; shard 2: locals 0, 1
out = np.setdiff1d(np.arange(N), part)
before = np.asarray(st.clients)
st1, _ = sim.round(st, convex_batches, jax.random.PRNGKey(1),
                   participants=part)
after = np.asarray(st1.clients)
np.testing.assert_array_equal(after[out], before[out])
assert np.abs(after[part] - before[part]).max() > 0      # participants moved
print("UNTOUCHED-OK")

# ------------------- jit cache keyed once per cohort size -----------------
f = sim._sharded_round_jit
n0 = f._cache_size()
rng2 = np.random.default_rng(1)
for t in range(3):                                # same S, different cohorts
    chosen = np.sort(rng2.choice(N, size=3, replace=False))
    st1, _ = sim.round(st1, convex_batches, jax.random.PRNGKey(t),
                       participants=chosen)
assert f._cache_size() == n0, (f._cache_size(), n0)
st1, _ = sim.round(st1, convex_batches, jax.random.PRNGKey(9),
                   participants=np.arange(8))     # new S → one new program
assert f._cache_size() == n0 + 1
print("CACHE-OK")

# ----------- pre-gathered [S] batches ≡ [N] bank (sharded path) -----------
sh = FedSim(dnn_task, "fedpm_foof", HParams(lr=0.3, damping=1.0), N,
            mesh=mesh)
st = sh.init(jax.random.PRNGKey(0))
rng = jax.random.PRNGKey(3)
# rounds donate their input state — copy to round twice from one state
full, _ = sh.round(st.copy(), dnn_batches, rng, participants=participants)
sub = jax.tree.map(lambda x: x[participants], dnn_batches)
pre, _ = sh.round(st, sub, rng, participants=participants)
assert maxerr(full.params, pre.params) == 0.0
print("PREGATHER-OK")

# -------- weighted axes= mixing: packed ≡ per-leaf oracle under psum ------
from jax.sharding import PartitionSpec as P
from repro.core import foof as F
from repro.distributed.axes import shard_map, use_mesh

cap, nb, bs, dout, v = 2, 2, 8, 5, 11
k = jax.random.PRNGKey(0)
m = jax.random.normal(k, (8 * cap, nb, bs, bs))
grams = {"w": jnp.einsum("snij,snkj->snik", m, m) / bs + 0.05 * jnp.eye(bs),
         "embed": {"w": jax.random.uniform(jax.random.PRNGKey(1),
                                           (8 * cap, v)) + 0.1}}
params = {"w": jax.random.normal(k, (8 * cap, nb * bs, dout)),
          "embed": {"w": jax.random.normal(k, (8 * cap, v, 3))}}
w = jax.random.uniform(jax.random.PRNGKey(2), (8 * cap,))  # incl. ~0 weights

def mix(packed):
    def island(p, g, wl):
        return F.mix_preconditioned(p, g, damping=0.1, weights=wl,
                                    packed=packed, axes=("clients",))
    with use_mesh(mesh):
        return shard_map(island, mesh=mesh,
                         in_specs=(P("clients"), P("clients"), P("clients")),
                         out_specs=P(), axis_names={"clients"},
                         check=False)(params, grams, w)

got, ref = mix(True), mix(False)
stacked = F.mix_preconditioned(params, grams, damping=0.1, weights=w)
assert maxerr(got, ref) < 2e-4
assert maxerr(got, stacked) < 2e-4
print("MIXAXES-OK")
print("OK")
'''


def test_sharded_engine_contracts():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         capture_output=True, text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("EQUIV-OK", "SHARD-OK", "UNTOUCHED-OK", "CACHE-OK",
                   "PREGATHER-OK", "MIXAXES-OK"):
        assert marker in res.stdout, (marker, res.stdout)

"""End-to-end system behaviour: cross-engine equivalence, checkpoints,
partitioner properties, HLO analyzer exactness, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs import get_config
from repro.core.algorithms import HParams
from repro.data import (FederatedDataset, make_clustered_classification,
                        make_libsvm_like, make_lm_tokens)
from repro.data.federated import build_round_batches
from repro.distributed.axes import make_auto_mesh, use_mesh
from repro.distributed.hlo_analysis import analyze_hlo
from repro.fl import distributed as D
from repro.fl.partition import client_label_histogram, dirichlet_partition
from repro.fl.simulate import FedSim
from repro.fl.tasks import DNNTask
from repro.models import transformer as T
from repro.models.simple import MLPModel


def test_cross_engine_equivalence_single_client():
    """The distributed local_steps round with one client must equal the
    simulate engine's fedpm_foof client + mixing (N=1 mixing = identity
    recovery of the same θ) — two independent code paths, same math."""
    cfg = get_config("olmo-1b", reduced=True)
    hp = HParams(lr=0.1, damping=1.0, foof_timing="start")
    rng = jax.random.PRNGKey(0)
    params = T.init_params(cfg, rng)
    k, b, s = 2, 4, 64
    toks = jax.random.randint(rng, (k * b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    mesh = make_auto_mesh((1, 1), ("data", "model"))
    rnd = D.make_local_steps_round(cfg, hp, mesh, k_steps=k)
    with use_mesh(mesh):
        p_dist, _ = jax.jit(rnd)(params, batch)

    # manual: K foof steps with grams at theta0, then N=1 mixing == theta
    from repro.core import foof as F
    from repro.utils import tree_axpy
    local = jax.tree.map(lambda x: x.reshape(k, b, *x.shape[1:]), batch)
    first = jax.tree.map(lambda x: x[0], local)
    grams0 = T.loss_fn(cfg, params, first, collect_foof=True)[1]["grams"]
    theta = params
    for i in range(k):
        mb = jax.tree.map(lambda x: x[i], local)
        g = jax.grad(lambda p: T.loss_fn(cfg, p, mb)[0])(theta)
        pre = F.precondition_tree(theta, g, grams0, damping=hp.damping)
        theta = tree_axpy(-hp.lr, pre, theta)
    for a, bb in zip(jax.tree.leaves(p_dist), jax.tree.leaves(theta)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=2e-3, atol=2e-3)


def test_fused_k1_reduces_loss():
    cfg = get_config("mamba2-1.3b", reduced=True)
    hp = HParams(lr=0.2, damping=1.0)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(cfg, rng)
    toks = jnp.asarray(make_lm_tokens(cfg.vocab_size, 4 * 64,
                                      seed=0)).reshape(4, 64)
    batch = {"tokens": toks, "labels": toks}
    step = jax.jit(D.make_fused_k1_step(cfg, hp))
    losses = []
    p = params
    for _ in range(8):
        p, m = step(p, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("gemma3-12b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, params, meta={"round": 7, "arch": cfg.name})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    restored = checkpoint.restore(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint.load_meta(path)["round"] == 7


def test_dirichlet_partition_properties(nprng):
    labels = nprng.integers(0, 10, size=5000)
    shards = dirichlet_partition(labels, 10, alpha=0.1, rng=nprng)
    assert all(len(s) >= 2 for s in shards)
    hist = client_label_histogram(labels, shards)
    # strong heterogeneity: most clients dominated by few classes
    frac_top2 = (np.sort(hist, axis=1)[:, -2:].sum(1) /
                 np.maximum(hist.sum(1), 1))
    shards_mild = dirichlet_partition(labels, 10, alpha=10.0, rng=nprng)
    hist_mild = client_label_histogram(labels, shards_mild)
    frac_top2_mild = (np.sort(hist_mild, axis=1)[:, -2:].sum(1) /
                      np.maximum(hist_mild.sum(1), 1))
    assert frac_top2.mean() > frac_top2_mild.mean() + 0.2


def test_round_batches_shapes(nprng):
    data = make_clustered_classification(1000, 16, 4, seed=0)
    ds = FederatedDataset.from_arrays(data, 5, alpha=0.5, seed=0)
    batches = build_round_batches(ds, steps=3, batch=8, rng=nprng)
    assert batches["x"].shape == (5, 3, 8, 16)
    assert batches["y"].shape == (5, 3, 8)


def test_hlo_analyzer_counts_scan_flops_exactly():
    m = 128
    f = jax.jit(lambda c0, xs: jax.lax.scan(
        lambda c, x: (c @ x, ()), c0, xs)[0])
    compiled = f.lower(jax.ShapeDtypeStruct((m, m), jnp.float32),
                       jax.ShapeDtypeStruct((6, m, m), jnp.float32)).compile()
    res = analyze_hlo(compiled.as_text(), 1)
    assert res["flops"] == pytest.approx(6 * 2 * m ** 3, rel=0.02)


def test_simulate_engine_sampling_runs():
    data = make_clustered_classification(800, 16, 4, seed=0)
    ds = FederatedDataset.from_arrays(data, 6, alpha=0.5, seed=0)
    model = MLPModel(in_dim=16, hidden=(32,), num_classes=4)
    task = DNNTask(model)
    sim = FedSim(task, "fedpm_foof", HParams(lr=0.3, damping=1.0), 6)
    test = ds.test_batch()
    _, hist = sim.run(jax.random.PRNGKey(0),
                      lambda t, k: build_round_batches(
                          ds, 3, 16, np.random.default_rng(t)),
                      rounds=4, sample_clients=3,
                      eval_fn=lambda p: task.metric(p, test))
    assert len(hist["metric"]) == 4
    assert np.isfinite(hist["metric"]).all()


def test_amortized_steps_match_fused_k1():
    """§Perf C4: refresh-every-step amortized FedPM ≡ the fused K1 step
    (same grams, same inverses, same update)."""
    from repro.core.algorithms import HParams as HP
    cfg = get_config("olmo-1b", reduced=True)
    hp = HP(lr=0.1, damping=1.0, inverse_method="cholesky")
    rng = jax.random.PRNGKey(0)
    params = T.init_params(cfg, rng)
    toks = jax.random.randint(rng, (4, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    fused = jax.jit(D.make_fused_k1_step(cfg, hp))
    refresh, steady = D.make_amortized_steps(cfg, hp)
    p1, _ = fused(params, batch)
    p2, inverses, _ = jax.jit(refresh)(params, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)
    # steady step with the cached inverses runs and reduces loss
    p3, m3 = jax.jit(steady)(p2, inverses, batch)
    assert np.isfinite(float(m3["loss"]))


def test_seq_parallel_numerically_neutral():
    """§Perf B3 is a sharding annotation — on one device outputs are
    bit-identical."""
    import dataclasses
    from repro.core.algorithms import HParams as HP
    cfg = get_config("olmo-1b", reduced=True)
    cfg_sp = dataclasses.replace(cfg, seq_parallel=True)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(cfg, rng)
    toks = jax.random.randint(rng, (2, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    l1, _ = T.loss_fn(cfg, params, batch)
    mesh = make_auto_mesh((1, 1), ("data", "model"))
    with use_mesh(mesh):
        l2, _ = jax.jit(lambda p: T.loss_fn(cfg_sp, p, batch))(params)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)

"""Cold-tier contract sweep: the DISK rung of the residency ladder.

Mirrors tests/test_store.py one tier further out (``repro.fl.coldstore``
+ ``repro.data.streaming``): mmap ≡ host-paged ≡ resident — BITWISE on
the vmap engine (the staged chunks are bytewise identical, so the
compiled programs are too), fp32 on the 8-device mesh subprocess leg —
stateless registrations page zero bytes from disk, the scatter-overlap
fence keeps consecutive chunks that share cohort rows exact, and cold
files never outlive their owner (``close()``/``with``/gc/interpreter
exit, including a failed ``run_scanned``).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import HParams
from repro.data import FederatedDataset, StreamingFederatedDataset, \
    bucket_boundaries, make_clustered_classification
from repro.data.streaming import StreamWriter
from repro.fl.coldstore import MmapPagedBank, MmapStateStore
from repro.fl.simulate import FedSim
from repro.fl.store import ClientStore, HostStateStore, device_bytes
from repro.fl.tasks import DNNTask
from repro.models.simple import MLPModel

N, R = 12, 5


@pytest.fixture(scope="module")
def ds():
    data = make_clustered_classification(1200, 16, 4, seed=0)
    return FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)


@pytest.fixture(scope="module")
def task(ds):
    return DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4))


@pytest.fixture(scope="module")
def sfd(ds, tmp_path_factory):
    """The module dataset spilled once to disk (persistent for the
    module: banks opened over it pass ``owned=False``)."""
    return StreamingFederatedDataset.from_dataset(
        ds, directory=str(tmp_path_factory.mktemp("streamfed")))


def _exact(a, b, tag):
    """Cold ≡ warm BITWISE: staged chunks are bytewise identical, so on
    one device the compiled programs — and their outputs — are too."""
    bank = lambda c: c.bank if isinstance(c, HostStateStore) else c
    for name, x, y in (("params", a.params, b.params),
                       ("server", a.server, b.server),
                       ("clients", bank(a.clients), bank(b.clients))):
        for u, v in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                          err_msg=f"{tag}:{name}")


# ------------------------------------------------- streaming dataset -------

def test_streaming_roundtrip(ds, sfd):
    idx, sizes = ds._padded_index()
    assert sfd.n_clients == N and sfd.n_samples == len(ds.x)
    for mm, want in ((sfd.x, ds.x), (sfd.y, ds.y),
                     (sfd.idx, idx.astype(np.int64)), (sfd.sizes, sizes)):
        assert isinstance(mm, np.memmap) and not mm.flags.writeable
        np.testing.assert_array_equal(np.asarray(mm), want)
    # reopen from the manifest alone
    again = StreamingFederatedDataset.open(sfd.directory)
    assert again.meta == sfd.meta
    np.testing.assert_array_equal(np.asarray(again.x), ds.x)


def test_stream_writer_blocks(tmp_path):
    """Block-at-a-time ingest lands bytewise what a whole-array spill
    lands, and the writer validates shapes and the index table."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((20, 3)).astype(np.float32)
    y = rng.integers(0, 4, 20).astype(np.int32)
    idx = rng.integers(0, 20, (6, 5)).astype(np.int64)
    sizes = np.full(6, 5, np.int32)
    w = StreamingFederatedDataset.writer(
        str(tmp_path / "d"), x_shape=(3,), x_dtype=np.float32,
        y_shape=(), y_dtype=np.int32, m=5)
    for lo in (0, 7, 14):
        w.add_samples(x[lo:lo + 7], y[lo:lo + 7])
    w.add_clients(idx[:2], sizes[:2])
    w.add_clients(idx[2:], sizes[2:])
    out = w.finalize()
    np.testing.assert_array_equal(np.asarray(out.x), x)
    np.testing.assert_array_equal(np.asarray(out.y), y)
    np.testing.assert_array_equal(np.asarray(out.idx), idx)
    np.testing.assert_array_equal(np.asarray(out.sizes), sizes)

    w2 = StreamingFederatedDataset.writer(
        str(tmp_path / "bad"), x_shape=(3,), x_dtype=np.float32,
        y_shape=(), y_dtype=np.int32, m=5)
    with pytest.raises(ValueError, match="trailing shape"):
        w2.add_samples(np.zeros((2, 4), np.float32), np.zeros(2, np.int32))
    w2.add_samples(x[:4], y[:4])
    w2.add_clients(np.full((1, 5), 17, np.int64), np.array([5], np.int32))
    with pytest.raises(ValueError, match="references sample"):
        w2.finalize()                                # idx 17 >= 4 samples


def test_open_rejects_foreign_manifest(tmp_path):
    import json
    (tmp_path / "manifest.json").write_text(json.dumps({"format": "nope"}))
    with pytest.raises(ValueError, match="not a repro-streamfed"):
        StreamingFederatedDataset.open(str(tmp_path))


def test_bucket_boundaries_ladder():
    bs = bucket_boundaries(40)
    assert bs[0] == 8 and bs[-1] == 40
    assert list(bs) == sorted(set(bs))
    assert all(b2 <= max(b1 + 1, int(b1 * 1.5)) for b1, b2 in
               zip(bs, bs[1:]))                      # geometric, no gaps
    assert bucket_boundaries(5) == (5,)              # max below min_m
    with pytest.raises(ValueError, match="max_size"):
        bucket_boundaries(0)


# ------------------------------------------------------- mmap data bank ----

def test_mmap_bank_stages_bitwise_vs_host(ds, sfd):
    host = ds.paged_bank(steps=2, batch=16)
    bank = sfd.mmap_bank(steps=2, batch=16)
    assert isinstance(bank, ClientStore) and not bank.is_resident
    assert isinstance(bank, MmapPagedBank)
    assert bank.n_clients == N and bank.spec == host.spec
    rows = np.array([1, 3, 8])
    a, b = host.gather(rows), bank.gather(rows)
    for u, v in ((a.x, b.x), (a.y, b.y), (a.sizes, b.sizes)):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))
    assert bank.last_staged_bytes == host.last_staged_bytes > 0
    # prefetch is consumed, like the host tier's
    bank.prefetch(rows)
    cached = bank._cache[(rows.tobytes(), None)]
    assert bank.gather(rows) is cached and bank._cache == {}


def test_bucketed_staging_trims_padding(ds, sfd):
    """With ``boundaries``, a union of small shards stages a narrower
    [U, M'] chunk — and the staged values (incl. in-graph sampling) are
    IDENTICAL, because cyclic-pad positions past a client's true size
    are never sampled."""
    sizes = np.asarray(sfd.sizes)
    m = int(sfd.meta["m"])
    rows = np.argsort(sizes)[:4].astype(np.int64)    # the smallest shards
    need = int(sizes[rows].max())
    assert need < m, "fixture must be ragged for this test"
    bs = sfd.bucket_boundaries()
    full = sfd.mmap_bank(steps=2, batch=16)
    bank = sfd.mmap_bank(steps=2, batch=16, boundaries=bs)
    a, b = full.gather(rows), bank.gather(rows)
    want_m = next(x for x in bs if x >= need)
    assert b.x.shape[1] == want_m < a.x.shape[1] == m
    assert bank.last_staged_bytes < full.last_staged_bytes
    key = jax.random.PRNGKey(5)
    parts = jnp.arange(len(rows), dtype=jnp.int32)   # staged-local cohort
    for u, v in zip(jax.tree.leaves(a.sample(key, parts)),
                    jax.tree.leaves(b.sample(key, parts))):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


def test_bucket_boundaries_validated(sfd):
    with pytest.raises(ValueError, match="sorted unique"):
        sfd.mmap_bank(steps=2, batch=16, boundaries=(16, 8, 200))
    with pytest.raises(ValueError, match="does not cover"):
        sfd.mmap_bank(steps=2, batch=16, boundaries=(8, 16))


def test_mmap_bank_owned_dir_lifecycle(ds):
    bank = ds.mmap_bank(steps=2, batch=16)           # fresh temp dir, owned
    d = bank.directory
    assert d is not None and os.path.isdir(d)
    store = bank.state_store({"c": jnp.ones((3,))}, N)
    assert store.directory.startswith(d + os.sep)    # paired under the bank
    bank.close()
    assert not os.path.exists(d)                     # state files went too
    bank.close()                                     # idempotent


# ------------------------------------------------------ mmap state store ---

def test_mmap_state_roundtrip_copy_close():
    one = {"m": jnp.arange(3.0), "v": jnp.ones((2, 2))}
    with MmapStateStore.broadcast(one, n=6) as store:
        assert isinstance(store, ClientStore) and not store.is_resident
        assert isinstance(store, HostStateStore)     # the host contract, held
        assert store.n_clients == 6 and not store.stateless
        for leaf in jax.tree.leaves(store.bank):
            assert isinstance(leaf, np.memmap)
        rows = np.array([1, 4])
        staged = store.gather(rows)
        np.testing.assert_array_equal(np.asarray(staged["m"]),
                                      np.tile(np.arange(3.0), (2, 1)))
        assert store.last_staged_bytes == device_bytes(staged) > 0
        twin = store.copy()                          # branches NEW cold files
        assert twin.directory != store.directory
        store.scatter(rows, jax.tree.map(lambda x: x + 1.0, staged))
        np.testing.assert_array_equal(store.bank["m"][1], [1, 2, 3])
        np.testing.assert_array_equal(store.bank["m"][0], [0, 1, 2])
        np.testing.assert_array_equal(twin.bank["m"][4], [0, 1, 2])
        d, dt = store.directory, twin.directory
        twin.close()
        assert not os.path.exists(dt)
    assert not os.path.exists(d)


def test_mmap_state_write_behind_fence():
    with MmapStateStore.broadcast({"c": jnp.zeros((2,))}, n=8) as store:
        rows = np.array([2, 5])
        store.scatter_async(rows, {"c": jnp.ones((2, 2))})
        store.prefetch(rows)                         # in flight: must skip
        store.fence(rows)
        np.testing.assert_array_equal(store.bank["c"][2], 1.0)
        np.testing.assert_array_equal(                # re-gather post-fence
            np.asarray(store.gather(rows)["c"]), np.ones((2, 2)))


def test_mmap_zero_init_is_sparse(tmp_path):
    logical = 4096 * 64 * 4                          # 1 MiB per leaf
    probe = tmp_path / "probe"
    with open(probe, "wb") as f:
        f.truncate(logical)
    if os.stat(probe).st_blocks * 512 >= logical:
        pytest.skip("filesystem does not store sparse files")
    store = MmapStateStore.broadcast(
        {"zero": np.zeros((64,), np.float32),
         "ones": np.ones((64,), np.float32)}, n=4096)
    # dict leaves flatten key-sorted: leaf0 = "ones" (dense), leaf1 = "zero"
    stat = {f: os.stat(os.path.join(store.directory, f))
            for f in os.listdir(store.directory)}
    assert stat["state_leaf1.mmap"].st_size == logical
    assert stat["state_leaf1.mmap"].st_blocks * 512 < logical // 4
    assert stat["state_leaf0.mmap"].st_blocks * 512 >= logical
    store.close()


def test_stateless_mmap_store_pages_zero_from_disk(ds, task):
    store = MmapStateStore.broadcast((), n=100_000)
    assert store.stateless and store.disk_bytes() == 0
    assert store.directory is None                   # no files at all
    store.gather(np.arange(64))
    assert store.last_staged_bytes == 0
    # end to end: a stateless registration through the full mmap tier
    bank = ds.mmap_bank(steps=2, batch=16)
    with bank:
        sim = FedSim(task.with_data(bank), "fedavg", HParams(lr=0.1), N)
        st = sim.init(jax.random.PRNGKey(0))
        assert isinstance(st.clients, MmapStateStore) and st.clients.stateless
        assert not any("state" in f for f in os.listdir(bank.directory))
        sim.run_scanned(jax.random.PRNGKey(1), 2, sample_clients=4,
                        eval_every=1)
        assert st.clients.last_staged_bytes == 0


# ----------------------------------- mmap ≡ host-paged ≡ resident (vmap) ---

@pytest.mark.parametrize("algo,hp", [
    ("scaffold", HParams(lr=0.1)),                   # stateful clients
    ("fedpm_foof", HParams(lr=0.3, damping=1.0)),    # preconditioned mixing
])
def test_mmap_scanned_equals_resident_bitwise(task, ds, sfd, algo, hp):
    rng = jax.random.PRNGKey(0)
    res = task.with_data(ds.device_bank(steps=2, batch=16))
    got_r, _ = FedSim(res, algo, hp, N).run_scanned(
        rng, R, sample_clients=5, eval_every=2)
    got_h, _ = FedSim(task.with_data(ds.paged_bank(steps=2, batch=16)),
                      algo, hp, N).run_scanned(
        rng, R, sample_clients=5, eval_every=2)
    got_m, _ = FedSim(task.with_data(sfd.mmap_bank(steps=2, batch=16)),
                      algo, hp, N).run_scanned(
        rng, R, sample_clients=5, eval_every=2)
    assert isinstance(got_m.clients, MmapStateStore)
    _exact(got_m, got_h, f"{algo}:mmap-vs-hostpaged")
    _exact(got_m, got_r, f"{algo}:mmap-vs-resident")


def test_overlap_fence_shared_cohort_rows(task, ds, sfd):
    """eval_every=1 under full participation: EVERY consecutive chunk
    pair shares every cohort row, so each gather re-reads rows the
    write-behind may still be draining — the fence must make overlap-on
    indistinguishable from the synchronous scatter."""
    rng = jax.random.PRNGKey(3)
    hp = HParams(lr=0.1)
    out = {}
    for tag, overlap in (("on", True), ("off", False)):
        sim = FedSim(task.with_data(sfd.mmap_bank(steps=2, batch=16)),
                     "scaffold", hp, N, scatter_overlap=overlap)
        assert sim.scatter_overlap is overlap
        out[tag], _ = sim.run_scanned(rng, 4, eval_every=1)
        assert out[tag].clients._pending == []       # final fence drained
    _exact(out["on"], out["off"], "overlap-fence")


# ------------------------------------------------------------- cleanup -----

def test_no_mmap_leak_after_failed_run(ds, task, tmp_path):
    """An exception mid-``run_scanned`` must not leak cold files past the
    owning ``with`` block (the satellite-2 contract)."""
    sfd = StreamingFederatedDataset.from_dataset(
        ds, directory=str(tmp_path / "d"))
    boom = RuntimeError("eval exploded")

    def eval_fn(params):
        raise boom

    with pytest.raises(RuntimeError, match="eval exploded"):
        with sfd.mmap_bank(steps=2, batch=16, owned=True) as bank:
            sim = FedSim(task.with_data(bank), "scaffold",
                         HParams(lr=0.1), N)
            sim.run_scanned(jax.random.PRNGKey(0), 4, sample_clients=4,
                            eval_every=1, eval_fn=eval_fn)
    assert not list(tmp_path.rglob("*.mmap"))
    assert not (tmp_path / "d").exists()


EXIT_CLEANUP_SCRIPT = r'''
import sys; sys.path.insert(0, "src")
import numpy as np, jax.numpy as jnp
from repro.data import FederatedDataset, make_clustered_classification
from repro.fl.coldstore import MmapStateStore

data = make_clustered_classification(240, 16, 4, seed=0)
ds = FederatedDataset.from_arrays(data, 6, alpha=0.5, seed=0)
bank = ds.mmap_bank(steps=2, batch=16)                  # owns a temp dir
store = MmapStateStore.broadcast({"c": jnp.ones((3,))}, n=6)
print("DIRS", bank.directory, store.directory)
bank.gather(np.arange(3)); store.gather(np.arange(3))
# no close(): weakref.finalize must fire at interpreter exit
'''


def test_cold_files_removed_at_interpreter_exit():
    res = subprocess.run([sys.executable, "-c", EXIT_CLEANUP_SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr[-3000:]
    line = next(ln for ln in res.stdout.splitlines() if ln.startswith("DIRS"))
    dirs = line.split()[1:]
    assert len(dirs) == 2
    for d in dirs:
        assert not os.path.exists(d), d


# ------------------------------------------- sharded engine (8 devices) ----

COLD_SHARDED_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.core.algorithms import HParams
from repro.data import FederatedDataset, make_clustered_classification
from repro.fl.coldstore import MmapStateStore
from repro.fl.simulate import FedSim
from repro.fl.sharded import make_client_mesh, staging_sharding
from repro.fl.tasks import DNNTask
from repro.models.simple import MLPModel

assert jax.device_count() == 8
mesh = make_client_mesh()
N, R = 16, 4
data = make_clustered_classification(1600, 16, 4, seed=0)
ds = FederatedDataset.from_arrays(data, N, alpha=0.5, seed=0)
task = DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4))
hp = HParams(lr=0.1)

def close(a, b, tag):
    ca = a.clients.bank if hasattr(a.clients, "bank") else a.clients
    cb = b.clients.bank if hasattr(b.clients, "bank") else b.clients
    for name, x, y in (("params", a.params, b.params),
                       ("server", a.server, b.server), ("clients", ca, cb)):
        for u, v in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=2e-6, atol=2e-6,
                                       err_msg=f"{tag}:{name}")

rng = jax.random.PRNGKey(0)
pag = task.with_data(ds.paged_bank(steps=2, batch=16))
got_h, _ = FedSim(pag, "scaffold", hp, N, mesh=mesh).run_scanned(
    rng, R, sample_clients=6, eval_every=2)
with ds.mmap_bank(steps=2, batch=16) as bank:
    sim = FedSim(task.with_data(bank), "scaffold", hp, N, mesh=mesh)
    got_m, _ = sim.run_scanned(rng, R, sample_clients=6, eval_every=2)
    assert isinstance(got_m.clients, MmapStateStore)
    close(got_m, got_h, "cold-sharded")
    print("COLD-SHARDED-EQUIV-OK")
    # staged chunks land SHARD-LOCAL straight from the maps
    staged = bank.gather(np.arange(8), sharding=staging_sharding(mesh))
    assert len(staged.x.sharding.device_set) == 8
    assert all(s.data.shape[0] == 1 for s in staged.x.addressable_shards)
    print("COLD-SHARDED-PLACEMENT-OK")
print("OK")
'''


def test_cold_sharded_contracts():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", COLD_SHARDED_SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("COLD-SHARDED-EQUIV-OK", "COLD-SHARDED-PLACEMENT-OK"):
        assert marker in res.stdout, (marker, res.stdout)

"""Participation-core invariants (the client-sampling state-corruption
bug class): sampled-out client state must be bit-identical across rounds,
and the gathered round must equal the legacy full-mask round for every
algorithm in the zoo."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import ALGORITHMS, HParams, Participation
from repro.data import (FederatedDataset, make_clustered_classification,
                        make_libsvm_like)
from repro.data.federated import build_round_batches
from repro.fl.simulate import FedSim
from repro.fl.tasks import ConvexTask, DNNTask
from repro.models.simple import LogisticModel, MLPModel

N_CLIENTS = 8


@pytest.fixture(scope="module")
def convex():
    data = make_libsvm_like("a9a", seed=0)
    ds = FederatedDataset.from_arrays(data, N_CLIENTS, alpha=0.0, seed=0,
                                      test_frac=0.1)
    d = data["x"].shape[1]
    task = ConvexTask(LogisticModel(d=d, lam=1e-3))
    return dict(task=task, batches=ds.client_full_batches(k_steps=1), d=d)


@pytest.fixture(scope="module")
def dnn():
    data = make_clustered_classification(1200, 16, 4, seed=0)
    ds = FederatedDataset.from_arrays(data, N_CLIENTS, alpha=0.5, seed=0)
    task = DNNTask(MLPModel(in_dim=16, hidden=(32,), num_classes=4))
    batches = build_round_batches(ds, 2, 16, np.random.default_rng(0))
    return dict(task=task, batches=batches)


# ------------------------------------------------- stateful invariance -----

def test_sampled_out_scaffold_state_untouched(convex):
    """With sample_clients=S < N, non-participants' control variates are
    bit-identical across rounds (the corruption this PR fixes)."""
    sim = FedSim(convex["task"], "scaffold", HParams(lr=0.3), N_CLIENTS)
    st = sim.init(jax.random.PRNGKey(0))
    participants = np.array([0, 2, 5])
    out = np.setdiff1d(np.arange(N_CLIENTS), participants)

    before = np.asarray(st.clients)
    st1, _ = sim.round(st, convex["batches"], jax.random.PRNGKey(1),
                       participants=participants)
    after1 = np.asarray(st1.clients)
    np.testing.assert_array_equal(after1[out], before[out])
    # participants actually moved (their control variates are live)
    assert np.abs(after1[participants] - before[participants]).max() > 0

    # a second sampled round with a different cohort: only that cohort moves
    participants2 = np.array([1, 2, 7])
    out2 = np.setdiff1d(np.arange(N_CLIENTS), participants2)
    st2, _ = sim.round(st1, convex["batches"], jax.random.PRNGKey(2),
                       participants=participants2)
    np.testing.assert_array_equal(np.asarray(st2.clients)[out2], after1[out2])


def test_sampled_round_params_finite_and_progressing(convex):
    """fedpm / scaffold converge under S < N sampling (no state corruption
    feeding back into the preconditioner)."""
    for algo in ("fedpm", "scaffold"):
        sim = FedSim(convex["task"], algo,
                     HParams(lr=1.0 if algo == "fedpm" else 0.3,
                             damping=1e-2), N_CLIENTS)
        st = sim.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        for t in range(3):
            chosen = np.sort(rng.choice(N_CLIENTS, size=4, replace=False))
            st, _ = sim.round(st, convex["batches"], jax.random.PRNGKey(t),
                              participants=chosen)
        assert np.isfinite(np.asarray(st.params)).all(), algo


# ------------------------------------------- masked == gathered, full zoo --

def _legacy_full_mask_round(sim, st, batches, rng, mask):
    """The pre-participation engine: vmap ALL N clients, mask-weighted
    server aggregation over the full stack."""
    rngs = jax.random.split(rng, sim.n)

    def client_fn(cstate, b, r):
        return sim.algo.client(sim.task, sim.hp, st.params, cstate,
                               st.server, b, r)

    msgs, _ = jax.vmap(client_fn)(st.clients, batches, rngs)
    part = Participation(weights=jnp.asarray(mask, jnp.float32),
                         n_total=sim.n)
    return sim.algo.server(sim.task, sim.hp, st.params, st.server, msgs,
                           part)


def _assert_trees_close(a, b, **tol):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **tol)


def _check_masked_equals_gathered(task, batches, algo, hp):
    sim = FedSim(task, algo, hp, N_CLIENTS)
    st = sim.init(jax.random.PRNGKey(0))
    mask = np.zeros(N_CLIENTS, np.float32)
    participants = np.array([1, 3, 4, 6])
    mask[participants] = 1.0
    rng = jax.random.PRNGKey(7)
    ref_params, ref_server = _legacy_full_mask_round(
        sim, st, batches, rng, mask)
    # ref_* may alias st's buffers (server fns pass state through), and
    # round() donates its input state — round a copy
    got, _ = sim.round(st.copy(), batches, rng, participants=participants)
    _assert_trees_close(got.params, ref_params, rtol=2e-4, atol=2e-5)
    _assert_trees_close(got.server, ref_server, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("algo", sorted(
    n for n, a in ALGORITHMS.items() if not a.needs_grams))
def test_masked_equals_gathered_convex(convex, algo):
    hp = HParams(lr=0.1, damping=1e-2)
    _check_masked_equals_gathered(convex["task"], convex["batches"], algo, hp)


@pytest.mark.parametrize("algo", sorted(
    n for n, a in ALGORITHMS.items() if a.needs_grams))
def test_masked_equals_gathered_dnn(dnn, algo):
    hp = HParams(lr=0.3, damping=1.0)
    _check_masked_equals_gathered(dnn["task"], dnn["batches"], algo, hp)


# ------------------------------------------------- engine data paths -------

def test_pregathered_batches_equal_full_bank(convex):
    """Passing [S,...] participant batches gives the identical round as
    passing the [N,...] bank and letting the engine gather."""
    sim = FedSim(convex["task"], "fedpm", HParams(lr=1.0, damping=1e-2),
                 N_CLIENTS)
    st = sim.init(jax.random.PRNGKey(0))
    participants = np.array([0, 3, 7])
    rng = jax.random.PRNGKey(3)
    # rounds donate their input state — copy to round twice from one state
    full, _ = sim.round(st.copy(), convex["batches"], rng,
                        participants=participants)
    sub_batches = jax.tree.map(lambda x: x[participants], convex["batches"])
    pre, _ = sim.round(st, sub_batches, rng, participants=participants)
    _assert_trees_close(full.params, pre.params, rtol=0, atol=0)


def test_legacy_mask_api_equals_participants_api(convex):
    sim = FedSim(convex["task"], "scaffold", HParams(lr=0.3), N_CLIENTS)
    st = sim.init(jax.random.PRNGKey(0))
    participants = np.array([2, 4, 5])
    mask = jnp.zeros((N_CLIENTS,)).at[jnp.asarray(participants)].set(1.0)
    rng = jax.random.PRNGKey(5)
    a, _ = sim.round(st.copy(), convex["batches"], rng, mask)
    b, _ = sim.round(st, convex["batches"], rng, participants=participants)
    _assert_trees_close(a.params, b.params, rtol=0, atol=0)
    _assert_trees_close(a.clients, b.clients, rtol=0, atol=0)


def test_fedns_sketch_frame_shared_via_server_state(convex):
    """The Nyström frame lives in server state (built once at init), is
    orthonormal, and the sketched method still runs with s < d."""
    hp = HParams(lr=1.0, damping=1e-3, sketch=32)
    sim = FedSim(convex["task"], "fedns", hp, N_CLIENTS)
    st = sim.init(jax.random.PRNGKey(0))
    omega = np.asarray(st.server)
    assert omega.shape == (convex["d"], 32)
    np.testing.assert_allclose(omega.T @ omega, np.eye(32), atol=1e-5)
    st1, _ = sim.round(st, convex["batches"], jax.random.PRNGKey(1))
    assert np.isfinite(np.asarray(st1.params)).all()
    np.testing.assert_array_equal(np.asarray(st1.server), omega)

"""FROZEN pre-compositional algorithm zoo — the bit-compat oracle.

This is a verbatim copy of the monolithic ``_xxx_client``/``_xxx_server``
closure pairs that ``repro.core.algorithms`` shipped before the
compositional LocalUpdate × Message × ServerMixer registry (PR 5).  The
registry's 14 paper compositions must reproduce these BITWISE through the
same engine (tests/test_api.py) — do not "fix" or modernize this module;
its value is that it does not change.

Messages here are the historical untyped dicts; the engine still accepts
them (``repro.core.api.client_loss`` and the comm accounting handle dict
messages), which this oracle also exercises.
"""
import jax
import jax.numpy as jnp

import sys

from repro.core import foof as F
import repro.core.inverse  # noqa: F401  (repro.core.__init__ shadows the
# submodule attribute with the same-named function; fetch the module)
inv = sys.modules["repro.core.inverse"]
from repro.core.algorithms import batches_len
from repro.core.api import Algorithm
from repro.utils import (tree_add, tree_axpy, tree_scale, tree_sub,
                         tree_zeros_like, global_norm_clip)


def _no_server_state(task, hp, params):
    return ()


def _no_client_state(task, params):
    return ()


def _grad_step(task, hp, params, batch, extra=None):
    loss, g = task.loss_grad(params, batch)
    if extra is not None:
        g = tree_add(g, extra)
    if hp.weight_decay:
        g = tree_axpy(hp.weight_decay, params, g)
    g = global_norm_clip(g, hp.clip)
    return tree_axpy(-hp.lr, g, params), loss


def _sgd_local(task, hp, params, batches, extra_fn=None):
    def step(theta, batch):
        extra = extra_fn(theta) if extra_fn is not None else None
        theta, loss = _grad_step(task, hp, theta, batch, extra)
        return theta, loss

    theta, losses = jax.lax.scan(step, params, batches)
    return theta, jnp.mean(losses)


# ================================================================= FOGM =====

def _psgd_client(task, hp, params, cstate, sstate, batches, rng):
    first = jax.tree.map(lambda x: x[0], batches)
    _, g = task.loss_grad(params, first)
    g = global_norm_clip(g, hp.clip)
    return {"grad": g}, cstate


def _psgd_server(task, hp, params, sstate, msgs, part):
    g = part.wmean(msgs["grad"])
    return tree_axpy(-hp.lr, g, params), sstate


# ================================================================= FOPM =====

def _fedavg_client(task, hp, params, cstate, sstate, batches, rng):
    theta, loss = _sgd_local(task, hp, params, batches)
    return {"theta": theta, "loss": loss}, cstate


def _fedavg_server(task, hp, params, sstate, msgs, part):
    return part.wmean(msgs["theta"]), sstate


def _fedavgm_server(task, hp, params, sstate, msgs, part):
    delta = tree_sub(part.wmean(msgs["theta"]), params)
    v = tree_axpy(hp.momentum, sstate, delta)
    return tree_add(params, v), v


def _fedprox_client(task, hp, params, cstate, sstate, batches, rng):
    theta0 = params
    theta, loss = _sgd_local(
        task, hp, params, batches,
        extra_fn=lambda th: tree_scale(tree_sub(th, theta0), hp.prox_mu))
    return {"theta": theta, "loss": loss}, cstate


def _scaffold_init_client(task, params):
    return tree_zeros_like(params)


def _scaffold_init_server(task, hp, params):
    return tree_zeros_like(params)


def _scaffold_client(task, hp, params, cstate, sstate, batches, rng):
    c_i, c = cstate, sstate
    corr = tree_sub(c, c_i)
    theta0 = params
    theta, loss = _sgd_local(task, hp, params, batches,
                             extra_fn=lambda th: corr)
    k = batches_len(batches)
    c_i_new = tree_add(tree_sub(c_i, c),
                       tree_scale(tree_sub(theta0, theta), 1.0 / (k * hp.lr)))
    return {"theta": theta, "dc": tree_sub(c_i_new, c_i), "loss": loss}, c_i_new


def _scaffold_server(task, hp, params, sstate, msgs, part):
    theta = part.wmean(msgs["theta"])
    frac = part.n_sampled / jnp.float32(part.n_total)
    c = tree_add(sstate, tree_scale(part.wmean(msgs["dc"]), frac))
    new = tree_add(params, tree_scale(tree_sub(theta, params), hp.server_lr))
    return new, c


def _fedadam_init_server(task, hp, params):
    return (tree_zeros_like(params), tree_zeros_like(params))


def _fedadam_client(task, hp, params, cstate, sstate, batches, rng):
    theta, loss = _sgd_local(task, hp, params, batches)
    return {"delta": tree_sub(theta, params), "loss": loss}, cstate


def _fedadam_server(task, hp, params, sstate, msgs, part):
    m, v = sstate
    d = part.wmean(msgs["delta"])
    m = tree_add(tree_scale(m, hp.beta1), tree_scale(d, 1 - hp.beta1))
    v = jax.tree.map(lambda vv, dd: hp.beta2 * vv + (1 - hp.beta2) * dd * dd, v, d)
    upd = jax.tree.map(lambda mm, vv: mm / (jnp.sqrt(vv) + hp.tau), m, v)
    return tree_axpy(hp.server_lr, upd, params), (m, v)


# ======================================================= SOGM (flat only) ===

def _fednl_client(task, hp, params, cstate, sstate, batches, rng):
    first = jax.tree.map(lambda x: x[0], batches)
    _, g = task.loss_grad(params, first)
    h = task.hessian(params, first)
    return {"grad": g, "hess": h}, cstate


def _fednl_server(task, hp, params, sstate, msgs, part):
    g = part.wmean(msgs["grad"])
    h = part.wmean(msgs["hess"])
    step = inv.solve(h, g[:, None], hp.damping, method=hp.inverse_method,
                     ns_iters=hp.ns_iters)[:, 0]
    return params - hp.lr * step, sstate


def _fedns_init_server(task, hp, params):
    d = params.shape[0]
    s = hp.sketch or d
    gauss = jax.random.normal(jax.random.PRNGKey(42), (d, s))
    omega, _ = jnp.linalg.qr(gauss)
    return omega


def _fedns_client(task, hp, params, cstate, sstate, batches, rng):
    first = jax.tree.map(lambda x: x[0], batches)
    _, g = task.loss_grad(params, first)
    h = task.hessian(params, first)
    omega = sstate
    return {"grad": g, "sketch": h @ omega}, cstate


def _fedns_server(task, hp, params, sstate, msgs, part):
    g = part.wmean(msgs["grad"])
    y = part.wmean(msgs["sketch"])
    omega = sstate
    core = omega.T @ y
    core = 0.5 * (core + core.T) + 1e-6 * jnp.eye(core.shape[0])
    h_hat = y @ jnp.linalg.solve(core, y.T)
    h_hat = 0.5 * (h_hat + h_hat.T)
    x = inv.solve(h_hat, g[:, None], max(hp.damping, 1e-6),
                  method=hp.inverse_method, ns_iters=hp.ns_iters)[:, 0]
    return params - hp.lr * x, sstate


# ================================================ SOPM with full Hessian ====

def _newton_local(task, hp, params, batches):
    def step(theta, batch):
        _, g = task.loss_grad(theta, batch)
        h = task.hessian(theta, batch)
        d = inv.solve(h, g[:, None], hp.damping, method=hp.inverse_method,
                      ns_iters=hp.ns_iters)[:, 0]
        return theta - hp.lr * d, h

    theta, hs = jax.lax.scan(step, params, batches)
    return theta, jax.tree.map(lambda x: x[-1], hs)


def _localnewton_full_client(task, hp, params, cstate, sstate, batches, rng):
    theta, _ = _newton_local(task, hp, params, batches)
    return {"theta": theta}, cstate


def _fedpm_full_client(task, hp, params, cstate, sstate, batches, rng):
    theta, h_last = _newton_local(task, hp, params, batches)
    return {"theta": theta, "precond": h_last}, cstate


def _fedpm_full_server(task, hp, params, sstate, msgs, part):
    pbar = part.wmean(msgs["precond"])
    ptheta = part.wmean(
        jax.vmap(lambda p, t: p @ t)(msgs["precond"], msgs["theta"]))
    theta = inv.solve(pbar, ptheta[:, None], 0.0, method=hp.inverse_method,
                      ns_iters=hp.ns_iters)[:, 0]
    return theta, sstate


# ==================================================== SOPM with FOOF ========

def _foof_local(task, hp, params, batches):
    first = jax.tree.map(lambda x: x[0], batches)
    grams0 = task.grams(params, first)
    precond = F.build_preconditioner(grams0, damping=hp.damping,
                                     method=hp.inverse_method,
                                     ns_iters=hp.ns_iters)

    def step(theta, batch):
        loss, g = task.loss_grad(theta, batch)
        if hp.weight_decay:
            g = tree_axpy(hp.weight_decay, theta, g)
        g = global_norm_clip(g, hp.clip)
        pre = F.apply_preconditioner(precond, theta, g)
        return tree_axpy(-hp.lr, pre, theta), loss

    theta, losses = jax.lax.scan(step, params, batches)
    if hp.foof_timing == "end":
        last = jax.tree.map(lambda x: x[-1], batches)
        grams_tx = task.grams(theta, last)
    else:
        grams_tx = grams0
    return theta, grams_tx, jnp.mean(losses)


def _localnewton_foof_client(task, hp, params, cstate, sstate, batches, rng):
    theta, _, loss = _foof_local(task, hp, params, batches)
    return {"theta": theta, "loss": loss}, cstate


def _fedpm_foof_client(task, hp, params, cstate, sstate, batches, rng):
    theta, grams, loss = _foof_local(task, hp, params, batches)
    return {"theta": theta, "grams": grams, "loss": loss}, cstate


def _fedpm_foof_server(task, hp, params, sstate, msgs, part):
    mixed = F.mix_preconditioned(msgs["theta"], msgs["grams"],
                                 damping=hp.damping,
                                 method=hp.inverse_method,
                                 ns_iters=hp.ns_iters, weights=part.weights,
                                 axes=part.axes)
    return mixed, sstate


# ------------------------------------------------ diagonal SOPM baselines ---

def _diag_local(task, hp, params, batches, *, sophia: bool):
    def step(carry, batch):
        theta, m, h = carry
        loss, g = task.loss_grad(theta, batch)
        if hp.weight_decay:
            g = tree_axpy(hp.weight_decay, theta, g)
        g = global_norm_clip(g, hp.clip)
        h = jax.tree.map(lambda hh, gg: hp.beta2 * hh + (1 - hp.beta2) * gg * gg,
                         h, g)
        if sophia:
            m = jax.tree.map(lambda mm, gg: hp.beta1 * mm + (1 - hp.beta1) * gg,
                             m, g)
            upd = jax.tree.map(
                lambda mm, hh: jnp.clip(mm / jnp.maximum(hp.sophia_gamma * hh,
                                                         1e-12), -1.0, 1.0),
                m, h)
        else:
            upd = jax.tree.map(lambda gg, hh: gg / (jnp.sqrt(hh) + hp.damping),
                               g, h)
        theta = tree_axpy(-hp.lr, upd, theta)
        return (theta, m, h), loss

    z = tree_zeros_like(params)
    (theta, _, _), losses = jax.lax.scan(step, (params, z, z), batches)
    return theta, jnp.mean(losses)


def _ltda_client(task, hp, params, cstate, sstate, batches, rng):
    theta, loss = _diag_local(task, hp, params, batches, sophia=False)
    return {"theta": theta, "loss": loss}, cstate


def _fedsophia_client(task, hp, params, cstate, sstate, batches, rng):
    theta, loss = _diag_local(task, hp, params, batches, sophia=True)
    return {"theta": theta, "loss": loss}, cstate


# ================================================================ registry ==

def _alg(name, cat, client, server, init_server=_no_server_state,
         init_client=_no_client_state, **kw) -> Algorithm:
    return Algorithm(name=name, category=cat, client=client, server=server,
                     init_server=init_server, init_client=init_client, **kw)


LEGACY_ALGORITHMS: dict = {
    "psgd": _alg("psgd", "FOGM", _psgd_client, _psgd_server),
    "fedavg": _alg("fedavg", "FOPM", _fedavg_client, _fedavg_server),
    "fedavgm": _alg("fedavgm", "FOPM", _fedavg_client, _fedavgm_server,
                    init_server=lambda task, hp, p: tree_zeros_like(p)),
    "fedprox": _alg("fedprox", "FOPM", _fedprox_client, _fedavg_server),
    "scaffold": _alg("scaffold", "FOPM", _scaffold_client, _scaffold_server,
                     init_server=_scaffold_init_server,
                     init_client=_scaffold_init_client),
    "fedadam": _alg("fedadam", "FOPM", _fedadam_client, _fedadam_server,
                    init_server=_fedadam_init_server),
    "fednl": _alg("fednl", "SOGM", _fednl_client, _fednl_server,
                  needs_hessian=True),
    "fedns": _alg("fedns", "SOGM", _fedns_client, _fedns_server,
                  init_server=_fedns_init_server, needs_hessian=True),
    "localnewton": _alg("localnewton", "SOPM", _localnewton_full_client,
                        _fedavg_server, needs_hessian=True),
    "fedpm": _alg("fedpm", "SOPM", _fedpm_full_client, _fedpm_full_server,
                  needs_hessian=True),
    "localnewton_foof": _alg("localnewton_foof", "SOPM",
                             _localnewton_foof_client, _fedavg_server,
                             needs_grams=True),
    "ltda": _alg("ltda", "SOPM", _ltda_client, _fedavg_server),
    "fedsophia": _alg("fedsophia", "SOPM", _fedsophia_client, _fedavg_server),
    "fedpm_foof": _alg("fedpm_foof", "SOPM", _fedpm_foof_client,
                       _fedpm_foof_server, needs_grams=True),
}

"""benchmarks.bench_gate: the regression check and the --update-baseline
guard (ISSUE 6 satellite).

The guard is the part worth testing: regenerating a baseline FROM a
failing run would silently widen the failing gate — the next regression
on top of it still passes and the gate is dead.  ``--update-baseline``
must refuse that (leaving the baseline untouched) unless the widening is
made explicit with ``--allow-regression``.
"""
import json

import pytest

from benchmarks.bench_gate import check, main


def _gates(**kv):
    return {k: {"value": v[0], "worse": v[1]} for k, v in kv.items()}


def test_check_directions_and_missing():
    base = {"gates": _gates(up=(2.0, "higher"), down=(4.0, "lower"),
                            gone=(1.0, "higher"))}
    cur = {"gates": _gates(up=(2.4, "higher"),    # within 2.0*1.25
                           down=(2.0, "lower"))}  # below 4.0*0.75 -> FAIL
    fails = check(cur, base, tol=0.25)
    assert any(f.startswith("down:") for f in fails)
    assert any("gone: missing" in f for f in fails)
    assert not any(f.startswith("up:") for f in fails)
    assert check({"gates": _gates(up=(2.4, "higher"), down=(3.1, "lower"),
                                  gone=(1.0, "higher"))}, base, 0.25) == []


@pytest.fixture()
def paths(tmp_path):
    base = tmp_path / "baseline.json"
    cur = tmp_path / "current.json"
    base.write_text(json.dumps(
        {"meta": {"note": "kept"}, "gates": _gates(g=(2.0, "higher"))}))
    return cur, base


def test_update_baseline_refuses_to_widen_failing_gate(paths, capsys):
    cur, base = paths
    cur.write_text(json.dumps({"gates": _gates(g=(9.0, "higher"))}))
    rc = main([str(cur), str(base), "--update-baseline"])
    assert rc == 1
    assert "REFUSING" in capsys.readouterr().err
    # the failing run must NOT have touched the checked-in baseline
    assert json.loads(base.read_text())["gates"]["g"]["value"] == 2.0


def test_update_baseline_allow_regression_is_explicit(paths, capsys):
    cur, base = paths
    cur.write_text(json.dumps({"gates": _gates(g=(9.0, "higher"))}))
    rc = main([str(cur), str(base), "--update-baseline",
               "--allow-regression"])
    assert rc == 0
    assert "WIDENING" in capsys.readouterr().out   # the act is logged
    out = json.loads(base.read_text())
    assert out["gates"]["g"]["value"] == 9.0
    assert out["meta"] == {"note": "kept"}         # meta survives refresh


def test_update_baseline_passing_run(paths):
    cur, base = paths
    cur.write_text(json.dumps(
        {"gates": _gates(g=(1.8, "higher"), new=(16.0, "lower"))}))
    assert main([str(cur), str(base), "--update-baseline"]) == 0
    out = json.loads(base.read_text())
    assert set(out["gates"]) == {"g", "new"}       # new gates picked up


def test_update_baseline_refuses_empty_gates(paths):
    cur, base = paths
    cur.write_text(json.dumps({"rows": {}}))       # smoke crashed early
    assert main([str(cur), str(base), "--update-baseline",
                 "--allow-regression"]) == 1
    assert json.loads(base.read_text())["gates"]["g"]["value"] == 2.0

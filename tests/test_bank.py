"""Packed gram-bank engine (repro.core.bank) vs the per-leaf reference:
numerical equivalence across transformer/MoE/SSM-shaped gram trees, the
factor-once local loop, and the one-factorization-per-round structural
guarantee."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core import bank as B
from repro.core import foof as F
from repro.core.algorithms import HParams, _foof_local
from repro.data import make_clustered_classification, FederatedDataset
from repro.data.federated import build_round_batches
from repro.fl.simulate import FedSim
from repro.fl.tasks import DNNTask
from repro.models.simple import MLPModel
from repro.utils import tree_axpy, global_norm_clip


def _spd(key, shape, bs):
    m = jax.random.normal(key, (*shape, bs, bs))
    return jnp.einsum("...ij,...kj->...ik", m, m) / bs + 0.05 * jnp.eye(bs)


def _trees(seed, stacked=0):
    """Transformer/MoE/SSM-shaped (params, grads, grams): stacked unit/inner
    lead axes, MoE routing (wi/shared_wi ride the router gram), a diagonal
    embedding lane, no-gram leaves, and TWO distinct block sizes."""
    ks = iter(jax.random.split(jax.random.PRNGKey(seed), 16))
    u, i, nb, bs, bs2, d, e, v = 2, 2, 2, 8, 12, 16, 3, 11
    s = (stacked,) if stacked else ()

    def rnd(*shape):
        return jax.random.normal(next(ks), (*s, *shape))

    params = {
        "blocks": {"attn": {"wqkv": rnd(u, i, nb * bs, 10),
                            "wo": rnd(u, i, bs2, d),
                            "norm": rnd(u, i, d)},
                   "moe": {"router": rnd(u, i, nb * bs, e),
                           "wi": rnd(u, i, e, nb * bs, 6),
                           "shared_wi": rnd(u, i, nb * bs, 4)}},
        "ssm": {"in_proj": rnd(bs2, 9), "out_proj": rnd(nb * bs, 7)},
        "embed": {"w": rnd(v, 6)},
        "head": rnd(d, 5),
    }
    grads = jax.tree.map(lambda x: x * 0.1 + 0.01, params)
    zero = jnp.zeros((*s, 0))
    grams = {
        "blocks": {"attn": {"wqkv": _spd(next(ks), (*s, u, i, nb), bs),
                            "wo": _spd(next(ks), (*s, u, i, 1), bs2),
                            "norm": zero},
                   "moe": {"router": _spd(next(ks), (*s, u, i, nb), bs),
                           "wi": zero, "shared_wi": zero}},
        "ssm": {"in_proj": _spd(next(ks), (*s, 1), bs2),
                "out_proj": _spd(next(ks), (*s, nb), bs)},
        "embed": {"w": jax.random.uniform(next(ks), (*s, v)) + 0.1},
        "head": zero,
    }
    return params, grads, grams


def _assert_trees_close(a, b, rtol=2e-4, atol=2e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ------------------------------------------------------- pack round-trip ---

def test_pack_unpack_roundtrip():
    _, _, grams = _trees(0)
    bank = B.pack(grams)
    assert len(bank.layout.block_sizes) == 2          # bs=8 and bs=12 groups
    back = B.unpack_like(grams, bank.mats, bank.diag, bank.others,
                         bank.layout)
    _assert_trees_close(grams, back, rtol=0, atol=0)


def test_pack_stacked_axis():
    _, _, grams = _trees(0, stacked=3)
    bank = B.pack(grams, stack=1)
    for m in bank.mats:
        assert m.shape[0] == 3
    assert bank.diag.shape[0] == 3


# ------------------------------------------------- packed ≡ per-leaf -------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 99), damping=st.sampled_from([1e-3, 1.0]),
       method=st.sampled_from(["cholesky", "ns"]))
def test_precondition_packed_matches_reference(seed, damping, method):
    params, grads, grams = _trees(seed)
    got = F.precondition_tree(params, grads, grams, damping=damping,
                              method=method, ns_iters=30)
    want = F.precondition_tree(params, grads, grams, damping=damping,
                               method=method, ns_iters=30, packed=False)
    _assert_trees_close(got, want)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 99), damping=st.sampled_from([1e-2, 1.0]),
       method=st.sampled_from(["cholesky", "ns"]))
def test_mix_packed_matches_reference(seed, damping, method):
    s = 3
    params, _, grams = _trees(seed, stacked=s)
    w = jax.random.uniform(jax.random.PRNGKey(seed), (s,)) + 0.2
    got = F.mix_preconditioned(params, grams, damping=damping, method=method,
                               ns_iters=30, weights=w)
    want = F.mix_preconditioned(params, grams, damping=damping, method=method,
                                ns_iters=30, weights=w, packed=False)
    _assert_trees_close(got, want, rtol=2e-3, atol=2e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 99), damping=st.sampled_from([1e-3, 1.0]))
def test_invert_grams_packed_matches_reference(seed, damping):
    _, _, grams = _trees(seed)
    got = F.invert_grams(grams, damping=damping)
    want = F.invert_grams(grams, damping=damping, packed=False)
    _assert_trees_close(got, want, rtol=1e-4, atol=1e-5)


def test_precondition_packed_pallas_matches_reference():
    params, grads, grams = _trees(7)
    got = F.precondition_tree(params, grads, grams, damping=0.1,
                              method="pallas_ns", ns_iters=30)
    want = F.precondition_tree(params, grads, grams, damping=0.1,
                               method="cholesky", packed=False)
    _assert_trees_close(got, want, rtol=5e-3, atol=5e-4)


def test_precondition_packed_pallas_chol_matches_reference():
    params, grads, grams = _trees(11)
    got = F.precondition_tree(params, grads, grams, damping=0.1,
                              method="pallas_chol")
    want = F.precondition_tree(params, grads, grams, damping=0.1,
                               method="cholesky", packed=False)
    _assert_trees_close(got, want, rtol=5e-3, atol=5e-4)


@pytest.mark.parametrize("method", ["pallas_ns", "pallas_chol"])
def test_mix_packed_pallas_matches_reference(method):
    """The fused mix kernel (one launch per group: reduce → invert →
    apply) must agree with the per-leaf cholesky oracle."""
    s = 3
    params, _, grams = _trees(5, stacked=s)
    w = jax.random.uniform(jax.random.PRNGKey(5), (s,)) + 0.2
    got = F.mix_preconditioned(params, grams, damping=0.1, method=method,
                               ns_iters=40, weights=w)
    want = F.mix_preconditioned(params, grams, damping=0.1,
                                method="cholesky", weights=w, packed=False)
    _assert_trees_close(got, want, rtol=5e-3, atol=5e-4)


# ------------------------------------------------ factor-once local loop ---

def _foof_local_perstep(task, hp, params, batches):
    """The seed's per-step-factorization local loop (reference)."""
    first = jax.tree.map(lambda x: x[0], batches)
    grams0 = task.grams(params, first)

    def step(theta, batch):
        loss, g = task.loss_grad(theta, batch)
        g = global_norm_clip(g, hp.clip)
        pre = F.precondition_tree(theta, g, grams0, damping=hp.damping,
                                  method=hp.inverse_method,
                                  ns_iters=hp.ns_iters, packed=False)
        return tree_axpy(-hp.lr, pre, theta), loss

    theta, losses = jax.lax.scan(step, params, batches)
    return theta


@pytest.fixture(scope="module")
def dnn_setup():
    data = make_clustered_classification(600, 16, 4, seed=0)
    ds = FederatedDataset.from_arrays(data, 4, alpha=0.5, seed=0)
    model = MLPModel(in_dim=16, hidden=(24,), num_classes=4)
    return ds, DNNTask(model)


def test_factor_once_matches_per_step_factorization(dnn_setup):
    """_foof_local with the cached packed factors must equal the seed's
    refactorize-every-step behaviour (same grams, same solves)."""
    ds, task = dnn_setup
    hp = HParams(lr=0.3, damping=1.0, local_steps=4)
    params = task.init(jax.random.PRNGKey(0))
    batches = build_round_batches(ds, 4, 16, np.random.default_rng(0))
    one = jax.tree.map(lambda x: x[0], batches)       # one client's K batches
    theta, _, _ = _foof_local(task, hp, params, one)
    theta_ref = _foof_local_perstep(task, hp, params, one)
    _assert_trees_close(theta, theta_ref, rtol=2e-4, atol=2e-5)


def test_fedpm_foof_round_packed_matches_reference(dnn_setup):
    """A full fedpm_foof round (client vmap + preconditioned mixing) on the
    packed bank matches a hand-built per-leaf round."""
    ds, task = dnn_setup
    hp = HParams(lr=0.3, damping=1.0)
    sim = FedSim(task, "fedpm_foof", hp, ds.n_clients)
    st_ = sim.init(jax.random.PRNGKey(0))
    params0 = jax.tree.map(jnp.copy, st_.params)  # round() donates st_
    batches = build_round_batches(ds, 3, 16, np.random.default_rng(0))
    new, _ = sim.round(st_, batches, jax.random.PRNGKey(1))
    # reference: per-leaf local loops + per-leaf mixing
    thetas, grams = [], []
    for c in range(ds.n_clients):
        cb = jax.tree.map(lambda x: x[c], batches)
        th = _foof_local_perstep(task, hp, params0, cb)
        last = jax.tree.map(lambda x: x[-1], cb)
        thetas.append(th)
        grams.append(task.grams(th, last))
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *thetas)
    gstack = jax.tree.map(lambda *xs: jnp.stack(xs), *grams)
    want = F.mix_preconditioned(stack, gstack, damping=hp.damping,
                                weights=jnp.ones((ds.n_clients,)),
                                packed=False)
    _assert_trees_close(new.params, want, rtol=2e-3, atol=2e-4)


# ------------------------------------------- structural: factorize once ----

def _count_cholesky(jaxpr, in_scan=False):
    """(outside_scan, inside_scan) cholesky-primitive counts, recursing
    through all sub-jaxprs."""
    out = np.zeros(2, dtype=int)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "cholesky":
            out[1 if in_scan else 0] += 1
        scan_here = in_scan or eqn.primitive.name == "scan"
        for v in eqn.params.values():
            for sub in jax.tree.leaves(v, is_leaf=lambda x: hasattr(x, "eqns")
                                       or hasattr(x, "jaxpr")):
                if hasattr(sub, "jaxpr"):
                    sub = sub.jaxpr
                if hasattr(sub, "eqns"):
                    out += _count_cholesky(sub, scan_here)
    return out


def test_one_factorization_per_round_regardless_of_k(dnn_setup):
    """fedpm_foof/localnewton_foof local loops: ALL cholesky factorizations
    sit outside the K-step scan — factorization count is K-independent."""
    ds, task = dnn_setup
    params = task.init(jax.random.PRNGKey(0))
    for k in (1, 4):
        batches = jax.tree.map(
            lambda x: x[0],
            build_round_batches(ds, k, 16, np.random.default_rng(0)))
        hp = HParams(lr=0.3, damping=1.0, inverse_method="cholesky")
        jaxpr = jax.make_jaxpr(
            lambda p, b: _foof_local(task, hp, p, b))(params, batches)
        outside, inside = _count_cholesky(jaxpr.jaxpr)
        assert inside == 0, f"K={k}: cholesky inside the local-step scan"
        assert outside >= 1, f"K={k}: no factorization at all?"


# ----------------------------------------------------- psum shard_map ------

_PSUM_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import foof as F
from repro.distributed.axes import make_auto_mesh, use_mesh, shard_map

S, nb, bs, dout, v = 8, 2, 8, 5, 11
rng = jax.random.PRNGKey(0)
m = jax.random.normal(rng, (S, nb, bs, bs))
a = jnp.einsum("snij,snkj->snik", m, m) / bs + 0.05 * jnp.eye(bs)
th = jax.random.normal(rng, (S, nb * bs, dout))
emb = jax.random.normal(rng, (S, v, 3))
cnt = jax.random.uniform(jax.random.PRNGKey(1), (S, v)) + 0.1
params = {"w": th, "embed": {"w": emb}}
grams = {"w": a, "embed": {"w": cnt}}
mesh = make_auto_mesh((8,), ("data",))

def mix(packed):
    def island(p, g):
        p0 = jax.tree.map(lambda x: x[0], p)      # this cohort's slice
        g0 = jax.tree.map(lambda x: x[0], g)
        return F.mix_preconditioned_psum(p0, g0, axes=("data",), damping=0.1,
                                         packed=packed)
    with use_mesh(mesh):
        return shard_map(island, mesh=mesh,
                         in_specs=(jax.tree.map(lambda _: P("data"), params),
                                   jax.tree.map(lambda _: P("data"), grams)),
                         out_specs=jax.tree.map(lambda _: P(), params),
                         axis_names={"data"}, check=False)(params, grams)

got, ref = mix(True), mix(False)
for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                               rtol=2e-4, atol=2e-5)
stacked = F.mix_preconditioned(params, grams, damping=0.1)
for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(stacked)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                               rtol=2e-4, atol=2e-5)
print("OK")
'''


def test_mix_psum_packed_matches_reference():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _PSUM_SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         capture_output=True, text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout

"""Quickstart: federated training with FedPM vs FedAvg in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Ten clients hold strongly heterogeneous (Dirichlet α=0.1) shards of a
synthetic 10-class problem; FedPM's preconditioned mixing converges in far
fewer rounds than FedAvg's simple mixing.
"""
import jax
import numpy as np

from repro.core.algorithms import HParams
from repro.data import FederatedDataset, make_clustered_classification
from repro.data.federated import build_round_batches, steps_per_epoch
from repro.fl.simulate import FedSim
from repro.fl.tasks import DNNTask
from repro.models.simple import MLPModel


def main(rounds: int = 10, n_clients: int = 10, alpha: float = 0.1):
    data = make_clustered_classification(6000, 64, 10, seed=0, spread=2.0)
    ds = FederatedDataset.from_arrays(data, n_clients, alpha=alpha, seed=0)
    task = DNNTask(MLPModel(in_dim=64, hidden=(128, 64), num_classes=10))
    test = ds.test_batch()
    k = steps_per_epoch(ds, 64) * 2              # 2 local epochs per round

    for algo, hp in [("fedavg", HParams(lr=0.1)),
                     ("fedpm_foof", HParams(lr=0.3, damping=1.0))]:
        sim = FedSim(task, algo, hp, n_clients)
        st = sim.init(jax.random.PRNGKey(0))
        r = np.random.default_rng(0)
        print(f"\n== {algo} (α={alpha}, {n_clients} clients, K={k}) ==")
        for t in range(rounds):
            batches = build_round_batches(ds, k, 64, r)
            st, m = sim.round(st, batches, jax.random.PRNGKey(t))
            acc = float(task.metric(st.params, test))
            print(f"round {t:2d}  client_loss={float(m['client_loss']):.3f}"
                  f"  test_acc={acc:.3f}")

    # Client sampling (Appendix D.2): S of N clients train each round.  The
    # engine gathers exactly the sampled cohort — compute scales with S,
    # and sampled-out clients' state is untouched.  The participant-aware
    # batch_fn builds batches for the cohort only.
    s = max(2, n_clients // 2)
    print(f"\n== fedpm_foof, sampling {s} of {n_clients} clients/round ==")
    sim = FedSim(task, "fedpm_foof", HParams(lr=0.3, damping=1.0), n_clients)
    _, hist = sim.run(
        jax.random.PRNGKey(0),
        lambda t, _k, clients: build_round_batches(
            ds, k, 64, np.random.default_rng(t), clients=clients),
        rounds=rounds, sample_clients=s,
        eval_fn=lambda p: task.metric(p, test))
    for t, acc in zip(hist["round"], hist["metric"]):
        print(f"round {t:2d}  test_acc={acc:.3f}")

    # The scan-compiled driver: attach a resident device bank to the task
    # and whole chunks of eval_every rounds compile into ONE lax.scan
    # program — cohorts and batches are drawn in-graph, so nothing
    # touches the host between evals (~4-5x rounds/sec at small sizes).
    print(f"\n== fedpm_foof, scan-compiled ({s} of {n_clients}/round) ==")
    banked = task.with_data(ds.device_bank(steps=k, batch=64))
    sim = FedSim(banked, "fedpm_foof", HParams(lr=0.3, damping=1.0),
                 n_clients)
    _, hist = sim.run_scanned(
        jax.random.PRNGKey(0), rounds, sample_clients=s,
        eval_every=max(1, rounds // 3),
        eval_fn=lambda p: task.metric(p, test))
    for t, acc in zip(hist["round"], hist["metric"]):
        print(f"round {t:2d}  test_acc={acc:.3f}")


if __name__ == "__main__":
    main()

"""Paper Test 1 (Fig. 1): superlinear convergence of FedPM on strongly
convex logistic regression with exact Hessians, K = 1.

    PYTHONPATH=src python examples/convex_superlinear.py [--dataset a9a|w8a]

Prints ‖θ_t − θ*‖ per round for 9 methods; FedPM and FedNL coincide
(Eq. 9 ≡ Eq. 6) and contract superlinearly, LocalNewton plateaus at the
bias of its locally preconditioned mixing, FO methods crawl.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import convex_setup, run_convex
from repro.core.algorithms import HParams

METHODS = {
    "psgd": HParams(lr=0.5),
    "fedavg": HParams(lr=0.5),
    "fedavgm": HParams(lr=0.5, momentum=0.9),
    "scaffold": HParams(lr=0.5),
    "fedadam": HParams(lr=0.3, server_lr=0.05),
    "fednl": HParams(lr=1.0, damping=0.0),
    "fedns": HParams(lr=1.0, damping=1e-3),
    "localnewton": HParams(lr=1.0, damping=0.0),
    "fedpm": HParams(lr=1.0, damping=0.0),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="a9a", choices=["a9a", "w8a"])
    ap.add_argument("--rounds", type=int, default=10)
    args = ap.parse_args()
    setup = convex_setup(args.dataset)
    print(f"dataset={args.dataset} d={setup['d']} "
          f"clients={setup['ds'].n_clients} f*={setup['f_star']:.6f}")
    print(f"{'method':12s} " + " ".join(f"r{t:<8d}" for t in
                                        range(args.rounds)))
    for algo, hp in METHODS.items():
        errs, _, _ = run_convex(setup, algo, hp, args.rounds)
        print(f"{algo:12s} " + " ".join(f"{e:<9.2e}" for e in errs))


if __name__ == "__main__":
    main()

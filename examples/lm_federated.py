"""End-to-end driver: federated language-model training with the
production engine (fused-K1 FedPM rounds under jit/GSPMD), checkpointing
and periodic eval.

    PYTHONPATH=src python examples/lm_federated.py                 # smoke
    PYTHONPATH=src python examples/lm_federated.py --preset 100m --steps 300

``--preset 100m`` builds a ~100M-param OLMo-family decoder (the spec's
end-to-end target; a few hundred steps ≈ hours on this 1-core CPU
container, minutes on a real host — the default preset runs the identical
code path at smoke scale).  ``--mode local_steps --k 4`` switches to the
shard_map K>1 FedPM round.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import get_config
from repro.core.algorithms import HParams
from repro.data import make_lm_tokens
from repro.fl import distributed as D
from repro.models import transformer as T


def build_config(preset: str):
    base = get_config("olmo-1b")
    if preset == "smoke":
        return base.reduced()
    if preset == "100m":
        return dataclasses.replace(
            base, name="olmo-100m", num_layers=8, d_model=768, num_heads=12,
            num_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=32000,
            dtype="float32", foof_block=768)
    raise ValueError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--algo", default="fedpm", choices=["fedpm", "fedavg"])
    ap.add_argument("--mode", default="fused_k1",
                    choices=["fused_k1", "local_steps"])
    ap.add_argument("--k", type=int, default=4, help="local steps (K>1 mode)")
    ap.add_argument("--lr", type=float, default=0.2)
    ap.add_argument("--damping", type=float, default=1.0)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--eval-every", type=int, default=20)
    args = ap.parse_args()

    cfg = build_config(args.preset)
    hp = HParams(lr=args.lr, damping=args.damping, clip=1.0)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(cfg, rng)
    n_params = T.count_params(params)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M vocab={cfg.vocab_size}")

    stream = make_lm_tokens(cfg.vocab_size, args.steps * args.batch
                            * args.seq + args.seq, seed=0)
    held = make_lm_tokens(cfg.vocab_size, 4 * args.seq, seed=1)
    held_batch = {"tokens": jnp.asarray(held[:4 * args.seq]).reshape(
        4, args.seq)}
    held_batch["labels"] = held_batch["tokens"]

    if args.mode == "fused_k1":
        step = jax.jit(D.make_fused_k1_step(cfg, hp) if args.algo == "fedpm"
                       else D.make_fedavg_step(cfg, hp), donate_argnums=0)
    else:
        from repro.distributed.axes import make_auto_mesh, use_mesh
        mesh = make_auto_mesh((jax.device_count(), 1), ("data", "model"))
        rnd = D.make_local_steps_round(cfg, hp, mesh, k_steps=args.k)
        ctx = use_mesh(mesh)
        ctx.__enter__()
        step = jax.jit(rnd)
    eval_loss = jax.jit(lambda p: T.loss_fn(cfg, p, held_batch)[0])

    bs = args.batch * (args.k if args.mode == "local_steps" else 1)
    t0 = time.time()
    for t in range(args.steps):
        lo = t * bs * args.seq
        toks = jnp.asarray(stream[lo:lo + bs * args.seq]).reshape(
            bs, args.seq)
        batch = {"tokens": toks, "labels": toks}
        params, m = step(params, batch)
        if t % args.eval_every == 0 or t == args.steps - 1:
            ev = float(eval_loss(params))
            print(f"step {t:4d}  train_loss={float(m['loss']):.4f}  "
                  f"eval_loss={ev:.4f}  ({time.time()-t0:.1f}s)", flush=True)
    checkpoint.save(args.ckpt, params,
                    meta={"arch": cfg.name, "steps": args.steps,
                          "algo": args.algo, "mode": args.mode})
    print(f"checkpoint written to {args.ckpt}.npz")


if __name__ == "__main__":
    main()

"""Batched serving: prefill a batch of prompts, then decode new tokens
against the KV/SSM cache — the serve_step the decode_32k/long_500k dry-run
shapes lower at production scale.

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-1.3b
    PYTHONPATH=src python examples/serve_decode.py --arch gemma3-12b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.data import make_lm_tokens
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    if cfg.frontend != "none":
        raise SystemExit("serve example uses token-input archs; "
                         "pick a dense/ssm/hybrid/moe arch")
    rng = jax.random.PRNGKey(0)
    params = T.init_params(cfg, rng)
    total = args.prompt_len + args.max_new
    prompts = jnp.asarray(make_lm_tokens(
        cfg.vocab_size, args.batch * args.prompt_len, seed=0)).reshape(
        args.batch, args.prompt_len)

    # ---- prefill ----
    prefill = jax.jit(lambda p, b: T.prefill(cfg, p, b))
    t0 = time.time()
    x_last, cache = prefill(params, {"tokens": prompts})
    cache = jax.tree.map(  # grow seq dims to the serving horizon
        lambda leaf: _grow(leaf, args.prompt_len, total), cache)
    logits = (x_last @ params["head"]["w"]).astype(jnp.float32)
    next_tok = jnp.argmax(logits[:, 0], axis=-1)[:, None]
    jax.block_until_ready(next_tok)
    t_prefill = time.time() - t0
    print(f"arch={cfg.name}  prefill {args.batch}×{args.prompt_len} tokens "
          f"in {t_prefill*1e3:.0f} ms")

    # ---- decode loop ----
    decode = jax.jit(lambda p, c, b, pos: T.decode_step(cfg, p, c, b, pos))
    out = [next_tok]
    t0 = time.time()
    for i in range(args.max_new - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(params, cache, {"tokens": out[-1]}, pos)
        nxt = jnp.argmax(logits[:, 0, :cfg.vocab_size], axis=-1)[:, None]
        out.append(nxt.astype(jnp.int32))
    jax.block_until_ready(out[-1])
    dt = time.time() - t0
    toks = args.batch * (args.max_new - 1)
    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"decoded {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on 1 CPU core)")
    print("first continuation:", gen[0][:16].tolist())


def _grow(leaf, have, want):
    for axis in range(leaf.ndim):
        if leaf.shape[axis] == have:
            pads = [(0, 0)] * leaf.ndim
            pads[axis] = (0, want - have)
            return jnp.pad(leaf, pads)
    return leaf


if __name__ == "__main__":
    main()

"""npz + json-manifest checkpointing for param/opt-state pytrees.

Flat key paths ("blocks/attn/wqkv") map leaves into a single .npz; the
manifest records tree structure, dtypes, round index and config name so a
restore round-trips exactly (tested)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}{_SEP}{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}{_SEP}#{i}" if prefix else f"#{i}", v)
        else:
            flat[prefix] = np.asarray(node)

    walk("", tree)
    return flat


def save(path: str, tree: PyTree, *, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path + ".npz", **flat)
    structure = jax.tree.structure(tree)
    manifest = {
        "keys": sorted(flat),
        "treedef": str(structure),
        "meta": meta or {},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    data = np.load(path + ".npz")

    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}{_SEP}{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            vals = [walk(f"{prefix}{_SEP}#{i}" if prefix else f"#{i}", v)
                    for i, v in enumerate(node)]
            return type(node)(vals)
        arr = data[prefix]
        if tuple(arr.shape) != tuple(node.shape):
            raise ValueError(f"shape mismatch at {prefix}: "
                             f"{arr.shape} vs {node.shape}")
        return jnp.asarray(arr, dtype=node.dtype)

    return walk("", like)


def load_meta(path: str) -> dict:
    with open(path + ".json") as f:
        return json.load(f).get("meta", {})

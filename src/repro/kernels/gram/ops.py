"""jit'd public wrapper for the gram kernel (CPU: interpret=True)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.gram.gram import gram_blocks
from repro.kernels.gram.ref import gram_blocks_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block", "damping", "use_pallas"))
def gram(x: jax.Array, block: int, *, damping: float = 0.0,
         use_pallas: bool | None = None) -> jax.Array:
    """Blocked FOOF gram of x [..., T, d] → [..., d/block, block, block]
    fp32.

    Leading dims (e.g. a gathered client axis or a stacked layer axis) are
    vmapped into the kernel grid — one launch builds the whole gram bank.
    Pads T to the tile size when needed (padding rows are zeros → exact:
    the 1/T scale uses the true T via pre-scaling)."""
    if x.ndim > 2:
        lead = x.shape[:-2]
        flat = x.reshape((-1,) + x.shape[-2:])
        out = jax.vmap(lambda xx: gram(xx, block, damping=damping,
                                       use_pallas=use_pallas))(flat)
        return out.reshape(*lead, *out.shape[-3:])
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    t, d = x.shape
    if not use_pallas and not _interpret_ok(t, d, block):
        return gram_blocks_ref(x, block, damping=damping)
    tb = 512 if t >= 512 else t
    pad = (-t) % tb
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)])
        # zeros contribute nothing; rescale the mean to the padded length
        a = gram_blocks(x, block, damping=0.0, t_block=tb,
                        interpret=not _on_tpu())
        a = a * ((t + pad) / t)
        if damping:
            a = a + damping * jnp.eye(block, dtype=jnp.float32)
        return a
    return gram_blocks(x, block, damping=damping, t_block=tb,
                       interpret=not _on_tpu())


def _interpret_ok(t, d, block) -> bool:
    # interpret mode is Python-slow; cap the work it sees in tests
    return t * d <= 1 << 22

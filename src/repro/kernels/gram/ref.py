"""Pure-jnp oracle for the gram kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_blocks_ref(x: jax.Array, block: int, *, damping: float = 0.0
                    ) -> jax.Array:
    t, d = x.shape
    nb = d // block
    xb = x.reshape(t, nb, block)
    a = jnp.einsum("tnb,tnc->nbc", xb, xb,
                   preferred_element_type=jnp.float32) / jnp.float32(t)
    if damping:
        a = a + damping * jnp.eye(block, dtype=jnp.float32)
    return a

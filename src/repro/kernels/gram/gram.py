"""Pallas TPU kernel: blocked FOOF gram construction  A = (1/T)·XᵀX + λI.

The FedPM hot loop (DESIGN.md §4.3): every linear layer's preconditioner is
the uncentered input covariance, block-diagonal within the layer.  This
kernel computes the diagonal blocks A_n = X_nᵀX_n for X_n = X[:, n·bs:(n+1)·bs]
by streaming T in tiles of ``t_block`` rows through VMEM and accumulating
each [bs, bs] output block in fp32 on the MXU; the 1/T scale and the λI
damping are fused into the final grid step (no extra HBM pass).

Grid: (nb, T/t_block) — the token axis is the minor (sequential) dimension,
so each output block accumulates in place across its token tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, o_ref, *, nsteps: int, inv_t: float, damping: float):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                    # [t_block, bs]
    o_ref[...] += jax.lax.dot_general(
        x, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None]

    @pl.when(t == nsteps - 1)
    def _finish():
        bs = o_ref.shape[-1]
        eye = jnp.eye(bs, dtype=jnp.float32)
        o_ref[...] = o_ref[...] * inv_t + damping * eye[None]


def gram_blocks(x: jax.Array, block: int, *, damping: float = 0.0,
                t_block: int = 512, interpret: bool = False) -> jax.Array:
    """x: [T, d] (d = nb·block) → [nb, block, block] fp32.

    VMEM per step: t_block·block·(x dtype) + block²·4 ≤ ~6 MB at the default
    shapes (512×1024 bf16 + 1024² fp32) — fits v5e VMEM with double buffering.
    """
    t, d = x.shape
    assert d % block == 0, (d, block)
    nb = d // block
    tb = min(t_block, t)
    assert t % tb == 0, (t, tb)
    nsteps = t // tb

    kernel = functools.partial(_gram_kernel, nsteps=nsteps,
                               inv_t=1.0 / t, damping=damping)
    return pl.pallas_call(
        kernel,
        grid=(nb, nsteps),
        in_specs=[pl.BlockSpec((tb, block), lambda n, s: (s, n))],
        out_specs=pl.BlockSpec((1, block, block), lambda n, s: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block, block), jnp.float32),
        interpret=interpret,
    )(x)

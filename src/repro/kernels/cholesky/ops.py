"""jit'd public wrapper for the blocked-Cholesky factor+solve kernel.

Dispatch (roofline-driven, see benchmarks/bench_roofline.py):

* **TPU** — the Pallas kernel: Schur-recursive inversion in VMEM, g blocks
  per grid step sized to the 128-wide MXU, RHS zero-padded to the lane.
* **CPU, default** — the same Schur restructuring as plain jnp with LAPACK
  leaf tiles: batched matmuls replace batched trsm (which XLA:CPU runs
  ~4.7x slower than an equivalent-shape matmul), a ~2x win at bs=128.
  Below bs=64 the triangular work no longer dominates and the LAPACK
  reference is used unchanged.
* **CPU, ``use_pallas=True``** — the kernel in interpret mode (correctness
  coverage of the exact TPU program; Python-slow, so work is capped).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.inverse import damp as _damp
from repro.kernels.cholesky.cholesky import (chol_inverse_blocks,
                                             chol_solve_blocks, spd_inverse)
from repro.kernels.cholesky.ref import chol_inverse_ref, chol_solve_ref

_MXU_LANE = 128
_TILE = 32
#: CPU crossover: below this block size LAPACK's serial triangular work no
#: longer dominates and the Schur restructuring ties instead of winning
_SCHUR_MIN_BS = 65


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_ok(nb: int, bs: int) -> bool:
    # interpret mode is Python-slow and the base case is a fori_loop; cap
    # the work tests can push through it
    return bs <= 256 and nb * bs ** 3 <= 1 << 25


def _pick_g(nb: int, bs: int, kp: int) -> int:
    """Blocks per grid step: whole bank on CPU (interpret pays per-step
    Python overhead), MXU/VMEM-budgeted divisor of nb on TPU."""
    if not _on_tpu():
        return nb
    budget = (12 * 2 ** 20) // (4 * (2 * bs * bs + 2 * bs * max(kp, 1)))
    target = max(1, min(_MXU_LANE // bs, budget))
    g = 1
    for d in range(2, min(nb, target) + 1):
        if nb % d == 0:
            g = d
    return g


def _schur_cpu(a: jax.Array, damping: float) -> jax.Array:
    """CPU Schur path: LAPACK only sees [_TILE,_TILE] diagonal leaves."""
    return spd_inverse(_damp(a.astype(jnp.float32), damping), tile=_TILE,
                       base=chol_inverse_ref)


@partial(jax.jit, static_argnames=("damping", "use_pallas"))
def chol_inverse(a: jax.Array, *, damping: float = 0.0,
                 use_pallas: bool | None = None) -> jax.Array:
    """Batched (A+δI)⁻¹ of SPD a [..., bs, bs] via blocked Cholesky.

    Leading dims flatten into the kernel grid."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    bs = a.shape[-1]
    lead = a.shape[:-2]
    nb = 1
    for d in lead:
        nb *= d
    if not use_pallas or bs > 1024:
        if bs >= _SCHUR_MIN_BS:
            return _schur_cpu(a, damping)
        return chol_inverse_ref(a, damping=damping)
    if not _on_tpu() and not _interpret_ok(nb, bs):
        return _schur_cpu(a, damping) if bs >= _SCHUR_MIN_BS else \
            chol_inverse_ref(a, damping=damping)
    flat = a.reshape(-1, bs, bs)
    out = chol_inverse_blocks(flat, damping=damping, tile=_TILE,
                              g=_pick_g(max(nb, 1), bs, bs),
                              interpret=not _on_tpu())
    return out.reshape(*lead, bs, bs)


@partial(jax.jit, static_argnames=("damping", "use_pallas"))
def chol_solve(a: jax.Array, b: jax.Array, *, damping: float = 0.0,
               use_pallas: bool | None = None) -> jax.Array:
    """Fused batched (A+δI)⁻¹ @ B over a packed bank [..., bs, bs] /
    [..., bs, k]: the inverse is built in VMEM and never round-trips HBM.

    The RHS lane is zero-padded to the 128-wide MXU tile on TPU (exact:
    zero columns cannot perturb X@B) and sliced back after.  Mismatched
    leading dims (one A applied to many B) route through chol_inverse + a
    broadcasting matmul."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    bs, k = a.shape[-1], b.shape[-1]
    kp = -(-k // _MXU_LANE) * _MXU_LANE if _on_tpu() else k
    lead = a.shape[:-2]
    if lead != b.shape[:-2]:
        x = chol_inverse(a, damping=damping, use_pallas=use_pallas)
        return x @ b.astype(jnp.float32)
    nb = 1
    for d in lead:
        nb *= d
    if not use_pallas or bs > 1024:
        if bs >= _SCHUR_MIN_BS:
            return _schur_cpu(a, damping) @ b.astype(jnp.float32)
        return chol_solve_ref(a, b, damping=damping)
    if not _on_tpu() and not _interpret_ok(nb, bs):
        x = chol_inverse(a, damping=damping, use_pallas=False)
        return x @ b.astype(jnp.float32)
    bp = b if kp == k else jnp.concatenate(
        [b, jnp.zeros((*lead, bs, kp - k), b.dtype)], axis=-1)
    out = chol_solve_blocks(a.reshape(-1, bs, bs), bp.reshape(-1, bs, kp),
                            damping=damping, tile=_TILE,
                            g=_pick_g(max(nb, 1), bs, kp),
                            interpret=not _on_tpu())
    return out.reshape(*lead, bs, kp)[..., :k]

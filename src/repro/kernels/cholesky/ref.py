"""LAPACK oracle for the blocked-Cholesky kernel: ``cho_factor`` +
``cho_solve`` — the paper's (and the seed repo's) exact solve path."""
from __future__ import annotations

import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve

from repro.core.inverse import damp


def chol_solve_ref(a, b, *, damping: float = 0.0):
    ad = damp(a.astype(jnp.float32), damping) if damping else a
    c, lower = cho_factor(ad, lower=True)
    return cho_solve((c, lower), b.astype(jnp.float32))


def chol_inverse_ref(a, *, damping: float = 0.0):
    eye = jnp.broadcast_to(jnp.eye(a.shape[-1], dtype=jnp.float32), a.shape)
    return chol_solve_ref(a, eye, damping=damping)

"""Pallas TPU kernel: batched blocked SPD factor+solve for the gram bank.

The paper Cholesky-factorizes its FOOF blocks on H100; LAPACK-style
``cho_factor``/``cho_solve`` serializes into triangular sweeps that leave
the MXU idle (and on CPU, batched trsm costs ~4.7x an equivalent-shape
matmul).  This kernel restructures the solve as a *Schur-complement
recursive inversion*: an SPD block splits 2x2,

    inv([[A11, A21ᵀ], [A21, A22]]):
        I11 = inv(A11)            W   = A21 @ I11
        S   = A22 - W @ A21ᵀ      I22 = inv(S)
        B21 = -I22 @ W            B11 = I11 - Wᵀ @ B21

so all O(bs³) work lands in batched matmuls (MXU-tileable) and only the
tiny ``tile``-sized diagonal base problems run a serial column-Cholesky.
The recursion is unrolled at trace time (bs is static) down to
``tile``-sized leaves; inside the kernel the base case factors L and
accumulates L⁻¹ jointly in one fori_loop (rank-1 downdates — no
triangular solve primitive exists in Pallas).

The fused solve kernel consumes the packed RHS bank directly: X = (A+δI)⁻¹
is built in VMEM and only X@B is written back — like the Newton–Schulz
kernel, the inverse never round-trips through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bmm(p, q):
    """Batched matmul over matching leading dims, fp32 accumulation."""
    nd = p.ndim
    dn = (((nd - 1,), (nd - 2,)), (tuple(range(nd - 2)),) * 2)
    return jax.lax.dot_general(p, q, dn, preferred_element_type=jnp.float32)


def _swap(p):
    return jnp.swapaxes(p, -1, -2)


def _tile_inverse(a):
    """inv(a) for SPD a [..., T, T] — serial column-Cholesky computing L and
    L⁻¹ jointly (rank-1 downdates only; Pallas-safe, no LAPACK)."""
    t = a.shape[-1]
    lead = a.shape[:-2]
    rows = jax.lax.broadcasted_iota(jnp.int32, (*lead, t, 1), a.ndim - 2)
    m0 = jnp.broadcast_to(jnp.eye(t, dtype=jnp.float32), a.shape)

    def body(i, carry):
        a, m, linv = carry
        col = jax.lax.dynamic_slice_in_dim(a, i, 1, axis=-1)    # [.., T, 1]
        dii = jax.lax.dynamic_slice_in_dim(col, i, 1, axis=-2)  # [.., 1, 1]
        d = jax.lax.rsqrt(dii)
        c = jnp.where(rows >= i, col, 0.0) * d                  # L[:, i]
        a = a - _bmm(c, _swap(c))
        ri = jax.lax.dynamic_slice_in_dim(m, i, 1, axis=-2) * d  # L⁻¹[i, :]
        m = m - _bmm(c, ri)          # zeroes row i, eliminates below
        linv = linv + _bmm((rows == i).astype(jnp.float32), ri)
        return a, m, linv

    _, _, linv = jax.lax.fori_loop(
        0, t, body, (a, m0, jnp.zeros_like(a)))
    return _bmm(_swap(linv), linv)


def spd_inverse(a, *, tile: int = 32, base=None):
    """inv(a) for SPD a [..., bs, bs] via recursive 2x2 Schur splits.

    Trace-time recursion: only ``tile``-sized diagonal problems reach the
    serial base case; everything else is batched matmuls.  ``base``
    overrides the leaf inverse (the CPU dispatch path substitutes LAPACK
    — same structure, faster leaves — while the kernel uses the
    Pallas-safe column-Cholesky).  Odd sizes split floor/ceil, so any bs
    works (200 → 100 → 50 → 25).
    """
    base = _tile_inverse if base is None else base
    bs = a.shape[-1]
    if bs <= tile:
        return base(a)
    h = bs // 2
    a11 = a[..., :h, :h]
    a21 = a[..., h:, :h]
    a22 = a[..., h:, h:]
    i11 = spd_inverse(a11, tile=tile, base=base)
    w = _bmm(a21, i11)
    s = a22 - _bmm(w, _swap(a21))
    i22 = spd_inverse(s, tile=tile, base=base)
    b21 = -_bmm(i22, w)
    b11 = i11 - _bmm(_swap(w), b21)
    top = jnp.concatenate([b11, _swap(b21)], axis=-1)
    bot = jnp.concatenate([b21, i22], axis=-1)
    return jnp.concatenate([top, bot], axis=-2)


def _damped(a_ref, damping: float):
    a = a_ref[...].astype(jnp.float32)
    if damping:
        a = a + damping * jnp.eye(a.shape[-1], dtype=jnp.float32)
    return a


def _chol_inverse_kernel(a_ref, o_ref, *, damping: float, tile: int):
    o_ref[...] = spd_inverse(_damped(a_ref, damping), tile=tile)


def _chol_solve_kernel(a_ref, b_ref, o_ref, *, damping: float, tile: int):
    x = spd_inverse(_damped(a_ref, damping), tile=tile)
    o_ref[...] = _bmm(x, b_ref[...].astype(jnp.float32))


def chol_inverse_blocks(a: jax.Array, *, damping: float = 0.0,
                        tile: int = 32, g: int = 1,
                        interpret: bool = False) -> jax.Array:
    """a: [nb, bs, bs] SPD blocks → (A+δI)⁻¹ [nb, bs, bs] fp32.

    ``g`` blocks per grid step (must divide nb) — the batched base-case
    factorizations and Schur matmuls then cover g blocks per launch."""
    nb, bs, _ = a.shape
    kernel = functools.partial(_chol_inverse_kernel, damping=damping,
                               tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(nb // g,),
        in_specs=[pl.BlockSpec((g, bs, bs), lambda n: (n, 0, 0))],
        out_specs=pl.BlockSpec((g, bs, bs), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs, bs), jnp.float32),
        interpret=interpret,
    )(a)


def chol_solve_blocks(a: jax.Array, b: jax.Array, *, damping: float = 0.0,
                      tile: int = 32, g: int = 1,
                      interpret: bool = False) -> jax.Array:
    """Fused factor-and-apply: X = (A+δI)⁻¹ stays in VMEM, only X@B is
    written (HBM traffic: read A, read B, write X@B).

    a: [nb, bs, bs] SPD blocks; b: [nb, bs, k] → [nb, bs, k] fp32."""
    nb, bs, _ = a.shape
    k = b.shape[-1]
    kernel = functools.partial(_chol_solve_kernel, damping=damping, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(nb // g,),
        in_specs=[pl.BlockSpec((g, bs, bs), lambda n: (n, 0, 0)),
                  pl.BlockSpec((g, bs, k), lambda n: (n, 0, 0))],
        out_specs=pl.BlockSpec((g, bs, k), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs, k), jnp.float32),
        interpret=interpret,
    )(a, b)

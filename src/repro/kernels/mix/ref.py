"""Pure-jnp oracle for the fused mix kernel: the unfused reduce → invert →
apply chain (same math as ``repro.core.bank._mix_engine``'s per-group
job, with num/Ā/X round-tripping memory between stages)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.inverse import solve


def mix_ref(a_stack, t_stack, w, *, damping: float, method: str = "cholesky",
            iters: int = 20):
    """a_stack [S, R, bs, bs], t_stack [S, R, bs, k], w [S] → [R, bs, k]."""
    bs = a_stack.shape[-1]
    af = a_stack.astype(jnp.float32)
    tf = t_stack.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    eye = damping * jnp.eye(bs, dtype=jnp.float32)
    num = jnp.tensordot(wf, (af + eye) @ tf, axes=1)
    abar = jnp.tensordot(wf, af, axes=1)
    return solve(abar, num, damping=damping, method=method, ns_iters=iters)

"""jit'd public wrapper for the fused weighted-mix-then-precondition
kernel (server-side Eq. 12 over the packed client-message bank)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.mix.mix import mix_blocks
from repro.kernels.mix.ref import mix_ref
from repro.kernels.nschulz.nschulz import DEFAULT_TOL

_MXU_LANE = 128
_TILE = 32


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_ok(s: int, r: int, bs: int, k: int, solver: str) -> bool:
    # interpret mode is Python-slow; the chol solver additionally runs the
    # serial base-case fori per Schur leaf, so it gets a tighter cap
    work = s * r * bs * (bs + k)
    return work <= (1 << 22 if solver == "ns" else 1 << 19) and bs <= 256


def _pick_g(r: int, bs: int, s: int, kp: int) -> int:
    """Rows per grid step: the whole group off-TPU (one big batched grid
    step — small g drowns in interpret per-step overhead), VMEM-budgeted
    divisor of r on TPU (the [S, g, bs, ·] slabs must fit alongside the
    accumulators)."""
    if not _on_tpu():
        return r
    per_row = 4 * (s + 2) * (bs * bs + bs * max(kp, 1))
    budget = max(1, (12 * 2 ** 20) // per_row)
    target = max(1, min(_MXU_LANE // bs, budget))
    g = 1
    for d in range(2, min(r, target) + 1):
        if r % d == 0:
            g = d
    return g


@partial(jax.jit, static_argnames=("damping", "iters", "tol", "solver",
                                   "use_pallas"))
def mix_precond(a_stack: jax.Array, t_stack: jax.Array, w: jax.Array, *,
                damping: float, iters: int = 25, tol: float = DEFAULT_TOL,
                solver: str = "ns",
                use_pallas: bool | None = None) -> jax.Array:
    """Fused FedPM preconditioned mixing over a stacked client bank:
    (Σw(A+δI)Θ, Σw A, inverse, apply) in one launch per block-size group.

    a_stack: [S, R, bs, bs]; t_stack: [S, R, bs, k]; w: [S] (normalized)
    → [R, bs, k] fp32.  ``solver``: "ns" (adaptive Newton–Schulz) or
    "chol" (Schur-recursive blocked Cholesky).  Off-TPU the kernel runs
    in interpret mode within work caps; past them — and for the serial
    chol base case, whose interpret cost is prohibitive — the unfused jnp
    reference takes over (same math, staged through memory)."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    s, r, bs, _ = a_stack.shape
    k = t_stack.shape[-1]
    kp = -(-k // _MXU_LANE) * _MXU_LANE if _on_tpu() else k
    ref_method = "cholesky" if solver == "chol" else "ns"
    if not use_pallas and not _interpret_ok(s, r, bs, kp, solver):
        return mix_ref(a_stack, t_stack, w, damping=damping,
                       method=ref_method, iters=iters)
    vmem = 4 * ((s + 2) * (bs * bs + bs * kp))
    if bs > 1024 or vmem > 12 * 2 ** 20:
        return mix_ref(a_stack, t_stack, w, damping=damping,
                       method=ref_method, iters=iters)
    tp = t_stack if kp == k else jnp.concatenate(
        [t_stack, jnp.zeros((s, r, bs, kp - k), t_stack.dtype)], axis=-1)
    out = mix_blocks(a_stack, tp, w, damping=damping, iters=iters, tol=tol,
                     solver=solver, tile=_TILE, g=_pick_g(r, bs, s, kp),
                     interpret=not _on_tpu())
    return out[..., :k]

"""Pallas TPU kernel: fused FedPM preconditioned mixing (Eq. 12).

Server-side mixing consumes the stacked client message bank directly:
per block-size group the unfused path runs four launches —

    num  = Σ_s w_s (A_s+δI) Θ_s      (batched matmul, then reduce)
    Ā    = Σ_s w_s A_s               (reduce)
    X    = (Ā+δI)⁻¹                  (inverse)
    out  = X @ num                   (matmul)

— with num/Ā/X all round-tripping HBM between launches.  This kernel does
the whole chain in ONE launch per group: the [S, g, bs, ·] client slabs
stream into VMEM once, the weighted reductions, the inverse (adaptive
Newton–Schulz or Schur-recursive Cholesky, both in-VMEM) and the final
apply happen in registers, and only the mixed [g, bs, k] block leaves.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.cholesky.cholesky import spd_inverse
from repro.kernels.nschulz.nschulz import DEFAULT_TOL, _bmm, _ns_iterate


def _mix_kernel(w_ref, a_ref, t_ref, o_ref, *, damping: float, iters: int,
                tol: float, solver: str, tile: int):
    # blocks: w [S], a [S, g, bs, bs], t [S, g, bs, k]
    w = w_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    t = t_ref[...].astype(jnp.float32)
    bs = a.shape[-1]
    eye = damping * jnp.eye(bs, dtype=jnp.float32)
    # Σ_s w_s (A_s+δI) Θ_s : per-client matmul batched over (S, g), then
    # one weighted contraction over S
    at = jax.lax.dot_general(a + eye, t, (((3,), (2,)), ((0, 1), (0, 1))),
                             preferred_element_type=jnp.float32)
    num = jnp.tensordot(w, at, axes=1)              # [g, bs, k]
    abar = jnp.tensordot(w, a, axes=1)              # [g, bs, bs]
    if solver == "chol":
        x = spd_inverse(abar + eye, tile=tile)
    else:
        x = _ns_iterate(abar, iters, damping, tol)
    o_ref[...] = _bmm(x, num)


def mix_blocks(a_stack: jax.Array, t_stack: jax.Array, w: jax.Array, *,
               damping: float, iters: int = 25, tol: float = DEFAULT_TOL,
               solver: str = "ns", tile: int = 32, g: int = 1,
               interpret: bool = False) -> jax.Array:
    """Fused weighted-mix-then-precondition over a stacked client bank.

    a_stack: [S, R, bs, bs] client gram banks; t_stack: [S, R, bs, k]
    packed client params; w: [S] normalized weights → mixed [R, bs, k]
    fp32.  ``g`` rows per grid step (must divide R)."""
    s, r, bs, _ = a_stack.shape
    k = t_stack.shape[-1]
    kernel = functools.partial(_mix_kernel, damping=damping, iters=iters,
                               tol=tol, solver=solver, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(r // g,),
        in_specs=[pl.BlockSpec((s,), lambda n: (0,)),
                  pl.BlockSpec((s, g, bs, bs), lambda n: (0, n, 0, 0)),
                  pl.BlockSpec((s, g, bs, k), lambda n: (0, n, 0, 0))],
        out_specs=pl.BlockSpec((g, bs, k), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, bs, k), jnp.float32),
        interpret=interpret,
    )(w, a_stack, t_stack)

"""Pure-jnp oracle for the Newton–Schulz kernel (same math as
repro.core.inverse.ns_inverse)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.inverse import damp, ns_inverse


def ns_inverse_ref(a, *, iters: int = 20, damping: float = 0.0):
    ad = damp(a.astype(jnp.float32), damping) if damping else a
    return ns_inverse(ad, iters)


def ns_solve_ref(a, b, *, iters: int = 20, damping: float = 0.0):
    """Oracle for the fused invert-and-apply kernel: explicit inverse then
    matmul (same math, inverse round-trips through memory)."""
    return ns_inverse_ref(a, iters=iters, damping=damping) @ b.astype(
        jnp.float32)

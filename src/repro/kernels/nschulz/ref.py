"""Pure-jnp oracle for the Newton–Schulz kernel (same math as
repro.core.inverse.ns_inverse)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.inverse import damp, ns_inverse


def ns_inverse_ref(a, *, iters: int = 20, damping: float = 0.0):
    ad = damp(a.astype(jnp.float32), damping) if damping else a
    return ns_inverse(ad, iters)

"""Pallas TPU kernel: fused Newton–Schulz SPD inverse.

TPU adaptation of FedPM's preconditioner inversion (DESIGN.md §4.1): the
paper Cholesky-factorizes on H100; triangular solves serialize badly on the
MXU, so we iterate  X ← X(2I − AX)  — two 128-aligned matmuls per step.

The WHOLE iteration runs inside one kernel invocation: A and X stay
resident in VMEM across all ``iters`` steps, so HBM sees exactly one read
of A and one write of X (a jnp scan pays 2·iters round-trips).  Grid is the
block-batch dimension; each program inverts one [bs, bs] FOOF block
(bs ≤ 1024 → A, X, AX ≤ 12 MB fp32 in VMEM).

Init X₀ = Aᵀ/(‖A‖₁‖A‖∞) guarantees ‖I − AX₀‖ < 1 → quadratic convergence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ns_iterate(a, iters: int, damping: float):
    """Newton–Schulz X ≈ A⁻¹ entirely in VMEM registers; shared by the
    inverse kernel and the fused invert-and-apply kernel."""
    bs = a.shape[-1]
    eye = jnp.eye(bs, dtype=jnp.float32)
    if damping:
        a = a + damping * eye
    n_inf = jnp.max(jnp.sum(jnp.abs(a), axis=-1))
    n_one = jnp.max(jnp.sum(jnp.abs(a), axis=-2))
    x = a.T / (n_inf * n_one)

    def body(_, x):
        ax = jax.lax.dot_general(a, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        return jax.lax.dot_general(x, 2.0 * eye - ax,
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    return jax.lax.fori_loop(0, iters, body, x)


def _ns_kernel(a_ref, o_ref, *, iters: int, damping: float):
    o_ref[0] = _ns_iterate(a_ref[0].astype(jnp.float32), iters, damping)


def _ns_solve_kernel(a_ref, b_ref, o_ref, *, iters: int, damping: float):
    x = _ns_iterate(a_ref[0].astype(jnp.float32), iters, damping)
    o_ref[0] = jax.lax.dot_general(x, b_ref[0].astype(jnp.float32),
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)


def ns_inverse_blocks(a: jax.Array, *, iters: int = 20, damping: float = 0.0,
                      interpret: bool = False) -> jax.Array:
    """a: [nb, bs, bs] SPD blocks → approximate inverses [nb, bs, bs] fp32."""
    nb, bs, _ = a.shape
    kernel = functools.partial(_ns_kernel, iters=iters, damping=damping)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, bs, bs), lambda n: (n, 0, 0))],
        out_specs=pl.BlockSpec((1, bs, bs), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs, bs), jnp.float32),
        interpret=interpret,
    )(a)


def ns_solve_blocks(a: jax.Array, b: jax.Array, *, iters: int = 20,
                    damping: float = 0.0, interpret: bool = False
                    ) -> jax.Array:
    """Fused invert-and-apply over a packed gram bank: per grid step,
    iterate X ≈ (A+δI)⁻¹ in VMEM and write only X@B — the inverse never
    round-trips through HBM (HBM traffic: read A, read B, write X@B).

    a: [nb, bs, bs] SPD blocks; b: [nb, bs, k] → [nb, bs, k] fp32.
    """
    nb, bs, _ = a.shape
    k = b.shape[-1]
    kernel = functools.partial(_ns_solve_kernel, iters=iters, damping=damping)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, bs, bs), lambda n: (n, 0, 0)),
                  pl.BlockSpec((1, bs, k), lambda n: (n, 0, 0))],
        out_specs=pl.BlockSpec((1, bs, k), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs, k), jnp.float32),
        interpret=interpret,
    )(a, b)

"""Pallas TPU kernel: fused adaptive Newton–Schulz SPD inverse.

TPU adaptation of FedPM's preconditioner inversion (DESIGN.md §4.1): the
paper Cholesky-factorizes on H100; triangular solves serialize badly on the
MXU, so we iterate  X ← X(2I − AX)  — two 128-aligned matmuls per step.

The WHOLE iteration runs inside one kernel invocation: A and X stay
resident in VMEM across all steps, so HBM sees exactly one read of A and
one write of X (a jnp scan pays 2·iters round-trips).  Each grid step
covers ``g`` blocks of the [nb, bs, bs] bank — the per-iteration matmuls
are then [g, bs, bs] batched, keeping the MXU fed for sub-128 blocks.

Two changes over the fixed-count jnp reference (``repro.core.inverse``):

* **SPD identity init** X₀ = I/‖A‖∞ (Gershgorin: ‖A‖∞ ≥ λ_max, so
  λ(AX₀) ∈ (0, 1] — always convergent, and symmetric so the residual
  I − AX stays symmetric).
* **In-kernel convergence test**: after one mandatory step, I − AX =
  (I − AX₀)² ⪰ 0, so the *trace* residual  r = Σ_blocks tr(I − AX) ≥ 0
  upper-bounds every eigenvalue of every block's error.  tr(AX) needs
  only the diagonal of the AX product the iteration computes anyway
  (sum(AX ∘ I) — a free reduction, where materializing max|I − AX| costs
  ~45% extra per step), and the while_loop exits as soon as
  r / (g·bs) ≤ tol instead of paying for the fixed worst-case ``iters``
  (cond ≲ 50 banks converge in 7–11 steps vs the reference's 20).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: normalized trace-residual exit threshold — just above the fp32 rounding
#: floor of the tr(AX) reduction, so converged banks exit instead of
#: burning the full ``iters`` budget chasing noise
DEFAULT_TOL = 1e-7


def _bmm(p, q):
    nd = p.ndim
    dn = (((nd - 1,), (nd - 2,)), (tuple(range(nd - 2)),) * 2)
    return jax.lax.dot_general(p, q, dn, preferred_element_type=jnp.float32)


def _ns_iterate(a, iters: int, damping: float, tol: float):
    """Adaptive Newton–Schulz X ≈ (A+δI)⁻¹ for a [..., bs, bs] in VMEM;
    shared by the inverse kernel, the fused invert-and-apply kernel, and
    the fused mix kernel."""
    bs = a.shape[-1]
    nb = 1
    for d in a.shape[:-2]:
        nb *= d
    eye = jnp.eye(bs, dtype=jnp.float32)
    if damping:
        a = a + damping * eye
    eye2 = 2.0 * eye
    # Gershgorin init: λ(AX₀) ∈ (0, 1] for any SPD A (incl. diagonal A,
    # where ‖A‖∞ = λ_max exactly — a 2/‖A‖∞ scale would put λ(AX₀) AT 2
    # and stall the iteration)
    n_inf = jnp.max(jnp.sum(jnp.abs(a), axis=-1))
    x = (1.0 / (n_inf + 1e-30)) * jnp.broadcast_to(eye, a.shape)
    # one mandatory step: I − AX₁ = (I − AX₀)² ⪰ 0 makes the trace
    # residual a valid (nonnegative, eigenvalue-dominating) error bound
    x = _bmm(x, eye2 - _bmm(a, x))
    denom = jnp.float32(nb * bs)

    def cond(c):
        i, _, res = c
        return jnp.logical_and(i < iters, res > tol)

    def body(c):
        i, x, _ = c
        ax = _bmm(a, x)
        res = (denom - jnp.sum(ax * eye)) / denom    # Σ tr(I−AX) / (nb·bs)
        return i + 1, _bmm(x, eye2 - ax), res

    _, x, _ = jax.lax.while_loop(cond, body, (1, x, jnp.float32(jnp.inf)))
    return x


def _ns_kernel(a_ref, o_ref, *, iters: int, damping: float, tol: float):
    o_ref[...] = _ns_iterate(a_ref[...].astype(jnp.float32), iters, damping,
                             tol)


def _ns_solve_kernel(a_ref, b_ref, o_ref, *, iters: int, damping: float,
                     tol: float):
    x = _ns_iterate(a_ref[...].astype(jnp.float32), iters, damping, tol)
    o_ref[...] = _bmm(x, b_ref[...].astype(jnp.float32))


def ns_inverse_blocks(a: jax.Array, *, iters: int = 20, damping: float = 0.0,
                      tol: float = DEFAULT_TOL, g: int = 1,
                      interpret: bool = False) -> jax.Array:
    """a: [nb, bs, bs] SPD blocks → approximate inverses [nb, bs, bs] fp32.

    ``g`` blocks per grid step (must divide nb); the convergence test is
    joint over each grid step's g blocks (extra steps past a block's own
    convergence are exact no-ops at the fixpoint)."""
    nb, bs, _ = a.shape
    kernel = functools.partial(_ns_kernel, iters=iters, damping=damping,
                               tol=tol)
    return pl.pallas_call(
        kernel,
        grid=(nb // g,),
        in_specs=[pl.BlockSpec((g, bs, bs), lambda n: (n, 0, 0))],
        out_specs=pl.BlockSpec((g, bs, bs), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs, bs), jnp.float32),
        interpret=interpret,
    )(a)


def ns_solve_blocks(a: jax.Array, b: jax.Array, *, iters: int = 20,
                    damping: float = 0.0, tol: float = DEFAULT_TOL,
                    g: int = 1, interpret: bool = False) -> jax.Array:
    """Fused invert-and-apply over a packed gram bank: per grid step,
    iterate X ≈ (A+δI)⁻¹ in VMEM and write only X@B — the inverse never
    round-trips through HBM (HBM traffic: read A, read B, write X@B).

    a: [nb, bs, bs] SPD blocks; b: [nb, bs, k] → [nb, bs, k] fp32.
    """
    nb, bs, _ = a.shape
    k = b.shape[-1]
    kernel = functools.partial(_ns_solve_kernel, iters=iters, damping=damping,
                               tol=tol)
    return pl.pallas_call(
        kernel,
        grid=(nb // g,),
        in_specs=[pl.BlockSpec((g, bs, bs), lambda n: (n, 0, 0)),
                  pl.BlockSpec((g, bs, k), lambda n: (n, 0, 0))],
        out_specs=pl.BlockSpec((g, bs, k), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs, k), jnp.float32),
        interpret=interpret,
    )(a, b)

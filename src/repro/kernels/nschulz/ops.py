"""jit'd public wrapper for the Newton–Schulz inverse kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.nschulz.nschulz import ns_inverse_blocks, ns_solve_blocks
from repro.kernels.nschulz.ref import ns_inverse_ref, ns_solve_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("iters", "damping", "use_pallas"))
def ns_inverse(a: jax.Array, *, iters: int = 20, damping: float = 0.0,
               use_pallas: bool | None = None) -> jax.Array:
    """Batched SPD inverse of a [..., bs, bs] via fused Newton–Schulz.

    Leading dims are flattened into the kernel grid; bs > 1024 (VMEM cap)
    or non-TPU-friendly shapes fall back to the jnp reference."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    bs = a.shape[-1]
    lead = a.shape[:-2]
    if not use_pallas and bs > 256:
        return ns_inverse_ref(a, iters=iters, damping=damping)
    if bs > 1024:   # VMEM wall: 3 fp32 buffers of bs² must fit ~16 MB
        return ns_inverse_ref(a, iters=iters, damping=damping)
    flat = a.reshape(-1, bs, bs)
    out = ns_inverse_blocks(flat, iters=iters, damping=damping,
                            interpret=not _on_tpu())
    return out.reshape(*lead, bs, bs)


@partial(jax.jit, static_argnames=("iters", "damping", "use_pallas"))
def ns_solve(a: jax.Array, b: jax.Array, *, iters: int = 20,
             damping: float = 0.0, use_pallas: bool | None = None
             ) -> jax.Array:
    """Fused batched (A+δI)⁻¹ @ B over a packed bank [..., bs, bs] /
    [..., bs, k] — the inverse stays in VMEM (one kernel per call).

    Leading dims flatten into the kernel grid.  Mismatched leading dims
    (one A applied to many B) route through ns_inverse + a broadcasting
    matmul — fusing there would re-iterate NS per broadcast copy.  Shapes
    whose VMEM footprint (A, X, AX + B, XB fp32) would exceed ~12 MB fall
    back the same way; non-TPU interpret mode additionally caps work."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    bs, k = a.shape[-1], b.shape[-1]
    lead = a.shape[:-2]
    if lead != b.shape[:-2]:
        inv = ns_inverse(a, iters=iters, damping=damping,
                         use_pallas=use_pallas)
        return inv @ b.astype(jnp.float32)
    if not use_pallas and (bs > 256 or bs * k > 1 << 16):
        return ns_solve_ref(a, b, iters=iters, damping=damping)
    if bs > 1024 or (3 * bs * bs + 2 * bs * k) * 4 > 12 * 2 ** 20:
        inv = ns_inverse(a, iters=iters, damping=damping,
                         use_pallas=use_pallas)
        return (inv @ b.astype(jnp.float32))
    out = ns_solve_blocks(a.reshape(-1, bs, bs), b.reshape(-1, bs, k),
                          iters=iters, damping=damping,
                          interpret=not _on_tpu())
    return out.reshape(*lead, bs, k)

"""jit'd public wrapper for the adaptive Newton–Schulz inverse kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.nschulz.nschulz import (DEFAULT_TOL, ns_inverse_blocks,
                                           ns_solve_blocks)
from repro.kernels.nschulz.ref import ns_inverse_ref, ns_solve_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


#: MXU lane width — sub-128 blocks are grouped g-per-grid-step so the
#: per-iteration batched matmuls run full-tile, and the fused kernel's RHS
#: is zero-padded up to this so the X@B matmul does too (narrow packed RHS
#: groups, e.g. a lone k=8 output column group, otherwise occupy a sliver
#: of the 128-wide systolic array)
_MXU_LANE = 128


def _pick_g(nb: int, bs: int, kp: int) -> int:
    """Blocks per grid step: the whole bank off-TPU (interpret mode pays
    Python overhead per grid step — one big batched step wins), largest
    VMEM-budgeted divisor of nb near 128/bs on TPU."""
    if not _on_tpu():
        return nb
    budget = (12 * 2 ** 20) // (4 * (3 * bs * bs + 2 * bs * max(kp, 1)))
    target = max(1, min(_MXU_LANE // bs, budget))
    g = 1
    for d in range(2, min(nb, target) + 1):
        if nb % d == 0:
            g = d
    return g


@partial(jax.jit, static_argnames=("iters", "damping", "tol", "use_pallas"))
def ns_inverse(a: jax.Array, *, iters: int = 20, damping: float = 0.0,
               tol: float = DEFAULT_TOL,
               use_pallas: bool | None = None) -> jax.Array:
    """Batched SPD inverse of a [..., bs, bs] via fused Newton–Schulz.

    ``iters`` is the budget, not the cost: the kernel's in-VMEM trace
    residual exits as soon as the bank is converged (``tol``).  Leading
    dims are flattened into the kernel grid; bs > 1024 (VMEM cap) or
    non-TPU-unfriendly shapes fall back to the fixed-count jnp
    reference."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    bs = a.shape[-1]
    lead = a.shape[:-2]
    if not use_pallas and bs > 256:
        return ns_inverse_ref(a, iters=iters, damping=damping)
    if bs > 1024:   # VMEM wall: 3 fp32 buffers of bs² must fit ~16 MB
        return ns_inverse_ref(a, iters=iters, damping=damping)
    flat = a.reshape(-1, bs, bs)
    out = ns_inverse_blocks(flat, iters=iters, damping=damping, tol=tol,
                            g=_pick_g(flat.shape[0], bs, bs),
                            interpret=not _on_tpu())
    return out.reshape(*lead, bs, bs)


@partial(jax.jit, static_argnames=("iters", "damping", "tol", "use_pallas"))
def ns_solve(a: jax.Array, b: jax.Array, *, iters: int = 20,
             damping: float = 0.0, tol: float = DEFAULT_TOL,
             use_pallas: bool | None = None) -> jax.Array:
    """Fused batched (A+δI)⁻¹ @ B over a packed bank [..., bs, bs] /
    [..., bs, k] — the inverse stays in VMEM (one kernel per call) and the
    iteration count adapts to the bank's conditioning (see nschulz.py).

    Leading dims flatten into the kernel grid.  The RHS lane k is
    zero-padded up to the 128-wide MXU tile before the kernel (the extra
    zero columns cost nothing beyond the tile already being resident) and
    sliced back after — padded ≡ unpadded, covered in tests/test_kernels
    (the convergence test reads only A and X, never B, so padding cannot
    change the iteration count either).
    Mismatched leading dims (one A applied to many B) route through
    ns_inverse + a broadcasting matmul — fusing there would re-iterate NS
    per broadcast copy.  Shapes whose VMEM footprint (A, X, AX + B_pad,
    XB_pad fp32) would exceed ~12 MB fall back the same way; non-TPU
    interpret mode additionally caps work."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    bs, k = a.shape[-1], b.shape[-1]
    # pad exactly when the MXU executes — CPU interpret mode has no
    # systolic array to fill, and 16x-ing its column work is pure waste
    kp = -(-k // _MXU_LANE) * _MXU_LANE if _on_tpu() else k
    lead = a.shape[:-2]
    if lead != b.shape[:-2]:
        inv = ns_inverse(a, iters=iters, damping=damping, tol=tol,
                         use_pallas=use_pallas)
        return inv @ b.astype(jnp.float32)
    if not use_pallas and (bs > 256 or bs * kp > 1 << 16):
        return ns_solve_ref(a, b, iters=iters, damping=damping)
    if bs > 1024 or (3 * bs * bs + 2 * bs * kp) * 4 > 12 * 2 ** 20:
        inv = ns_inverse(a, iters=iters, damping=damping, tol=tol,
                         use_pallas=use_pallas)
        return (inv @ b.astype(jnp.float32))
    bp = b if kp == k else jnp.concatenate(
        [b, jnp.zeros((*lead, bs, kp - k), b.dtype)], axis=-1)
    flat_a = a.reshape(-1, bs, bs)
    out = ns_solve_blocks(flat_a, bp.reshape(-1, bs, kp),
                          iters=iters, damping=damping, tol=tol,
                          g=_pick_g(flat_a.shape[0], bs, kp),
                          interpret=not _on_tpu())
    return out.reshape(*lead, bs, kp)[..., :k]

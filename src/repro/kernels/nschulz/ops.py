"""jit'd public wrapper for the Newton–Schulz inverse kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.nschulz.nschulz import ns_inverse_blocks
from repro.kernels.nschulz.ref import ns_inverse_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("iters", "damping", "use_pallas"))
def ns_inverse(a: jax.Array, *, iters: int = 20, damping: float = 0.0,
               use_pallas: bool | None = None) -> jax.Array:
    """Batched SPD inverse of a [..., bs, bs] via fused Newton–Schulz.

    Leading dims are flattened into the kernel grid; bs > 1024 (VMEM cap)
    or non-TPU-friendly shapes fall back to the jnp reference."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    bs = a.shape[-1]
    lead = a.shape[:-2]
    if not use_pallas and bs > 256:
        return ns_inverse_ref(a, iters=iters, damping=damping)
    if bs > 1024:   # VMEM wall: 3 fp32 buffers of bs² must fit ~16 MB
        return ns_inverse_ref(a, iters=iters, damping=damping)
    flat = a.reshape(-1, bs, bs)
    out = ns_inverse_blocks(flat, iters=iters, damping=damping,
                            interpret=not _on_tpu())
    return out.reshape(*lead, bs, bs)

"""Gemma 3 12B — 5:1 local:global sliding-window attention, 128k context
[hf:google/gemma-3-1b-pt].  Unit = 6 layers (5 sliding + 1 global); in
long-context serving the global layers hold a capped window too, which is
what makes long_500k decode feasible (DESIGN.md §5)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
    head_dim=256, d_ff=15360, vocab_size=262144,
    local_per_global=5, sliding_window=1024, layers_per_unit=6,
    rope_theta=1e6, subquadratic=True, long_context_global_window=8192,
    source="hf:google/gemma-3-1b-pt",
)

"""Zamba2 7B — Mamba2 backbone + shared attention block [arXiv:2411.15242].
81 mamba layers in units of 3; one weight-shared GQA attention block is
applied at the head of every unit (27 applications, one set of weights)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    attention="full", ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    attn_every=3, layers_per_unit=3, subquadratic=True,
    long_context_global_window=8192,
    source="arXiv:2411.15242",
)

"""Mamba2 1.3B — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    attention="none", ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    subquadratic=True,
    seq_parallel=True,    # §Perf D2: free peak-memory win (22→13 GB, same step time)
    source="arXiv:2405.21060",
)

"""DeepSeek-V2 236B — MLA (kv_lora=512) + MoE 2 shared + 160 routed top-6
[arXiv:2405.04434].  (The real model's first layer is a dense MLP; we use
the MoE block uniformly — noted in DESIGN.md §7.)"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=1536, vocab_size=102400,
    attention="mla", kv_lora_rank=512, q_lora_rank=1536,
    qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128, head_dim=192,
    num_experts=160, experts_per_tok=6, num_shared_experts=2,
    moe_shard_map=True,   # §Perf A1: locality-aware expert dispatch
    fsdp_mode="cols",     # §Perf B2: weight-gather FSDP placement
    source="arXiv:2405.04434",
)

"""Qwen2-VL 72B — M-RoPE, dynamic resolution [arXiv:2409.12191].
ViT frontend is a stub per spec: input_specs() provides 1024 precomputed
patch embeddings prepended to the text tokens; positions are the 3-stream
(t, h, w) M-RoPE ids."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    mrope_sections=(16, 24, 24), frontend="vision_stub", frontend_tokens=1024,
    rope_theta=1e6,
    fsdp_mode="cols",     # §Perf B2: weight-gather FSDP placement
    seq_parallel=True,    # §Perf B3: seq-sharded residual stream
    source="arXiv:2409.12191",
)

"""Architecture registry: ``get_config("<arch-id>")``.

Each module defines ``CONFIG`` with the exact assigned spec (source cited in
``source=``).  ``get_config(name, reduced=True)`` returns the CPU smoke
variant.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, InputShape, INPUT_SHAPES

_ARCH_MODULES = {
    "command-r-35b": "command_r_35b",
    "gemma3-12b": "gemma3_12b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "llama3-405b": "llama3_405b",
    "olmo-1b": "olmo_1b",
    "mamba2-1.3b": "mamba2_1_3b",
    "musicgen-medium": "musicgen_medium",
    "zamba2-7b": "zamba2_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str, *, reduced: bool = False) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    cfg = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def shape_supported(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k only for sub-quadratic archs (DESIGN.md §5 skips)."""
    if shape_name == "long_500k":
        return cfg.subquadratic
    return True

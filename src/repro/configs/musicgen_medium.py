"""MusicGen medium — decoder-only over EnCodec tokens [arXiv:2306.05284].
The EnCodec frontend is a stub per spec: input_specs() provides precomputed
frame embeddings; the model carries 4 parallel codebook heads."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    num_codebooks=4, frontend="audio_stub",
    source="arXiv:2306.05284",
)

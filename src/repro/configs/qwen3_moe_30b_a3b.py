"""Qwen3-MoE 30B-A3B — 128 experts, top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    head_dim=128, d_ff=768, vocab_size=151936,
    num_experts=128, experts_per_tok=8,
    rope_theta=1e6,
    moe_shard_map=True,   # §Perf A1: locality-aware expert dispatch
    source="hf:Qwen/Qwen3-30B-A3B",
)

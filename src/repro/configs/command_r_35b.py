"""Command R 35B — dense GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22528, vocab_size=256000,
    use_bias=False, norm="layernorm", rope_theta=8e6,
    fsdp_mode="cols",     # §Perf B2: weight-gather FSDP placement
    seq_parallel=True,    # §Perf B3: seq-sharded residual stream
    source="hf:CohereForAI/c4ai-command-r-v01",
)

"""Llama 3 405B — dense GQA, 128k vocab [arXiv:2407.21783].
Largest assigned arch; FedPM runs in fused_k1 mode only (DESIGN.md §3b
memory wall) with FSDP param sharding over the data axis."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128256,
    rope_theta=5e5,
    fsdp_mode="cols",     # §Perf B2: weight-gather FSDP placement
    seq_parallel=True,    # §Perf B3: seq-sharded residual stream
    source="arXiv:2407.21783",
)

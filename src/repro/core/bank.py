"""Packed gram-bank preconditioning: factor-once, cross-layer batched solves.

FedPM's per-layer FOOF preconditioners are many small SPD blocks scattered
across the param tree — one ``[nb, bs, bs]`` stack per linear layer plus a
diagonal lane for the embedding.  The per-leaf tree walks in
``repro.core.foof`` dispatch one tiny factorization/solve per layer; on
accelerators each of those is a separate launch and none of them fills the
MXU.  This module flattens every same-block-size gram leaf across the
WHOLE tree into one bank so that factorization, inversion, Newton–Schulz,
and the Pallas kernel each run as ONE batched call per distinct block size
(typically 1–3 groups per model).

Layout
------
``pack`` walks the gram tree in ``tree_flatten_with_path`` order and
classifies each leaf:

* **mat** — trailing shape ``[lead..., nb, bs, bs]`` (square blocks).  The
  lead axes (e.g. the transformer's stacked unit/inner-layer axes) and the
  block axis ``nb`` flatten into rows of the per-block-size group bank
  ``mats[g]: [stack..., R_g, bs, bs]``.
* **diag** — trailing 1-D shape ``[V]`` (the embedding's exact token-count
  diagonal).  All diag leaves concatenate into one vector lane
  ``diag: [stack..., D]`` — inverting/averaging the lane is one
  elementwise op.  The division into each ``[V, dout]`` grad stays per
  leaf (already a single elementwise broadcast, nothing to batch).
* **none** — size-0 placeholder (param has no gram): passthrough.
* **other** — anything else falls back to the per-leaf reference path in
  ``repro.core.foof`` (no in-tree model produces such leaves).

``stack`` leading axes (the gathered participant axis S in server mixing)
are preserved on the bank arrays, so client means become one tensordot per
group instead of one per layer.

Right-hand sides pack the same way: a param leaf ``[lead..., din, dout]``
is blocked to ``[rows, bs, k]`` — lead axes that match the gram's lead
become extra rows; broadcast (shared-gram) lead axes, e.g. the MoE expert
axis riding on the pooled router gram, fold into the ``k`` columns.  Per
group the ``k`` axis is zero-padded to the widest leaf so one batched
``cho_solve`` / Newton–Schulz / fused Pallas invert-and-apply launch
covers every layer at once.  Padding is exact: triangular and
Newton–Schulz solves act column-independently, and padded columns are
dropped on unpack.

Future sharded/async PRs should pack into this bank (add a lane or a
group) rather than re-introducing per-leaf walks — see ROADMAP
"Open items".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.linalg import cho_factor, cho_solve

from repro.core import inverse as inv

PyTree = Any

#: param key → sibling key whose gram (same layer inputs) should be used
GRAM_ROUTES = {"wi": "router", "wkv_a": "wq_a", "shared_wi": "router"}


# ----------------------------------------------------------------- layout --

@dataclass(frozen=True)
class MatEntry:
    group: int          # index into BankLayout.block_sizes
    start: int          # first row of this leaf inside the group bank
    rows: int           # prod(lead) * nb
    core: tuple         # leaf shape without the stack axes


@dataclass(frozen=True)
class DiagEntry:
    start: int          # offset into the diagonal lane
    size: int
    core: tuple


@dataclass(frozen=True)
class BankLayout:
    """Static (hashable) description of how a gram tree packs into banks."""
    block_sizes: tuple      # bs per mat group
    group_rows: tuple       # total rows per mat group
    diag_size: int
    paths: tuple            # normalized gram-leaf paths, pack order
    entries: tuple          # parallel: MatEntry | DiagEntry | "none" | "other"
    stack: int              # leading stack axes shared by every leaf


def _norm_path(path) -> tuple:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(k.key)
        elif hasattr(k, "idx"):
            out.append(k.idx)
        elif hasattr(k, "name"):
            out.append(k.name)
        else:
            out.append(str(k))
    return tuple(out)


def _classify(shape: tuple, stack: int) -> str:
    core = shape[stack:]
    if any(s == 0 for s in shape):
        return "none"
    if len(core) >= 3 and core[-1] == core[-2]:
        return "mat"
    if len(core) == 1:
        return "diag"
    return "other"


# ------------------------------------------------------------------- bank --

@jax.tree_util.register_pytree_node_class
class GramBank:
    """A gram tree packed into per-block-size banks + a diagonal lane."""

    def __init__(self, mats, diag, others, layout: BankLayout):
        self.mats = tuple(mats)
        self.diag = diag
        self.others = tuple(others)
        self.layout = layout

    def tree_flatten(self):
        return (self.mats, self.diag, self.others), self.layout

    @classmethod
    def tree_unflatten(cls, layout, children):
        mats, diag, others = children
        return cls(mats, diag, others, layout)


def pack(grams: PyTree, *, stack: int = 0) -> GramBank:
    """Pack a gram tree into a :class:`GramBank`.

    ``stack`` leading axes (identical on every leaf — e.g. the gathered
    participant axis S) are preserved on the bank arrays.
    """
    leaves = jax.tree_util.tree_leaves_with_path(grams)
    paths, entries, others = [], [], []
    sizes: list[int] = []
    rows: list[int] = []
    chunks: list[list] = []
    diag_chunks: list = []
    diag_off = 0
    for path, leaf in leaves:
        paths.append(_norm_path(path))
        kind = _classify(tuple(leaf.shape), stack)
        if kind == "mat":
            bs = leaf.shape[-1]
            core = tuple(leaf.shape[stack:])
            r = int(np.prod(core[:-2], dtype=np.int64))
            if bs in sizes:
                g = sizes.index(bs)
            else:
                g = len(sizes)
                sizes.append(bs)
                rows.append(0)
                chunks.append([])
            entries.append(MatEntry(group=g, start=rows[g], rows=r, core=core))
            rows[g] += r
            lead = leaf.shape[:stack]
            chunks[g].append(
                leaf.astype(jnp.float32).reshape(*lead, r, bs, bs))
        elif kind == "diag":
            core = tuple(leaf.shape[stack:])
            entries.append(DiagEntry(start=diag_off, size=core[0], core=core))
            diag_off += core[0]
            diag_chunks.append(leaf.astype(jnp.float32))
        elif kind == "other":
            entries.append("other")
            others.append(leaf)
        else:
            entries.append("none")
    mats = tuple(c[0] if len(c) == 1 else jnp.concatenate(c, axis=stack)
                 for c in chunks)
    diag = (None if not diag_chunks else
            (diag_chunks[0] if len(diag_chunks) == 1
             else jnp.concatenate(diag_chunks, axis=stack)))
    layout = BankLayout(block_sizes=tuple(sizes), group_rows=tuple(rows),
                        diag_size=diag_off, paths=tuple(paths),
                        entries=tuple(entries), stack=stack)
    return GramBank(mats, diag, others, layout)


def _rows(arr, start, n, axis):
    return jax.lax.slice_in_dim(arr, start, start + n, axis=axis)


def unpack_like(grams: PyTree, mats, diag, others, layout: BankLayout
                ) -> PyTree:
    """Rebuild a tree congruent to ``grams`` from transformed bank arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(grams)
    out, oi = [], 0
    for i, leaf in enumerate(leaves):
        e = layout.entries[i]
        if e == "none":
            out.append(leaf)
        elif e == "other":
            out.append(others[oi])
            oi += 1
        elif isinstance(e, MatEntry):
            m = _rows(mats[e.group], e.start, e.rows, layout.stack)
            out.append(m.reshape(*leaf.shape[:layout.stack], *e.core))
        else:
            d = _rows(diag, e.start, e.size, layout.stack)
            out.append(d.reshape(*leaf.shape[:layout.stack], *e.core))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------- rhs packing ----

@dataclass(frozen=True)
class _MatPlan:
    entry: MatEntry
    bs: int
    k: int                  # packed rhs columns = prod(col lead) * dout
    perm: tuple             # axis permutation of blocked w (sans stack)
    inv_perm: tuple
    blocked_shape: tuple    # (*lw, nb, bs, dout)
    out_shape: tuple        # original param core shape (*lw, din, dout)


def _mat_plan(entry: MatEntry, w_core: tuple):
    """Plan how param core ``[lead..., din, dout]`` blocks against the gram
    entry's ``[lead..., nb, bs, bs]``; None when shapes are incompatible."""
    core = entry.core
    la, nb, bs = core[:-3], core[-3], core[-1]
    if len(w_core) < 2:
        return None
    lw, (din, dout) = w_core[:-2], w_core[-2:]
    if din != nb * bs or len(la) > len(lw):
        return None
    row_axes, col_axes = [], []
    for i, (da, dw) in enumerate(zip(la, lw)):
        if da == dw:
            row_axes.append(i)
        elif da == 1:
            col_axes.append(i)
        else:
            return None
    col_axes += list(range(len(la), len(lw)))
    n = len(lw)
    perm = (*row_axes, n, n + 1, *col_axes, n + 2)
    rows = int(np.prod([lw[i] for i in row_axes], dtype=np.int64)) * nb
    if rows != entry.rows:
        return None
    k = int(np.prod([lw[i] for i in col_axes], dtype=np.int64)) * dout
    inv_perm = tuple(int(i) for i in np.argsort(perm))
    return _MatPlan(entry=entry, bs=bs, k=k, perm=perm, inv_perm=inv_perm,
                    blocked_shape=(*lw, nb, bs, dout), out_shape=tuple(w_core))


def _pack_rhs(w, plan: _MatPlan, stack: int):
    st = w.shape[:stack]
    wb = w.astype(jnp.float32).reshape(*st, *plan.blocked_shape)
    perm = tuple(range(stack)) + tuple(stack + i for i in plan.perm)
    wb = wb.transpose(perm)
    return wb.reshape(*st, plan.entry.rows, plan.bs, plan.k)


def _unpack_rhs(out, plan: _MatPlan, stack: int, dtype):
    st = out.shape[:stack]
    permuted = tuple(plan.blocked_shape[i] for i in plan.perm)
    ob = out.reshape(*st, *permuted)
    iperm = tuple(range(stack)) + tuple(stack + i for i in plan.inv_perm)
    ob = ob.transpose(iperm)
    return ob.reshape(*st, *plan.out_shape).astype(dtype)


def _pad_k(x, kmax: int):
    k = x.shape[-1]
    if k == kmax:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, kmax - k)]
    return jnp.pad(x, pad)


def _maybe_take(arr, idx: np.ndarray, axis: int):
    n = arr.shape[axis]
    if idx.size == n and np.array_equal(idx, np.arange(n)):
        return arr
    return jnp.take(arr, jnp.asarray(idx), axis=axis)


def _resolve(index: dict, path: tuple):
    """Gram-leaf path for a param leaf path, honoring GRAM_ROUTES (a param
    whose own gram is absent/size-0 rides its sibling's — same layer
    inputs).  Returns None → no gram (passthrough)."""
    if not path:
        return None
    if index.get(path) not in (None, "none"):
        return path
    route = GRAM_ROUTES.get(path[-1])
    if route is not None:
        routed = (*path[:-1], route)
        if index.get(routed) not in (None, "none"):
            return routed
    return None


def _other_positions(layout: BankLayout) -> dict:
    pos, oi = {}, 0
    for p, e in zip(layout.paths, layout.entries):
        if e == "other":
            pos[p] = oi
            oi += 1
    return pos


# ------------------------------------------------ precondition engine ------

def _assemble_jobs(jobs_by_entry: dict, stack: int):
    """Fold all param leaves that resolved to the SAME gram entry into one
    job by concatenating their rhs along columns (they share the entry's
    rows), then pad+concat entries into the group rhs.  ``use`` therefore
    indexes each bank row at most once — the fused Pallas kernel never
    re-iterates a shared block, and factor gathers carry no duplicates.

    Returns (rhs, use, ents) with ents = [(rows, members, ktot)].
    """
    ents = []
    for start, members in jobs_by_entry.items():
        rows = members[0][1].entry.rows
        ktot = sum(m[1].k for m in members)
        ents.append((start, rows, members, ktot))
    kmax = max(ktot for _, _, _, ktot in ents)
    rhs_parts, use_parts = [], []
    for start, rows, members, _ in ents:
        er = (members[0][2] if len(members) == 1
              else jnp.concatenate([m[2] for m in members], axis=-1))
        rhs_parts.append(_pad_k(er, kmax))
        use_parts.append(np.arange(start, start + rows))
    rhs = (rhs_parts[0] if len(rhs_parts) == 1
           else jnp.concatenate(rhs_parts, axis=stack))
    return rhs, np.concatenate(use_parts), ents


def _scatter_jobs(sol, ents, outs, unpack):
    """Split a solved group rhs back per entry (rows) and per member
    (columns); ``unpack(piece, plan, dtype)`` rebuilds each leaf."""
    off = 0
    for _, rows, members, _ in ents:
        ent_sol = jax.lax.slice_in_dim(sol, off, off + rows, axis=0)
        koff = 0
        for i, plan, _, dt in members:
            outs[i] = unpack(ent_sol[..., koff:koff + plan.k], plan, dt)
            koff += plan.k
        off += rows


def _packed_apply(params, grads, layout: BankLayout, *, group_solve=None,
                  diag_solve, other_solve, entry_solve=None):
    """Shared engine for (preconditioner ∘ grads): pack rhs per group, run
    ONE ``group_solve`` per block-size group, rebuild the grad tree.

    group_solve(g, use_idx, rhs[B, bs, kmax]) -> [B, bs, kmax] fp32
    diag_solve(entry, g_leaf) -> leaf | None (None → passthrough)
    other_solve(other_idx, p_leaf, g_leaf) -> leaf

    ``entry_solve(g, start, rows, rhs[rows, bs, k]) -> [rows, bs, k]``
    replaces group_solve with a per-gram-entry solve that skips the
    assemble/scatter stage entirely — no pad-to-kmax, no cross-entry
    concat, no row gather/slice.  Correct only when the solve is
    column-independent AND row-sliceable (a cached-inverse matmul is;
    the fused Pallas kernels are not — they must see each block exactly
    once per launch, which the assembled ``use`` guarantees).
    """
    pleaves = jax.tree_util.tree_leaves_with_path(params)
    gleaves, gdef = jax.tree_util.tree_flatten(grads)
    index = dict(zip(layout.paths, layout.entries))
    other_pos = _other_positions(layout)
    jobs: list[dict] = [{} for _ in layout.block_sizes]
    outs: list = [None] * len(gleaves)
    for i, ((path, p), g) in enumerate(zip(pleaves, gleaves)):
        gp = _resolve(index, _norm_path(path))
        e = index.get(gp) if gp is not None else None
        if e is None:
            outs[i] = g
        elif e == "other":
            outs[i] = other_solve(other_pos[gp], p, g)
        elif isinstance(e, DiagEntry):
            res = diag_solve(e, g)
            outs[i] = g if res is None else res
        else:
            plan = _mat_plan(e, tuple(g.shape))
            if plan is None:
                raise ValueError(
                    f"gram blocks {e.core} incompatible with grad "
                    f"{g.shape} at {gp}")
            jobs[e.group].setdefault(e.start, []).append(
                (i, plan, _pack_rhs(g, plan, 0), g.dtype))
    for gi, job in enumerate(jobs):
        if not job:
            continue
        if entry_solve is not None:
            for start, members in job.items():
                rows = members[0][1].entry.rows
                for i, plan, rhs, dt in members:
                    outs[i] = _unpack_rhs(entry_solve(gi, start, rows, rhs),
                                          plan, 0, dt)
            continue
        rhs, use, ents = _assemble_jobs(job, 0)
        sol = group_solve(gi, use, rhs)
        _scatter_jobs(sol, ents, outs,
                      lambda piece, plan, dt: _unpack_rhs(piece, plan, 0, dt))
    return jax.tree_util.tree_unflatten(gdef, outs)


@jax.tree_util.register_pytree_node_class
class PackedPreconditioner:
    """Factor-once / apply-many FOOF preconditioner over the packed bank.

    ``facs`` holds per-group EXPLICIT inverses for every method —
    ``cholesky`` builds them through the Schur-recursive blocked kernel op
    (``repro.kernels.cholesky``), ``ns``/``pallas_ns`` through
    Newton–Schulz; ``diag_inv`` is the reciprocal diagonal lane.
    ``apply`` is then a pure per-entry matmul — NO re-factorization, no
    triangular solves (XLA:CPU runs batched trsm ~4.7x slower than the
    equivalent matmul), and no per-call rhs re-assembly — so K local
    steps amortize one factorization (paper Table 2 cost model).
    """

    def __init__(self, facs, diag_inv, others, layout, method, ns_iters,
                 damping):
        self.facs = tuple(facs)
        self.diag_inv = diag_inv
        self.others = tuple(others)
        self.layout = layout
        self.method = method
        self.ns_iters = ns_iters
        self.damping = damping

    def tree_flatten(self):
        return ((self.facs, self.diag_inv, self.others),
                (self.layout, self.method, self.ns_iters, self.damping))

    @classmethod
    def tree_unflatten(cls, aux, children):
        facs, diag_inv, others = children
        return cls(facs, diag_inv, others, *aux)


def build_preconditioner(grams: PyTree, *, damping: float,
                         method: str = "cholesky", ns_iters: int = 20
                         ) -> PackedPreconditioner:
    """Factor/invert every gram ONCE — one batched call per block-size
    group — returning cached factors for repeated ``apply_preconditioner``
    calls (the K-local-steps amortization)."""
    bank = pack(grams)
    if method == "cholesky":
        from repro.kernels.cholesky import ops as chol_ops
        facs = tuple(chol_ops.chol_inverse(m, damping=damping)
                     for m in bank.mats)
    else:
        facs = tuple(inv.inverse(m, damping, method=method,
                                 ns_iters=ns_iters)
                     for m in bank.mats)
    diag_inv = None if bank.diag is None else 1.0 / (bank.diag + damping)
    return PackedPreconditioner(facs, diag_inv, bank.others, bank.layout,
                                method, ns_iters, damping)


def _diag_apply(diag_inv, entry: DiagEntry, g):
    if g.ndim < 2 or entry.size != g.shape[-2]:
        return None
    lane = jax.lax.slice_in_dim(diag_inv, entry.start,
                                entry.start + entry.size, axis=0)
    return (g.astype(jnp.float32) * lane[:, None]).astype(g.dtype)


def apply_preconditioner(pp: PackedPreconditioner, params: PyTree,
                         grads: PyTree) -> PyTree:
    """Preconditioned grads from cached inverses: one matmul per gram
    entry against its row-slice of the group factor bank, zero
    factorizations and zero rhs re-assembly (every method's ``facs`` are
    explicit inverses, so applying is column-independent and
    row-sliceable — the ``entry_solve`` fast path)."""
    from repro.core import foof as F

    def entry_solve(g, start, rows, rhs):
        fac = jax.lax.slice_in_dim(pp.facs[g], start, start + rows, axis=0)
        return fac @ rhs

    def other_solve(oi, p, g):
        return F._precondition_leaf(p, g, pp.others[oi], pp.damping,
                                    pp.method, pp.ns_iters)

    return _packed_apply(params, grads, pp.layout, entry_solve=entry_solve,
                         diag_solve=lambda e, g: _diag_apply(pp.diag_inv, e, g),
                         other_solve=other_solve)


def precondition_tree(params: PyTree, grads: PyTree, grams: PyTree, *,
                      damping: float, method: str = "cholesky",
                      ns_iters: int = 20) -> PyTree:
    """One-shot packed FOOF preconditioning (Eq. 11 direction).

    cholesky/ns: invert the bank once, apply.  pallas_ns / pallas_chol:
    the fused invert-and-apply kernels compute X = (A+δI)⁻¹ and X@G
    inside one kernel per group — the inverse never round-trips HBM.
    """
    if not method.startswith("pallas"):
        pp = build_preconditioner(grams, damping=damping, method=method,
                                  ns_iters=ns_iters)
        return apply_preconditioner(pp, params, grads)

    from repro.core import foof as F
    bank = pack(grams)
    diag_inv = None if bank.diag is None else 1.0 / (bank.diag + damping)

    if method == "pallas_chol":
        from repro.kernels.cholesky import ops as chol_ops

        def group_solve(g, use, rhs):
            return chol_ops.chol_solve(_maybe_take(bank.mats[g], use, 0),
                                       rhs, damping=damping)
    else:
        from repro.kernels.nschulz import ops as ns_ops

        def group_solve(g, use, rhs):
            # ``use`` is duplicate-free (shared grams fold into one job's
            # columns), so the fused kernel iterates each block exactly once
            return ns_ops.ns_solve(_maybe_take(bank.mats[g], use, 0), rhs,
                                   iters=ns_iters, damping=damping)

    def other_solve(oi, p, g):
        return F._precondition_leaf(p, g, bank.others[oi], damping, method,
                                    ns_iters)

    return _packed_apply(params, grads, bank.layout, group_solve=group_solve,
                         diag_solve=lambda e, g: _diag_apply(diag_inv, e, g),
                         other_solve=other_solve)


# ---------------------------------------------------------------- invert ---

def invert_grams(grams: PyTree, *, damping: float, method: str = "cholesky",
                 ns_iters: int = 20) -> PyTree:
    """(A+δI)⁻¹ for every gram leaf via ONE batched inverse per block-size
    group (+ one elementwise op for the diagonal lane); returns the per-leaf
    inverse tree consumed by ``foof.apply_inverses``."""
    from repro.core import foof as F
    bank = pack(grams)
    if method == "cholesky":
        from repro.kernels.cholesky import ops as chol_ops
        inv_mats = tuple(chol_ops.chol_inverse(m, damping=damping)
                         for m in bank.mats)
    else:
        inv_mats = tuple(inv.inverse(m, damping, method=method,
                                     ns_iters=ns_iters)
                         for m in bank.mats)
    inv_diag = None if bank.diag is None else 1.0 / (bank.diag + damping)
    inv_others = tuple(F._invert_leaf(a, damping, method, ns_iters)
                       for a in bank.others)
    return unpack_like(grams, inv_mats, inv_diag, inv_others, bank.layout)


# ----------------------------------------------------------------- mixing --

def _mix_engine(params, bank: GramBank, *, damping, method, ns_iters,
                reduce_mats, reduce_leaf, other_solve, group_mix=None):
    """FedPM preconditioned mixing (Eq. 12) over the packed bank.

    ``reduce_mats`` is the participant mean of an fp32 packed array (it
    removes the stack axes); ``reduce_leaf`` the mean of a raw leaf.  Per
    block-size group this runs: one gather, one (A_i+δI)@θ_i batched
    matmul, TWO reductions (numerator + Ā), one factorization of Ā and one
    batched solve — regardless of how many layers share the group.

    ``group_mix(g, use_idx, rhs[S, B, bs, kmax]) -> [B, bs, kmax]``
    replaces that whole chain with a single fused call (the Pallas mix
    kernel: reduce → invert → apply never leaves VMEM).  Only valid when
    the stacked rhs is locally complete — i.e. no cross-shard psum inside
    the reduction — so sharded callers must leave it None.
    """
    layout = bank.layout
    stack = layout.stack
    pleaves = jax.tree_util.tree_leaves_with_path(params)
    _, pdef = jax.tree_util.tree_flatten(params)
    index = dict(zip(layout.paths, layout.entries))
    other_pos = _other_positions(layout)
    den_lane = (None if bank.diag is None
                else reduce_mats(bank.diag) + damping)
    jobs: list[dict] = [{} for _ in layout.block_sizes]
    outs: list = [None] * len(pleaves)
    for i, (path, p) in enumerate(pleaves):
        gp = _resolve(index, _norm_path(path))
        e = index.get(gp) if gp is not None else None
        core = tuple(p.shape[stack:])
        if e is None:
            outs[i] = reduce_leaf(p)
        elif e == "other":
            outs[i] = other_solve(other_pos[gp], p)
        elif isinstance(e, DiagEntry):
            if len(core) < 2 or e.size != core[-2]:
                outs[i] = reduce_leaf(p)
            else:
                a = _rows(bank.diag, e.start, e.size, stack)
                num = reduce_mats((a[..., None] + damping)
                                  * p.astype(jnp.float32))
                den = jax.lax.slice_in_dim(den_lane, e.start,
                                           e.start + e.size, axis=0)
                outs[i] = (num / den[:, None]).astype(p.dtype)
        else:
            plan = _mat_plan(e, core)
            if plan is None:
                outs[i] = reduce_leaf(p)    # simple mixing on mismatch
            else:
                jobs[e.group].setdefault(e.start, []).append(
                    (i, plan, _pack_rhs(p, plan, stack), p.dtype))
    for gi, job in enumerate(jobs):
        if not job:
            continue
        bs = layout.block_sizes[gi]
        rhs, use, ents = _assemble_jobs(job, stack)
        if group_mix is not None:
            _scatter_jobs(group_mix(gi, use, rhs), ents, outs,
                          lambda piece, plan, dt:
                          _unpack_rhs(piece, plan, 0, dt))
            continue
        a_use = _maybe_take(bank.mats[gi], use, stack)
        eye = damping * jnp.eye(bs, dtype=jnp.float32)
        num = reduce_mats((a_use + eye) @ rhs)        # Σ w_i (A_i+δI) θ_i
        abar = reduce_mats(bank.mats[gi])             # Σ w_i A_i
        if method == "pallas_ns":
            from repro.kernels.nschulz import ops as ns_ops
            sol = ns_ops.ns_solve(_maybe_take(abar, use, 0), num,
                                  iters=ns_iters, damping=damping)
        elif method == "pallas_chol":
            from repro.kernels.cholesky import ops as chol_ops
            sol = chol_ops.chol_solve(_maybe_take(abar, use, 0), num,
                                      damping=damping)
        elif method == "cholesky_safe":
            # quarantine fallback: escalate damping per group matrix and
            # degrade to the identity preconditioner before letting a
            # non-finite factorization NaN the mixed params
            sol = inv.solve_escalated(_maybe_take(abar, use, 0), num,
                                      damping)
        else:
            abar_d = inv.damp(abar, damping)
            if method == "ns":
                x = inv.ns_inverse(abar_d, ns_iters)
                sol = _maybe_take(x, use, 0) @ num
            else:
                c = cho_factor(abar_d, lower=True)[0]
                sol = cho_solve((_maybe_take(c, use, 0), True), num)
        _scatter_jobs(sol, ents, outs,
                      lambda piece, plan, dt: _unpack_rhs(piece, plan, 0, dt))
    return jax.tree_util.tree_unflatten(pdef, outs)


def normalize_weights(weights: jax.Array | None, n: int,
                      axes: tuple = ()) -> jax.Array:
    """Participant aggregation weights, normalized to sum 1 (uniform when
    None).  Shared by the packed and per-leaf mixing paths — the two must
    stay identical for the packed≡per-leaf property to hold under
    weighted mixing.

    ``axes``: mesh axes the participant stack is sharded over (the
    sharded engine's per-shard buckets) — the normalizing weight sum is
    then the cross-shard psum total, so zero-weight padding slots and
    uneven buckets normalize exactly like the single-device stack.
    NOTE: ``weights=None`` with ``axes`` set means uniform over EVERY
    local row on every shard — callers with padded buckets (the sharded
    engine) must pass explicit weights with 0 at padding slots, or the
    padding rows' garbage averages in."""
    if weights is None and not axes:
        return jnp.full((n,), 1.0 / n, jnp.float32)
    w = (jnp.ones((n,), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    if w.shape[0] != n:
        raise ValueError(f"weights [{w.shape[0]}] must match the "
                         f"gathered participant axis [{n}]")
    wsum = jnp.sum(w)
    if axes:
        wsum = jax.lax.psum(wsum, axes)
    return w / jnp.maximum(wsum, 1e-12)


def mix_preconditioned(params_stack: PyTree, grams_stack: PyTree, *,
                       damping: float, method: str = "cholesky",
                       ns_iters: int = 20,
                       weights: jax.Array | None = None,
                       axes: tuple = (),
                       gram_scale: jax.Array | None = None) -> PyTree:
    """Packed FedPM server mixing over participant-stacked trees.

    With ``axes`` set (inside a shard_map manual region) the leading
    stack axis is each shard's LOCAL participant bucket: every bank
    reduction becomes a per-shard partial tensordot + one cross-shard
    psum per block-size group, so the full [S] stack never materializes
    on a device and the packed-rhs banks stay sharded over their row
    axis.

    ``gram_scale`` ([S], optional) scales participant ``i``'s ENTIRE
    gram bank row by ``gram_scale[i]`` before anything else touches it —
    the staleness-damping hook (``Ã_i = s_i A_i``).  Scaling the packed
    bank once up front makes every downstream lane (numerator, mixed
    denominator Ā, diagonal lane, fused pallas group_mix) consistent by
    construction, and a scale of exactly 1.0 is bitwise inert."""
    from repro.core import foof as F
    axes = tuple(axes)
    n = jax.tree.leaves(params_stack)[0].shape[0]
    w = normalize_weights(weights, n, axes)

    def reduce_mats(x):
        r = jnp.tensordot(w, x.astype(jnp.float32), axes=1)
        return jax.lax.psum(r, axes) if axes else r

    def reduce_leaf(x):
        return reduce_mats(x).astype(x.dtype)

    bank = pack(grams_stack, stack=1)
    if gram_scale is not None:
        gs = gram_scale.astype(jnp.float32)
        if gs.shape[0] != n:
            raise ValueError(f"gram_scale [{gs.shape[0]}] must match the "
                             f"gathered participant axis [{n}]")

        def _scale(x):
            return x * gs.reshape(gs.shape[:1] + (1,) * (x.ndim - 1))

        bank = GramBank(
            tuple(_scale(m) for m in bank.mats),
            None if bank.diag is None else _scale(bank.diag),
            tuple(_scale(o.astype(jnp.float32)).astype(o.dtype)
                  if o.size else o for o in bank.others),
            bank.layout)

    group_mix = None
    if not axes and method.startswith("pallas"):
        # fused server mixing: one kernel launch per block-size group does
        # reduce → invert → apply over the stacked client bank (only valid
        # unsharded — the kernel reduces the FULL stack axis locally)
        from repro.kernels.mix import ops as mix_ops
        solver = "chol" if method == "pallas_chol" else "ns"

        def group_mix(gi, use, rhs):
            a_use = _maybe_take(bank.mats[gi], use, 1)
            return mix_ops.mix_precond(a_use, rhs, w, damping=damping,
                                       iters=ns_iters, solver=solver)

    def other_solve(oi, p):
        return F._mix_leaf(p, bank.others[oi], damping, method, ns_iters,
                           reduce_leaf)

    return _mix_engine(params_stack, bank, damping=damping, method=method,
                       ns_iters=ns_iters, reduce_mats=reduce_mats,
                       reduce_leaf=reduce_leaf, other_solve=other_solve,
                       group_mix=group_mix)


def mix_preconditioned_psum(params: PyTree, grams: PyTree, *, axes,
                            damping: float, method: str = "cholesky",
                            ns_iters: int = 20) -> PyTree:
    """Packed Eq. 12 inside a shard_map manual region: per block-size group
    the client means become TWO psums (numerator bank + gram bank) instead
    of two per layer."""
    from repro.core import foof as F
    axes = tuple(axes)

    def reduce_mats(x):
        return jax.lax.pmean(x.astype(jnp.float32), axes)

    def reduce_leaf(x):
        return jax.lax.pmean(x, axes)

    bank = pack(grams, stack=0)

    def other_solve(oi, p):
        return F._mix_leaf_psum(p, bank.others[oi], damping, method,
                                ns_iters, reduce_leaf)

    return _mix_engine(params, bank, damping=damping, method=method,
                       ns_iters=ns_iters, reduce_mats=reduce_mats,
                       reduce_leaf=reduce_leaf, other_solve=other_solve)

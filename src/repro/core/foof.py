"""FOOF preconditioning + FedPM preconditioned mixing over param pytrees.

FOOF (Benzing 2022, paper Sec 3.3): per-layer preconditioner is the
uncentered input covariance A_l; the update is
    W ← W − η · (A_l + δI)⁻¹ · ∇W          (Eq. 11)
and FedPM's server-side *preconditioned mixing* is
    W ← (Σ_i A_i,l + NδI)⁻¹ · Σ_i (A_i,l + δI) · W_i,l      (Eq. 12)
(δ applied on both sides so mixing of identical params is the identity —
a property we test).

Grams mirror the param tree (size-0 leaves = "no gram").  Some params share
another param's input (e.g. MoE expert ``wi`` sees the same tokens as the
``router``); ``GRAM_ROUTES`` redirects them to the sibling gram.  The
embedding's gram is the exact token-frequency *diagonal* (1-D leaf).

The public entry points dispatch to the packed gram-bank engine
(``repro.core.bank``): all same-block-size gram leaves across the tree are
flattened into one ``[B, bs, bs]`` bank so factorization/inversion/solve
run as ONE batched call per block size instead of one per layer.
``packed=False`` keeps the original per-leaf walk — the numerical oracle
the bank is property-tested against.  ``build_preconditioner`` /
``apply_preconditioner`` expose the factor-once / apply-K amortization
used by the local-step loops.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import bank as B
from repro.core import inverse as inv
from repro.core.bank import (GRAM_ROUTES, GramBank, PackedPreconditioner,
                             apply_preconditioner, build_preconditioner)
from repro.models.layers import is_gram

PyTree = Any


def _resolve_gram(key: str, grams_level: dict):
    g = grams_level.get(key)
    if g is not None and g.size > 0:
        return g
    route = GRAM_ROUTES.get(key)
    if route is not None:
        g2 = grams_level.get(route)
        if g2 is not None and g2.size > 0:
            return g2
    return None


def _align_gram(a: jax.Array, lead_w: tuple) -> jax.Array:
    """Insert axes so gram [..., nb, bs, bs] broadcasts over w's leading dims
    (e.g. an expert axis that the pooled gram lacks)."""
    a_lead = a.shape[:-3]
    missing = len(lead_w) - len(a_lead)
    if missing > 0:
        a = a.reshape(*a_lead, *(1,) * missing, *a.shape[-3:])
    return jnp.broadcast_to(a, (*lead_w, *a.shape[-3:]))


def _blocked_apply(op_result_of, a: jax.Array, w: jax.Array) -> jax.Array:
    """Apply a per-block [..., nb, bs, bs] operator to w [..., din, dout]
    (din = nb·bs), broadcasting over leading dims of w."""
    nb, bs = a.shape[-3], a.shape[-1]
    lead_w = w.shape[:-2]
    din, dout = w.shape[-2:]
    assert din == nb * bs, f"gram blocks {nb}×{bs} mismatch din {din}"
    wb = w.reshape(*lead_w, nb, bs, dout)
    out = op_result_of(_align_gram(a, lead_w), wb)
    return out.reshape(*lead_w, din, dout).astype(w.dtype)


def precondition_tree(params: PyTree, grads: PyTree, grams: PyTree, *,
                      damping: float, method: str = "cholesky",
                      ns_iters: int = 20, packed: bool = True) -> PyTree:
    """Return the FOOF-preconditioned gradient tree (Eq. 11 direction).

    Linear params with a gram get (A+δI)⁻¹g per block; the embedding gets
    the exact diagonal solve; everything else passes through unchanged
    (→ plain first-order step, DESIGN.md §Arch-applicability).

    ``packed=True`` (default) runs the gram-bank engine: one batched
    factor+solve per block size (and for ``pallas_ns``/``pallas_chol``
    the fused invert-and-apply kernels — adaptive Newton–Schulz or
    Schur-recursive blocked Cholesky); ``packed=False`` is the per-leaf
    reference.
    """
    if packed:
        return B.precondition_tree(params, grads, grams, damping=damping,
                                   method=method, ns_iters=ns_iters)

    def walk(p_level, g_level, a_level):
        if isinstance(p_level, dict):
            out = {}
            for k in p_level:
                pk, gk = p_level[k], g_level[k]
                ak = a_level[k] if isinstance(a_level, dict) else None
                if isinstance(pk, dict):
                    out[k] = walk(pk, gk, ak)
                    continue
                a = _resolve_gram(k, a_level) if isinstance(a_level, dict) else None
                out[k] = _precondition_leaf(pk, gk, a, damping, method, ns_iters)
            return out
        return jax.tree.map(lambda g: g, g_level)

    return walk(params, grads, grams)


def _precondition_leaf(p, g, a, damping, method, ns_iters):
    if a is None or a.size == 0:
        return g
    if a.ndim < 3:
        # diagonal gram (embedding): a [V]; g [V, D]
        if a.shape[-1] == g.shape[-2]:
            return (g.astype(jnp.float32)
                    / (a[..., None] + damping)).astype(g.dtype)
        return g
    solve = partial(inv.solve, damping=damping, method=method,
                    ns_iters=ns_iters)
    return _blocked_apply(solve, a, g)


def mix_preconditioned(params_stack: PyTree, grams_stack: PyTree, *,
                       damping: float, method: str = "cholesky",
                       ns_iters: int = 20, weights: jax.Array | None = None,
                       packed: bool = True, axes: tuple = (),
                       gram_scale: jax.Array | None = None) -> PyTree:
    """FedPM server mixing (Eq. 12) over participant-stacked trees.

    Participation contract: the leading axis of params_stack / grams_stack
    is the GATHERED participant axis S — every stacked message is in the
    round (client sampling gathers before stacking; see
    ``repro.fl.simulate``).  Params with a gram:
    θ = (Σ_i w_i A_i + δI)⁻¹ · Σ_i w_i (A_i + δI) θ_i with Σw_i = 1
    (uniform by default; ``weights`` [S] reweights participants, e.g. by
    data size).  Others: plain weighted mean (simple mixing).  Mixing
    identical params is the identity for any SPD grams — tested property.

    ``packed=True`` (default) mixes through the gram bank: per block-size
    group ONE batched (A_i+δI)θ_i matmul, one Ā factorization and one
    solve — and for ``pallas_ns``/``pallas_chol`` on an unsharded stack,
    ONE fused kernel launch doing reduce → invert → apply without leaving
    VMEM; ``packed=False`` is the per-leaf reference.

    ``axes``: mesh axes the participant stack is sharded over — inside
    ``repro.fl.sharded``'s manual region the leading axis is each shard's
    local bucket and every mean gains one cross-shard psum (per
    block-size group when packed).

    ``gram_scale``: optional [S] per-participant curvature scale — the
    staleness-damping hook (``Ã_i = s_i A_i``): every gram enters BOTH
    the numerator Σw_i(Ã_i+δI)θ_i and the mixed denominator Ā, so scaling
    toward zero degrades that report gracefully toward plain weighted
    averaging while the δI floor keeps the solve well-posed.  A scale of
    exactly 1.0 is a bitwise no-op (x·1.0 is exact), which is what the
    async engine's zero-staleness equivalence contract rides on.
    """
    axes = tuple(axes)
    if packed:
        return B.mix_preconditioned(params_stack, grams_stack,
                                    damping=damping, method=method,
                                    ns_iters=ns_iters, weights=weights,
                                    axes=axes, gram_scale=gram_scale)
    if gram_scale is not None:
        # per-leaf reference: scale every gram leaf up front (fp32, cast
        # back) — the packed path scales the packed bank identically, so
        # packed ≡ per-leaf still holds under staleness damping
        gs = gram_scale.astype(jnp.float32)
        grams_stack = jax.tree.map(
            lambda a: (a.astype(jnp.float32)
                       * gs.reshape(gs.shape[:1] + (1,) * (a.ndim - 1))
                       ).astype(a.dtype) if a.size else a,
            grams_stack)
    n = jax.tree.leaves(params_stack)[0].shape[0]
    w = B.normalize_weights(weights, n, axes)

    def wmean(x):
        r = jnp.tensordot(w.astype(jnp.float32),
                          x.astype(jnp.float32), axes=1)
        if axes:
            r = jax.lax.psum(r, axes)
        return r.astype(x.dtype)

    def walk(p_level, a_level):
        if isinstance(p_level, dict):
            out = {}
            for k in p_level:
                pk = p_level[k]
                if isinstance(pk, dict):
                    out[k] = walk(pk, a_level[k] if isinstance(a_level, dict) else None)
                    continue
                a = _resolve_gram(k, a_level) if isinstance(a_level, dict) else None
                out[k] = _mix_leaf(pk, a, damping, method, ns_iters, wmean)
            return out
        return jax.tree.map(wmean, p_level)

    return walk(params_stack, grams_stack)


def _mix_leaf(p_stack, a_stack, damping, method, ns_iters, wmean):
    mean = wmean(p_stack)
    if a_stack is None or a_stack.size == 0:
        return mean
    if a_stack.ndim < 4:
        # diagonal gram: [N, V]; params [N, V, D]
        if a_stack.shape[-1] != p_stack.shape[-2]:
            return mean
        num = wmean((a_stack[..., None] + damping)
                    * p_stack.astype(jnp.float32))
        den = wmean(a_stack)[..., None] + damping
        return (num / den).astype(p_stack.dtype)
    # blocked matrix gram: a [N, ..., nb, bs, bs]; p [N, ..., din, dout]
    nb, bs = a_stack.shape[-3], a_stack.shape[-1]
    din, dout = p_stack.shape[-2:]
    if din != nb * bs:
        return mean
    lead = p_stack.shape[1:-2]
    pb = p_stack.reshape(p_stack.shape[0], *lead, nb, bs, dout).astype(jnp.float32)
    a_b = jax.vmap(lambda a: _align_gram(a, lead))(a_stack.astype(jnp.float32))
    ad = a_b + damping * jnp.eye(bs, dtype=jnp.float32)
    num = wmean(ad @ pb)                                  # Σ w_i (A_i+δI)θ_i
    abar = wmean(a_b)                                     # Σ w_i A_i
    out = inv.solve(abar, num, damping=damping, method=method,
                    ns_iters=ns_iters)
    return out.reshape(*lead, din, dout).astype(p_stack.dtype)


# ----------------------------------------------- amortized preconditioner --

def _invert_leaf(a, damping, method, ns_iters):
    if a.size == 0:
        return a
    if a.ndim < 3 or a.shape[-1] != a.shape[-2]:
        return 1.0 / (a.astype(jnp.float32) + damping)   # diagonal
    return inv.inverse(a, damping, method=method, ns_iters=ns_iters)


def invert_grams(grams: PyTree, *, damping: float, method: str = "cholesky",
                 ns_iters: int = 20, packed: bool = True) -> PyTree:
    """Precompute (A+δI)⁻¹ for every gram leaf (§Perf C4: the paper computes
    FOOF matrices once per round — this is that trick as a first-class step:
    refresh every F steps, apply the cached inverses in between).

    ``packed=True`` (default) inverts through the gram bank — one batched
    inverse per block size; ``packed=False`` is the per-leaf reference.
    """
    if packed:
        return B.invert_grams(grams, damping=damping, method=method,
                              ns_iters=ns_iters)
    return jax.tree.map(partial(_invert_leaf, damping=damping, method=method,
                                ns_iters=ns_iters), grams)


def apply_inverses(params: PyTree, grads: PyTree, inverses: PyTree) -> PyTree:
    """Preconditioned gradients using cached inverses (pure matmuls)."""
    def walk(p_level, g_level, i_level):
        if isinstance(p_level, dict):
            out = {}
            for k in p_level:
                pk, gk = p_level[k], g_level[k]
                ik = i_level[k] if isinstance(i_level, dict) else None
                if isinstance(pk, dict):
                    out[k] = walk(pk, gk, ik)
                    continue
                a = _resolve_gram(k, i_level) if isinstance(i_level, dict) else None
                out[k] = _apply_inv_leaf(pk, gk, a)
            return out
        return g_level

    return walk(params, grads, inverses)


def _apply_inv_leaf(p, g, ainv):
    if ainv is None or ainv.size == 0:
        return g
    if ainv.ndim < 3:
        if ainv.shape[-1] == g.shape[-2]:     # diagonal inverse [V]
            return (g.astype(jnp.float32) * ainv[..., None]).astype(g.dtype)
        return g
    matmul = lambda a, w: (a @ w.astype(jnp.float32)).astype(w.dtype)
    return _blocked_apply(matmul, ainv, g)


# ------------------------------------------------- shard_map (psum) mixing --

def mix_preconditioned_psum(params: PyTree, grams: PyTree, *, axes,
                            damping: float, method: str = "cholesky",
                            ns_iters: int = 20, packed: bool = True
                            ) -> PyTree:
    """Eq. 12 inside a shard_map manual region: the client "stack" is the
    mesh axes ``axes``; means become psums.  Every cohort on the mesh is a
    participant by construction (full participation), so this is exactly
    ``mix_preconditioned`` with uniform weights over the gathered axis
    (tested equivalence).

    ``packed=True`` (default) mixes through the gram bank — two psums per
    block-size group instead of two per layer; ``packed=False`` is the
    per-leaf reference.
    """
    if packed:
        return B.mix_preconditioned_psum(params, grams, axes=axes,
                                         damping=damping, method=method,
                                         ns_iters=ns_iters)
    axes = tuple(axes)

    def pmean(x):
        return jax.lax.pmean(x, axes)

    def walk(p_level, a_level):
        if isinstance(p_level, dict):
            out = {}
            for k in p_level:
                pk = p_level[k]
                if isinstance(pk, dict):
                    out[k] = walk(pk, a_level[k] if isinstance(a_level, dict) else None)
                    continue
                a = _resolve_gram(k, a_level) if isinstance(a_level, dict) else None
                out[k] = _mix_leaf_psum(pk, a, damping, method, ns_iters, pmean)
            return out
        return jax.tree.map(pmean, p_level)

    return walk(params, grams)


def _mix_leaf_psum(p, a, damping, method, ns_iters, pmean):
    if a is None or a.size == 0:
        return pmean(p)
    if a.ndim < 3:
        # diagonal gram (embedding): a [V]; p [V, D]
        if a.shape[-1] != p.shape[-2]:
            return pmean(p)
        num = pmean((a[..., None] + damping) * p.astype(jnp.float32))
        den = pmean(a)[..., None] + damping
        return (num / den).astype(p.dtype)
    nb, bs = a.shape[-3], a.shape[-1]
    din, dout = p.shape[-2:]
    if din != nb * bs:
        return pmean(p)
    lead = p.shape[:-2]
    pb = p.reshape(*lead, nb, bs, dout).astype(jnp.float32)
    a_b = _align_gram(a.astype(jnp.float32), lead)
    ad = a_b + damping * jnp.eye(bs, dtype=jnp.float32)
    num = pmean(ad @ pb)
    abar = pmean(a_b)
    out = inv.solve(abar, num, damping=damping, method=method,
                    ns_iters=ns_iters)
    return out.reshape(*lead, din, dout).astype(p.dtype)

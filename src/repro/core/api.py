"""Compositional algorithm API: LocalUpdate × Message × ServerMixer.

The paper's core move is a *decomposition* — the ideal second-order update
splits into local client solves and preconditioned mixing on the server
(Eq. 6 → Eq. 9/12).  This module makes that decomposition the programming
model: an :class:`Algorithm` is the composition of

* a :class:`LocalUpdate` — the client-side solver (sgd / prox / scaffold /
  full-newton / foof / diagonal-sophia ...).  Each declares ``provides``
  (the message fields it can furnish, some lazily) and ``hparams`` (the
  :class:`~repro.core.algorithms.HParams` fields it actually reads, instead
  of implicitly depending on the whole flat grab-bag);
* a :class:`Message` — a typed, pytree-registered dataclass replacing the
  ad-hoc ``{"theta": ..., "loss": ...}`` dicts.  Its ``WIRE`` fields are
  exactly what crosses the client→server wire; ``METRICS`` fields (the
  per-round ``loss``) are telemetry and excluded from
  :meth:`Message.bytes_on_wire`;
* a :class:`ServerMixer` — the server-side aggregation (mean / momentum /
  adam / scaffold-control / preconditioned-mix ...).  Each declares
  ``needs`` — the wire fields it consumes — and aggregates through the
  engine-supplied ``Participation`` only, so mixers stay engine-agnostic
  (vmap stack, or sharded buckets with psum axes).

:func:`register` composes the three into the engine-facing
``(init_server, init_client, client, server)`` quadruple: the registry is
a *cross-product* — new scenarios (fedprox local + preconditioned mixing,
scaffold + FOOF) are one-line registrations, not copy-pasted closures.

Wire transforms
---------------
A registration may attach a :class:`WireTransform` — a pure-jax
encode/decode pair applied at the client→server boundary (encode inside
the vmapped client fn, decode on the stacked messages before the mixer).
Transforms change what the ``WIRE`` fields *hold* (bf16 leaves, top-k
(values, indices) pairs, rank-r gram sketches), which is exactly what the
bytes accounting measures — the communication-cost axis that Fed-Sophia
and FedNS-style sketching make central to second-order FL.

Everything stays a pure pytree: messages (transformed or not) scan, vmap,
donate, and shard exactly like the dicts they replace — the round-body
purity contract of ``repro.fl.simulate.FedSim.run_scanned`` is unchanged.

Communication accounting
------------------------
:func:`comm_cost` computes exact per-client ``bytes_up`` (the encoded
message's wire fields) and ``bytes_down`` (params, plus server state for
mixers that broadcast it — SCAFFOLD's control variate, FedNS's sketch
frame) via ``jax.eval_shape`` — no compilation, no execution.  The
simulation engine surfaces these as per-round ``bytes_up``/``bytes_down``
metrics.

This module is framework only; the concrete solvers/mixers and the zoo
registrations live in :mod:`repro.core.algorithms`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

CATEGORIES = ("FOGM", "FOPM", "SOGM", "SOPM")


def _no_server_state(task, hp, params):
    return ()


def _no_client_state(task, params):
    return ()


# ================================================================ messages ==

class Message:
    """Base for typed wire messages.

    Subclasses (built by :func:`message_cls`) are frozen dataclasses
    registered as jax pytrees.  ``WIRE`` names the fields that cross the
    client→server wire; ``METRICS`` names telemetry fields (``loss``)
    that ride along for the engine's per-round metrics but are not part
    of the communication payload.
    """
    WIRE: tuple = ()
    METRICS: tuple = ()

    def wire_tree(self) -> dict:
        """The wire payload as a dict pytree (what a transport would send)."""
        return {f: getattr(self, f) for f in self.WIRE}

    def bytes_on_wire(self) -> int:
        """Exact payload bytes of the WIRE fields.  Works on concrete
        arrays and on ``jax.eval_shape`` structs alike."""
        return wire_bytes(self.wire_tree())


def wire_bytes(tree: PyTree) -> int:
    """Total bytes of a pytree's leaves (arrays or ShapeDtypeStructs)."""
    total = 0
    for x in jax.tree.leaves(tree):
        total += int(np.prod(getattr(x, "shape", ()))) * \
            np.dtype(x.dtype).itemsize
    return total


@lru_cache(maxsize=None)
def message_cls(wire: tuple, metrics: tuple = ()) -> type:
    """The typed message dataclass for a (wire, metrics) field set.

    Cached so every registration with the same field set shares one
    class (and one pytree registration).  Field order is wire then
    metrics — stable, so jaxpr/pytree structure is deterministic.
    """
    fields = tuple(wire) + tuple(metrics)
    if len(set(fields)) != len(fields):
        raise ValueError(f"duplicate message fields: {fields}")
    name = "Msg_" + "_".join(fields) if fields else "Msg_empty"
    cls = dataclasses.make_dataclass(name, fields, bases=(Message,),
                                     frozen=True)
    cls.WIRE = tuple(wire)
    cls.METRICS = tuple(metrics)
    jax.tree_util.register_pytree_node(
        cls,
        lambda m: (tuple(getattr(m, f) for f in fields), None),
        lambda _, children: cls(*children))
    return cls


def client_loss(msgs):
    """The per-round loss metric of a stacked message, or None.

    Accepts typed messages and legacy dict messages (custom Algorithm
    objects built outside the registry keep working).
    """
    if isinstance(msgs, Message):
        return getattr(msgs, "loss", None) if "loss" in msgs.METRICS else None
    if isinstance(msgs, dict):
        return msgs.get("loss")
    return None


# ========================================================= wire transforms ==

class WireTransform:
    """Pure-jax encode/decode applied at the client→server boundary.

    ``encode`` runs inside the (vmapped) client fn on a single client's
    message; ``decode`` runs server-side on the participant-stacked
    message (leading axis S) and receives the server's ``params`` as the
    reference tree for fields that mirror the parameter structure.
    Both must be pure jax (scan/vmap/shard_map safe).
    """
    name: str = "identity"
    #: message fields the transform touches; () = every WIRE field
    fields: tuple = ()

    def _targets(self, msg: Message) -> tuple:
        return tuple(self.fields) or msg.WIRE

    def encode(self, msg: Message) -> Message:
        return msg

    def decode(self, msgs: Message, params: PyTree) -> Message:
        return msgs

    def _map_fields(self, msg, fn):
        return dataclasses.replace(
            msg, **{f: fn(getattr(msg, f)) for f in self._targets(msg)
                    if f in msg.WIRE})


@dataclass(frozen=True)
class Bf16Wire(WireTransform):
    """Cast float wire leaves to bfloat16 on the wire (2× uplink saving);
    the server decodes back to float32 before aggregation."""
    fields: tuple = ()
    name: str = "bf16"

    def encode(self, msg):
        cast = lambda t: jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, t)
        return self._map_fields(msg, cast)

    def decode(self, msgs, params):
        up = lambda t: jax.tree.map(
            lambda x: x.astype(jnp.float32)
            if x.dtype == jnp.bfloat16 else x, t)
        return self._map_fields(msgs, up)


@dataclass(frozen=True)
class TopKWire(WireTransform):
    """Magnitude top-k sparsification of params-shaped wire fields
    (``delta``/``theta``/``grad``): each leaf becomes a
    ``{"v": [k], "i": [k] int32}`` pair; the server scatters back to
    dense (zeros elsewhere).  ``frac`` is the kept fraction per leaf."""
    frac: float = 0.1
    fields: tuple = ("delta",)
    name: str = "topk"

    def _k(self, n: int) -> int:
        return max(1, int(n * self.frac))

    def encode(self, msg):
        def enc_leaf(x):
            flat = x.reshape(-1)
            _, i = jax.lax.top_k(jnp.abs(flat), self._k(flat.shape[0]))
            return {"v": jnp.take(flat, i), "i": i.astype(jnp.int32)}
        return self._map_fields(msg, lambda t: jax.tree.map(enc_leaf, t))

    def decode(self, msgs, params):
        def dec_field(enc_tree, ref_tree):
            def dec_leaf(enc, ref):
                n = int(np.prod(ref.shape))
                dense = jax.vmap(
                    lambda v, i: jnp.zeros((n,), v.dtype).at[i].set(v))(
                        enc["v"], enc["i"])
                return dense.reshape(enc["v"].shape[0], *ref.shape)
            # enc_tree nests {"v","i"} below each ref leaf — walk ref
            return jax.tree.map(
                lambda ref, enc: dec_leaf(enc, ref), ref_tree, enc_tree,
                is_leaf=lambda x: isinstance(x, dict) and set(x) == {"v", "i"})
        return dataclasses.replace(
            msgs, **{f: dec_field(getattr(msgs, f), params)
                     for f in self._targets(msgs) if f in msgs.WIRE})


@lru_cache(maxsize=None)
def _sketch_frame(bs: int, rank: int) -> np.ndarray:
    """Deterministic orthonormal [bs, rank] test frame (shared by every
    client and the server — the FedNS trick, no frame on the wire)."""
    gauss = np.random.default_rng(7).normal(size=(bs, rank))
    q, _ = np.linalg.qr(gauss)
    return q.astype(np.float32)


@dataclass(frozen=True)
class GramSketchWire(WireTransform):
    """Rank-r Nyström sketch of square gram blocks: a ``[..., bs, bs]``
    SPD block ships as ``{"ny": Y = A @ Ω}`` (``[..., bs, r]``, r < bs);
    the server reconstructs ``Â = Y (ΩᵀY)⁻¹ Yᵀ``.  Leaves that are not
    square blocks (diagonal embedding grams, size-0 placeholders,
    rectangular arrays) — or already at/below the rank — pass through
    untouched.  The ``{"ny": ...}`` wrapper marks exactly the encoded
    leaves, so decode can never mistake an unencoded tall array (e.g. a
    params-shaped field the transform was misregistered on) for a
    sketch; wrapping adds pytree structure, not wire bytes."""
    rank: int = 8
    fields: tuple = ("grams",)
    name: str = "gram_sketch"

    def _is_block(self, x) -> bool:
        return (getattr(x, "ndim", 0) >= 2 and x.shape[-1] == x.shape[-2]
                and x.shape[-1] > 1 and x.size > 0)

    def encode(self, msg):
        def enc_leaf(x):
            if not self._is_block(x) or x.shape[-1] <= self.rank:
                return x          # nothing to compress: ship A itself
            omega = jnp.asarray(_sketch_frame(x.shape[-1], self.rank))
            return {"ny": x.astype(jnp.float32) @ omega}
        return self._map_fields(msg, lambda t: jax.tree.map(enc_leaf, t))

    def decode(self, msgs, params):
        def dec_leaf(leaf):
            if not (isinstance(leaf, dict) and set(leaf) == {"ny"}):
                return leaf                        # was never encoded
            y = leaf["ny"]
            bs, r = y.shape[-2], y.shape[-1]
            omega = jnp.asarray(_sketch_frame(bs, r))
            core = jnp.swapaxes(y, -1, -2) @ omega    # YᵀΩ = ΩᵀAΩ (A SPD)
            core = 0.5 * (core + jnp.swapaxes(core, -1, -2)) \
                + 1e-6 * jnp.eye(r, dtype=y.dtype)
            a_hat = y @ jnp.linalg.solve(core, jnp.swapaxes(y, -1, -2))
            return 0.5 * (a_hat + jnp.swapaxes(a_hat, -1, -2))
        is_enc = lambda x: isinstance(x, dict) and set(x) == {"ny"}
        return self._map_fields(
            msgs, lambda t: jax.tree.map(dec_leaf, t, is_leaf=is_enc))


# ============================================================== components ==

@dataclass(frozen=True)
class LocalUpdate:
    """A client-side solver.

    ``run(task, hp, params, cstate, sstate, batches, rng) ->
    (fields, new_cstate)`` where ``fields`` maps every name in
    ``provides`` to a value or a 0-arg thunk (lazy — only the fields the
    composed message actually carries are materialized, so e.g. grams
    are never computed for a plain-mean registration).

    ``hparams`` declares the :class:`HParams` fields the solver reads;
    ``field_hparams`` adds per-optional-field extras (e.g. transmitting
    ``grams`` reads ``foof_timing``).  Declarations are enforced by the
    registry sweep test: perturbing any *undeclared* field must not
    change the round's output bitwise.
    """
    name: str
    run: Callable
    provides: tuple
    metrics: tuple = ()
    hparams: tuple = ()
    field_hparams: dict = field(default_factory=dict)
    init_client: Callable = _no_client_state
    needs_hessian: bool = False
    needs_grams: bool = False


@dataclass(frozen=True)
class ServerMixer:
    """A server-side aggregation rule.

    ``mix(task, hp, params, sstate, msg, part) -> (new_params, sstate)``
    consumes the participant-stacked typed message and aggregates ONLY
    through ``part`` (``wmean`` / ``n_sampled`` / ``axes``) so the same
    mixer runs on the vmap stack and inside sharded shard_map buckets.
    ``needs`` are the wire fields it consumes — the registry builds the
    message from exactly these.  ``broadcasts_state = True`` marks
    mixers whose server state rides the downlink to every client
    (SCAFFOLD's control variate, FedNS's sketch frame) for the
    ``bytes_down`` accounting.

    ``damping`` is the mixer's declared STALENESS hook, mirroring how
    ``LocalUpdate.hparams`` declares reads: ``damping(hp, staleness)``
    maps per-report round-age ``[S]`` to a curvature scale ``[S]``
    applied to each report's gram bank before the preconditioned mix
    (Eq. 12) — the buffered-async engine feeds ``Participation.
    staleness`` and ONLY mixers that declare the hook may react to it.
    ``damping is None`` (the default) declares "staleness-blind":
    the registry sweep test perturbs ``staleness`` (weights fixed) and
    requires the round's output bitwise unchanged for such mixers, so
    an undeclared read fails CI the same way an undeclared hparam does.
    """
    name: str
    needs: tuple
    mix: Callable
    init_server: Callable = _no_server_state
    hparams: tuple = ()
    broadcasts_state: bool = False
    damping: Callable | None = None


@dataclass(frozen=True)
class Algorithm:
    """An engine-facing algorithm (possibly composed via :func:`register`).

    The engine contract is unchanged from the monolithic zoo:
    ``init_server/init_client/client/server`` with ``client`` vmapped
    over participants and ``server`` consuming the stacked messages plus
    a ``Participation``.  Composed instances additionally carry their
    parts (``local``, ``mixer``, ``wire``, ``message_cls``) for
    introspection, docs tables, and comm accounting.
    """
    name: str
    category: str
    init_server: Callable
    init_client: Callable
    client: Callable
    server: Callable
    needs_hessian: bool = False
    needs_grams: bool = False
    local: LocalUpdate | None = None
    mixer: ServerMixer | None = None
    wire: WireTransform | None = None
    message_cls: type | None = None

    @property
    def stateless(self) -> bool:
        """True when clients carry NO persistent state (the FedAvg /
        FedAdam family: ``init_client`` is the empty-state default).
        Stateless registrations have an empty client-state tree, so the
        paged engine (``repro.fl.store``) stages and writes back zero
        client-state bytes for them — paging is free."""
        return self.init_client is _no_client_state

    @property
    def hparams(self) -> tuple:
        """HParams fields this algorithm reads (sorted union of its
        parts' declarations, including per-wire-field extras)."""
        if self.local is None or self.mixer is None:
            return ()
        hs = set(self.local.hparams) | set(self.mixer.hparams)
        for f in self.mixer.needs:
            hs |= set(self.local.field_hparams.get(f, ()))
        return tuple(sorted(hs))


# ================================================================ registry ==

LOCAL_UPDATES: dict[str, LocalUpdate] = {}
SERVER_MIXERS: dict[str, ServerMixer] = {}
ALGORITHMS: dict[str, Algorithm] = {}


def register_local(lu: LocalUpdate) -> LocalUpdate:
    if lu.name in LOCAL_UPDATES:
        raise ValueError(f"local update {lu.name!r} already registered")
    LOCAL_UPDATES[lu.name] = lu
    return lu


def register_mixer(m: ServerMixer) -> ServerMixer:
    if m.name in SERVER_MIXERS:
        raise ValueError(f"server mixer {m.name!r} already registered")
    SERVER_MIXERS[m.name] = m
    return m


def _compose_client(local: LocalUpdate, mcls: type,
                    wire: WireTransform | None) -> Callable:
    def client(task, hp, params, cstate, sstate, batches, rng):
        out, new_cstate = local.run(task, hp, params, cstate, sstate,
                                    batches, rng)
        kw = {}
        for f in mcls.WIRE + mcls.METRICS:
            v = out[f]
            kw[f] = v() if callable(v) else v
        msg = mcls(**kw)
        if wire is not None:
            msg = wire.encode(msg)
        return msg, new_cstate
    return client


def _compose_server(mixer: ServerMixer, wire: WireTransform | None
                    ) -> Callable:
    def server(task, hp, params, sstate, msgs, part):
        if wire is not None:
            msgs = wire.decode(msgs, params)
        return mixer.mix(task, hp, params, sstate, msgs, part)
    return server


def decode_msgs(algo: Algorithm, msgs, params) -> Any:
    """Apply ``algo``'s wire decode to a participant-stacked message —
    the server-side half of the wire boundary, exposed for engines that
    need to SEE the decoded message before mixing (the fault-quarantine
    round validates reports after decode, then calls
    :func:`mix_decoded`).  Identity when the registration carries no
    wire transform."""
    if algo.wire is not None:
        return algo.wire.decode(msgs, params)
    return msgs


def mix_decoded(algo: Algorithm, task, hp, params, sstate, msgs, part):
    """Run ``algo``'s server aggregation on an ALREADY-DECODED message
    stack.  ``algo.server`` cannot be used for this — the composed
    server decodes internally, and a second decode is not idempotent for
    every transform (top-k would walk dense leaves expecting
    ``{"v","i"}`` pairs).  Legacy algorithms built outside the registry
    have no mixer and no wire, so their ``server`` IS the mix."""
    if algo.mixer is not None:
        return algo.mixer.mix(task, hp, params, sstate, msgs, part)
    return algo.server(task, hp, params, sstate, msgs, part)


def register(name: str, category: str, local: str | LocalUpdate,
             mixer: str | ServerMixer, *, wire: WireTransform | None = None
             ) -> Algorithm:
    """Compose a LocalUpdate and a ServerMixer (plus an optional wire
    transform) into a named, engine-ready :class:`Algorithm`."""
    if name in ALGORITHMS:
        raise ValueError(f"algorithm {name!r} already registered")
    if category not in CATEGORIES:
        raise ValueError(f"category {category!r} not in {CATEGORIES}")
    lu = LOCAL_UPDATES[local] if isinstance(local, str) else local
    mx = SERVER_MIXERS[mixer] if isinstance(mixer, str) else mixer
    missing = [f for f in mx.needs if f not in lu.provides]
    if missing:
        raise ValueError(
            f"{name!r}: mixer {mx.name!r} needs {missing} which local "
            f"update {lu.name!r} does not provide (provides {lu.provides})")
    mcls = message_cls(tuple(mx.needs), tuple(lu.metrics))
    algo = Algorithm(
        name=name, category=category,
        init_server=mx.init_server, init_client=lu.init_client,
        client=_compose_client(lu, mcls, wire),
        server=_compose_server(mx, wire),
        needs_hessian=lu.needs_hessian,
        needs_grams=lu.needs_grams or "grams" in mx.needs,
        local=lu, mixer=mx, wire=wire, message_cls=mcls)
    ALGORITHMS[name] = algo
    return algo


def get_algorithm(name: str) -> Algorithm:
    import repro.core.algorithms  # noqa: F401  (populates the registry)
    if name not in ALGORITHMS:
        raise KeyError(f"unknown algorithm {name!r}; "
                       f"choose from {sorted(ALGORITHMS)}")
    return ALGORITHMS[name]


def unused_hparams(algo: Algorithm, hp) -> tuple:
    """HParams fields set away from their defaults that ``algo`` declares
    it never reads — a registration-metadata lint for experiment configs."""
    if algo.local is None:
        return ()
    read = set(algo.hparams)
    out = []
    for f in dataclasses.fields(hp):
        if f.name not in read and getattr(hp, f.name) != f.default:
            out.append(f.name)
    return tuple(out)


# ========================================================= comm accounting ==

def message_struct(algo: Algorithm, task, hp, params, cstate, sstate,
                   batch) -> Message:
    """Shape-only evaluation of one client's (encoded) message.

    All tree args may be concrete arrays or ShapeDtypeStructs; nothing is
    executed or compiled.  ``batch`` is ONE client's ``[K, B, ...]``
    batches."""
    msg, _ = jax.eval_shape(
        lambda p, c, sv, b, r: algo.client(task, hp, p, c, sv, b, r),
        params, cstate, sstate, batch, jax.random.PRNGKey(0))
    return msg


def downlink_bytes(algo: Algorithm, params, sstate) -> int:
    """Per-client downlink payload: the params broadcast, plus server
    state for mixers that broadcast it (SCAFFOLD's control variate,
    FedNS's sketch frame).  THE definition of ``bytes_down`` — shared by
    :func:`comm_cost` and the engine's per-round metrics."""
    down = wire_bytes(params)
    if algo.mixer is not None and algo.mixer.broadcasts_state:
        down += wire_bytes(sstate)
    return down


def message_wire_bytes(msg) -> int:
    """Uplink payload bytes of one client's message (typed messages count
    WIRE fields only; legacy dict messages count everything but the
    ``loss`` metric)."""
    if isinstance(msg, Message):
        return msg.bytes_on_wire()
    if isinstance(msg, dict):                  # legacy dict message
        return wire_bytes({k: v for k, v in msg.items() if k != "loss"})
    return wire_bytes(msg)


def comm_cost(algo: Algorithm | str, task, hp, batch, *, s: int = 1,
              rng=None) -> dict:
    """Exact per-round communication cost for a cohort of ``s`` clients.

    ``bytes_up`` counts the encoded WIRE fields of every participant's
    message; ``bytes_down`` counts the params broadcast (plus server
    state for ``broadcasts_state`` mixers).  Pure ``eval_shape`` — safe
    to call on any model size."""
    algo = get_algorithm(algo) if isinstance(algo, str) else algo
    rng = jax.random.PRNGKey(0) if rng is None else rng
    params = jax.eval_shape(task.init, rng)
    sstate = jax.eval_shape(lambda p: algo.init_server(task, hp, p), params)
    cstate = jax.eval_shape(lambda p: algo.init_client(task, p), params)
    msg = message_struct(algo, task, hp, params, cstate, sstate, batch)
    up = message_wire_bytes(msg)
    down = downlink_bytes(algo, params, sstate)
    return {"bytes_up": up * s, "bytes_down": down * s,
            "bytes_up_per_client": up, "bytes_down_per_client": down}

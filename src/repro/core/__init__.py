"""FedPM core: preconditioned mixing, FOOF, inverses, the algorithm zoo
(a compositional LocalUpdate × Message × ServerMixer registry)."""
from repro.core.algorithms import ALGORITHMS, Algorithm, HParams, get_algorithm
from repro.core.api import (LocalUpdate, Message, ServerMixer, WireTransform,
                            comm_cost, register, register_local,
                            register_mixer)
from repro.core.bank import (GramBank, PackedPreconditioner,
                             apply_preconditioner, build_preconditioner)
from repro.core.foof import mix_preconditioned, precondition_tree, GRAM_ROUTES
from repro.core.inverse import inverse, ns_inverse, solve

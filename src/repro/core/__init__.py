"""FedPM core: preconditioned mixing, FOOF, inverses, the algorithm zoo."""
from repro.core.algorithms import ALGORITHMS, Algorithm, HParams, get_algorithm
from repro.core.bank import (GramBank, PackedPreconditioner,
                             apply_preconditioner, build_preconditioner)
from repro.core.foof import mix_preconditioned, precondition_tree, GRAM_ROUTES
from repro.core.inverse import inverse, ns_inverse, solve

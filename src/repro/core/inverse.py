"""Damped SPD inverses for FedPM preconditioning.

Two paths (DESIGN.md §4.1):
  - ``cholesky``: dense SPD factorization via ``cho_factor``/``cho_solve``
    (the paper's choice; oracle here).  One factorization + two triangular
    solves — ~3× cheaper than the LU that ``jnp.linalg.solve`` would run,
    and it exploits symmetry that LU ignores.
  - ``ns``: Newton–Schulz iteration  X ← X(2I − AX)  — pure matmuls, the
    TPU-native path.  The Pallas kernel in ``repro.kernels.nschulz`` computes
    the same iteration with explicit VMEM tiling; this module is its jnp
    reference and the dispatch point (set ``use_pallas=True``).

``cholesky_safe`` is the fault-tolerant variant (:func:`solve_escalated`):
damping escalation δ → 10δ → 100δ per matrix with an identity-
preconditioner fallback, for banks that may be indefinite after a
poisoned-report quarantine.

Kernel-backed methods (``repro.kernels``): ``pallas_ns`` — the fused
adaptive Newton–Schulz kernel (in-VMEM convergence test); ``pallas_chol``
— the Schur-recursive blocked-Cholesky kernel (exact, matmul-rich; on CPU
it dispatches to the same Schur restructuring in jnp with LAPACK leaf
tiles).

All functions are batched over arbitrary leading dims.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve


def damp(a: jax.Array, damping: float) -> jax.Array:
    n = a.shape[-1]
    return a + damping * jnp.eye(n, dtype=a.dtype)


def ns_inverse(a: jax.Array, iters: int = 20) -> jax.Array:
    """Approximate A⁻¹ for SPD A via Newton–Schulz.

    Init X₀ = Aᵀ/(‖A‖₁‖A‖∞) guarantees ‖I − AX₀‖ < 1; convergence is then
    quadratic.  ``iters=20`` covers condition numbers ≳ 1e5.
    """
    af = a.astype(jnp.float32)
    n1 = jnp.max(jnp.sum(jnp.abs(af), axis=-1), axis=-1)   # ‖A‖∞
    ninf = jnp.max(jnp.sum(jnp.abs(af), axis=-2), axis=-1)  # ‖A‖₁
    x = jnp.swapaxes(af, -1, -2) / (n1 * ninf)[..., None, None]
    eye2 = 2.0 * jnp.eye(a.shape[-1], dtype=jnp.float32)

    def body(x, _):
        return x @ (eye2 - af @ x), None

    x, _ = jax.lax.scan(body, x, None, length=iters)
    return x.astype(a.dtype)


def _cho_solve(ad: jax.Array, bf: jax.Array) -> jax.Array:
    """SPD solve via Cholesky, batched over matching leading dims."""
    c, lower = cho_factor(ad, lower=True)
    return cho_solve((c, lower), bf)


#: damping multipliers tried by the escalating solve, mildest first
ESCALATION = (1.0, 10.0, 100.0)


def solve_escalated(a: jax.Array, b: jax.Array, damping: float = 0.0
                    ) -> jax.Array:
    """Solve (A + dI) x = b with DAMPING ESCALATION — the quarantine
    fallback for grams that are indefinite even after nominal damping
    (a poisoned cohort's surviving bank, accumulated cancellation, a
    near-empty weighted mean).

    ``cho_factor`` on a non-SPD matrix produces NaNs instead of raising
    (LAPACK potrf failure surfaces as non-finite factors under jit), so
    a plain Cholesky path would silently propagate NaN into the mixed
    params — the exact run-killing failure this guards.  Per matrix
    (independently across leading batch dims) the solve tries damping
    d, 10d, 100d and keeps the MILDEST finite result; if all three
    factorizations fail it falls back to the identity preconditioner
    ``x = b`` (degrading the preconditioned mix toward plain weighted
    averaging — graceful, never NaN).  A zero ``damping`` escalates
    from 1e-6 (escalating a zero is a no-op).

    Built as a where-chain over DESCENDING multipliers so the mildest
    finite candidate wins; healthy SPD inputs take the d-damped branch
    and match the plain ``cholesky`` method's solve exactly.
    """
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    lead = jnp.broadcast_shapes(af.shape[:-2], bf.shape[:-2])
    af = jnp.broadcast_to(af, (*lead, *af.shape[-2:]))
    bf = jnp.broadcast_to(bf, (*lead, *bf.shape[-2:]))
    base = float(damping) if damping > 0 else 1e-6
    sol = bf                       # identity-preconditioner fallback
    for mult in sorted(ESCALATION, reverse=True):
        cand = _cho_solve(damp(af, base * mult), bf)
        ok = jnp.all(jnp.isfinite(cand), axis=(-2, -1))[..., None, None]
        sol = jnp.where(ok, cand, sol)
    return sol.astype(b.dtype)


def inverse(a: jax.Array, damping: float = 0.0, *, method: str = "cholesky",
            ns_iters: int = 20) -> jax.Array:
    if method == "cholesky_safe":
        n = a.shape[-1]
        return solve_escalated(
            a, jnp.broadcast_to(jnp.eye(n, dtype=jnp.float32),
                                a.shape[:-2] + (n, n)), damping)
    ad = damp(a.astype(jnp.float32), damping)
    if method == "ns":
        return ns_inverse(ad, ns_iters)
    if method == "pallas_ns":
        from repro.kernels.nschulz import ops as _ops
        return _ops.ns_inverse(ad, iters=ns_iters)
    if method == "pallas_chol":
        from repro.kernels.cholesky import ops as _ops
        return _ops.chol_inverse(ad)
    n = a.shape[-1]
    return _cho_solve(ad, jnp.broadcast_to(jnp.eye(n, dtype=jnp.float32),
                                           ad.shape))


def solve(a: jax.Array, b: jax.Array, damping: float = 0.0, *,
          method: str = "cholesky", ns_iters: int = 20) -> jax.Array:
    """Solve (A + δI) x = b.  a: [..., n, n]; b: [..., n, k]."""
    if method == "cholesky_safe":
        return solve_escalated(a, b, damping)
    ad = damp(a.astype(jnp.float32), damping)
    bf = b.astype(jnp.float32)
    # NS paths invert the UN-broadcast ad (one iteration per distinct
    # matrix) and let the matmul broadcast over b's extra leading dims.
    if method == "ns":
        return (ns_inverse(ad, ns_iters) @ bf).astype(b.dtype)
    if method == "pallas_ns":
        # ``ad`` is already damped — hand it straight to the fused
        # invert-and-apply kernel (no second damp/cast round-trip, and the
        # inverse never materializes in HBM); mismatched leading dims fall
        # back inside ns_solve to one inverse kernel + broadcast matmul.
        from repro.kernels.nschulz import ops as _ops
        return _ops.ns_solve(ad, bf, iters=ns_iters).astype(b.dtype)
    if method == "pallas_chol":
        # fused factor-and-apply: the Schur inverse is built in VMEM and
        # only X@B leaves the kernel; mismatched leading dims fall back
        # inside chol_solve to one inverse kernel + broadcast matmul
        from repro.kernels.cholesky import ops as _ops
        return _ops.chol_solve(ad, bf).astype(b.dtype)
    # broadcast batch dims (the factorization requires matching leading dims)
    lead = jnp.broadcast_shapes(ad.shape[:-2], bf.shape[:-2])
    ad = jnp.broadcast_to(ad, (*lead, *ad.shape[-2:]))
    bf = jnp.broadcast_to(bf, (*lead, *bf.shape[-2:]))
    return _cho_solve(ad, bf).astype(b.dtype)

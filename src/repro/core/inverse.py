"""Damped SPD inverses for FedPM preconditioning.

Two paths (DESIGN.md §4.1):
  - ``cholesky``: dense SPD factorization via ``cho_factor``/``cho_solve``
    (the paper's choice; oracle here).  One factorization + two triangular
    solves — ~3× cheaper than the LU that ``jnp.linalg.solve`` would run,
    and it exploits symmetry that LU ignores.
  - ``ns``: Newton–Schulz iteration  X ← X(2I − AX)  — pure matmuls, the
    TPU-native path.  The Pallas kernel in ``repro.kernels.nschulz`` computes
    the same iteration with explicit VMEM tiling; this module is its jnp
    reference and the dispatch point (set ``use_pallas=True``).

Kernel-backed methods (``repro.kernels``): ``pallas_ns`` — the fused
adaptive Newton–Schulz kernel (in-VMEM convergence test); ``pallas_chol``
— the Schur-recursive blocked-Cholesky kernel (exact, matmul-rich; on CPU
it dispatches to the same Schur restructuring in jnp with LAPACK leaf
tiles).

All functions are batched over arbitrary leading dims.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve


def damp(a: jax.Array, damping: float) -> jax.Array:
    n = a.shape[-1]
    return a + damping * jnp.eye(n, dtype=a.dtype)


def ns_inverse(a: jax.Array, iters: int = 20) -> jax.Array:
    """Approximate A⁻¹ for SPD A via Newton–Schulz.

    Init X₀ = Aᵀ/(‖A‖₁‖A‖∞) guarantees ‖I − AX₀‖ < 1; convergence is then
    quadratic.  ``iters=20`` covers condition numbers ≳ 1e5.
    """
    af = a.astype(jnp.float32)
    n1 = jnp.max(jnp.sum(jnp.abs(af), axis=-1), axis=-1)   # ‖A‖∞
    ninf = jnp.max(jnp.sum(jnp.abs(af), axis=-2), axis=-1)  # ‖A‖₁
    x = jnp.swapaxes(af, -1, -2) / (n1 * ninf)[..., None, None]
    eye2 = 2.0 * jnp.eye(a.shape[-1], dtype=jnp.float32)

    def body(x, _):
        return x @ (eye2 - af @ x), None

    x, _ = jax.lax.scan(body, x, None, length=iters)
    return x.astype(a.dtype)


def _cho_solve(ad: jax.Array, bf: jax.Array) -> jax.Array:
    """SPD solve via Cholesky, batched over matching leading dims."""
    c, lower = cho_factor(ad, lower=True)
    return cho_solve((c, lower), bf)


def inverse(a: jax.Array, damping: float = 0.0, *, method: str = "cholesky",
            ns_iters: int = 20) -> jax.Array:
    ad = damp(a.astype(jnp.float32), damping)
    if method == "ns":
        return ns_inverse(ad, ns_iters)
    if method == "pallas_ns":
        from repro.kernels.nschulz import ops as _ops
        return _ops.ns_inverse(ad, iters=ns_iters)
    if method == "pallas_chol":
        from repro.kernels.cholesky import ops as _ops
        return _ops.chol_inverse(ad)
    n = a.shape[-1]
    return _cho_solve(ad, jnp.broadcast_to(jnp.eye(n, dtype=jnp.float32),
                                           ad.shape))


def solve(a: jax.Array, b: jax.Array, damping: float = 0.0, *,
          method: str = "cholesky", ns_iters: int = 20) -> jax.Array:
    """Solve (A + δI) x = b.  a: [..., n, n]; b: [..., n, k]."""
    ad = damp(a.astype(jnp.float32), damping)
    bf = b.astype(jnp.float32)
    # NS paths invert the UN-broadcast ad (one iteration per distinct
    # matrix) and let the matmul broadcast over b's extra leading dims.
    if method == "ns":
        return (ns_inverse(ad, ns_iters) @ bf).astype(b.dtype)
    if method == "pallas_ns":
        # ``ad`` is already damped — hand it straight to the fused
        # invert-and-apply kernel (no second damp/cast round-trip, and the
        # inverse never materializes in HBM); mismatched leading dims fall
        # back inside ns_solve to one inverse kernel + broadcast matmul.
        from repro.kernels.nschulz import ops as _ops
        return _ops.ns_solve(ad, bf, iters=ns_iters).astype(b.dtype)
    if method == "pallas_chol":
        # fused factor-and-apply: the Schur inverse is built in VMEM and
        # only X@B leaves the kernel; mismatched leading dims fall back
        # inside chol_solve to one inverse kernel + broadcast matmul
        from repro.kernels.cholesky import ops as _ops
        return _ops.chol_solve(ad, bf).astype(b.dtype)
    # broadcast batch dims (the factorization requires matching leading dims)
    lead = jnp.broadcast_shapes(ad.shape[:-2], bf.shape[:-2])
    ad = jnp.broadcast_to(ad, (*lead, *ad.shape[-2:]))
    bf = jnp.broadcast_to(bf, (*lead, *bf.shape[-2:]))
    return _cho_solve(ad, bf).astype(b.dtype)

"""The FL algorithm zoo (paper Table 1 + Sec 4 comparison methods).

Every algorithm is a triple of pure functions

    init_server(task, hp, params)                  -> sstate
    client(task, hp, params, cstate, sstate, batches, rng) -> (msg, new_cstate)
    server(task, hp, params, sstate, msgs, part)   -> (new_params, sstate)

vmapped over clients by ``repro.fl.simulate``.  ``batches`` has a leading
local-step axis K.

Participation contract (client sampling, Appendix D.2): the engine gathers
the S sampled clients BEFORE the client vmap, so ``msgs`` are stacked over
the S participants only — every gathered message participates.  ``part`` is
a ``Participation`` carrying the per-participant aggregation ``weights``
([S], ones for plain sampling) and the static total client count
``n_total`` (N), which algorithms that scale by the sampled fraction
(SCAFFOLD's S/N control-variate term) read explicitly instead of inferring
it from a full-length mask.

Categories (paper Table 1):
  FOGM : psgd
  FOPM : fedavg, fedavgm, fedprox, scaffold, fedadam
  SOGM : fednl, fedns                        (flat params + full Hessian)
  SOPM : localnewton, ltda, fedsophia        (simple mixing)
         fedpm                               (preconditioned mixing — ours)

``localnewton`` and ``fedpm`` have both a ``full`` backend (exact Hessian,
Test 1's convex model) and a ``foof`` backend (per-layer input covariance,
Test 2's DNNs).  FedPM with K = 1 and full Hessians is algebraically equal
to FedNL's global update (Eq. 9 ≡ Eq. 6) — asserted in tests.

Round-body PURITY contract: client/server fns (and anything they put in
``msgs`` — per-round metrics like ``loss`` included) must be pure jax —
no host callbacks (``jax.debug.callback`` / ``io_callback`` / ``print``
side channels), no host-dependent control flow.  ``FedSim.run_scanned``
compiles whole chunks of rounds into one ``lax.scan`` program; a host
callback in the round body would force a host round-trip per round and
break the scanned driver's one-dispatch-per-chunk guarantee (and its
bit-for-bit equivalence with the per-round oracle).  Metrics that need
host aggregation belong at chunk boundaries (``eval_fn``), not in the
round body.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import foof as F
from repro.core import inverse as inv
from repro.utils import (tree_add, tree_axpy, tree_scale, tree_sub,
                         tree_zeros_like, global_norm_clip)

PyTree = Any


@dataclass(frozen=True)
class HParams:
    lr: float = 0.1
    local_steps: int = 1
    damping: float = 1.0            # δ for SO methods ({1.0, 0.01, 1e-4} in paper)
    clip: float | None = None       # gradient-clipping max norm
    weight_decay: float = 0.0
    momentum: float = 0.9           # fedavgm
    server_lr: float = 1.0          # fedadam / scaffold global lr
    prox_mu: float = 0.001          # fedprox
    beta1: float = 0.9              # fedadam / fedsophia
    beta2: float = 0.99
    tau: float = 1e-3               # fedadam ε
    sketch: int = 0                 # fedns sketch size (0 → d)
    inverse_method: str = "cholesky"  # cholesky | ns | pallas_ns
    ns_iters: int = 20
    foof_timing: str = "end"        # grams at round "end" (paper trick) | "start"
    sophia_gamma: float = 0.05


@dataclass(frozen=True)
class Algorithm:
    name: str
    category: str                   # FOGM | FOPM | SOGM | SOPM
    init_server: Callable
    init_client: Callable
    client: Callable
    server: Callable
    needs_hessian: bool = False
    needs_grams: bool = False


class Participation(NamedTuple):
    """Who is in this round's aggregation.

    ``weights``: [S_local] nonnegative weights over the GATHERED message
    stack (ones for uniform sampling; fractional weights support e.g.
    data-size weighting).  ``n_total``: static total client count N.

    ``axes``: mesh axes the participant stack is sharded over — empty in
    the vmap engine (the stack holds ALL S participants), and
    ``("clients",)`` inside ``repro.fl.sharded``'s manual region, where
    each shard holds only its local participant bucket (zero-weight
    padding slots included) and cross-shard totals are psums.  Server fns
    aggregate through ``part`` (``wmean`` / ``n_sampled``) and stay
    engine-agnostic: per-shard partial reductions + one collective, never
    a full gathered stack on one device.
    """
    weights: jax.Array
    n_total: int
    axes: tuple = ()

    @property
    def n_sampled(self) -> jax.Array:
        """Participant count S = number of positive-weight entries (weight
        mass is aggregation emphasis, not cohort size — fractional weights
        must not shrink fraction-of-N terms like SCAFFOLD's S/N)."""
        s = jnp.sum((self.weights > 0).astype(jnp.float32))
        return jax.lax.psum(s, self.axes) if self.axes else s

    def wmean(self, tree_stack: PyTree) -> PyTree:
        """Weighted mean over the (possibly sharded) participant axis."""
        return _wmean(tree_stack, self)


def _wmean(tree_stack: PyTree, part: Participation) -> PyTree:
    """Weighted mean over the gathered participant axis.

    Normalizes by the true weight sum (epsilon floor only), so fractional
    weights (e.g. data-size weighting) aggregate correctly — matching
    ``foof.mix_preconditioned``.  Accumulates in fp32 and casts back to the
    leaf dtype (also matching ``mix_preconditioned``), so bf16 runs don't
    drift through server aggregation.  The engine never dispatches an
    empty cohort (``FedSim.round`` short-circuits S = 0).

    With ``part.axes`` set (sharded engine), the stack is each shard's
    local bucket: the numerator/denominator partial sums cross shards as
    ONE psum, so no device ever materializes the full [S] stack.
    """
    wf = part.weights.astype(jnp.float32)
    num = jax.tree.map(
        lambda x: jnp.tensordot(wf, x.astype(jnp.float32), axes=1),
        tree_stack)
    den = jnp.sum(wf)
    if part.axes:
        num, den = jax.lax.psum((num, den), part.axes)
    den = jnp.maximum(den, 1e-12)
    return jax.tree.map(lambda n, x: (n / den).astype(x.dtype),
                        num, tree_stack)


def _no_server_state(task, hp, params):
    return ()


def _no_client_state(task, params):
    return ()


def _grad_step(task, hp, params, batch, extra=None):
    loss, g = task.loss_grad(params, batch)
    if extra is not None:
        g = tree_add(g, extra)
    if hp.weight_decay:
        g = tree_axpy(hp.weight_decay, params, g)
    g = global_norm_clip(g, hp.clip)
    return tree_axpy(-hp.lr, g, params), loss


def _sgd_local(task, hp, params, batches, extra_fn=None):
    """K local SGD steps; extra_fn(theta) adds a correction to the grad."""
    def step(theta, batch):
        extra = extra_fn(theta) if extra_fn is not None else None
        theta, loss = _grad_step(task, hp, theta, batch, extra)
        return theta, loss

    theta, losses = jax.lax.scan(step, params, batches)
    return theta, jnp.mean(losses)


# ================================================================= FOGM =====

def _psgd_client(task, hp, params, cstate, sstate, batches, rng):
    first = jax.tree.map(lambda x: x[0], batches)
    _, g = task.loss_grad(params, first)
    g = global_norm_clip(g, hp.clip)
    return {"grad": g}, cstate


def _psgd_server(task, hp, params, sstate, msgs, part):
    g = part.wmean(msgs["grad"])
    return tree_axpy(-hp.lr, g, params), sstate


# ================================================================= FOPM =====

def _fedavg_client(task, hp, params, cstate, sstate, batches, rng):
    theta, loss = _sgd_local(task, hp, params, batches)
    return {"theta": theta, "loss": loss}, cstate


def _fedavg_server(task, hp, params, sstate, msgs, part):
    return part.wmean(msgs["theta"]), sstate


def _fedavgm_server(task, hp, params, sstate, msgs, part):
    delta = tree_sub(part.wmean(msgs["theta"]), params)
    v = tree_axpy(hp.momentum, sstate, delta)   # v = m·v + Δ
    return tree_add(params, v), v


def _fedprox_client(task, hp, params, cstate, sstate, batches, rng):
    theta0 = params
    theta, loss = _sgd_local(
        task, hp, params, batches,
        extra_fn=lambda th: tree_scale(tree_sub(th, theta0), hp.prox_mu))
    return {"theta": theta, "loss": loss}, cstate


def _scaffold_init_client(task, params):
    return tree_zeros_like(params)


def _scaffold_init_server(task, hp, params):
    return tree_zeros_like(params)


def _scaffold_client(task, hp, params, cstate, sstate, batches, rng):
    # correction: g - c_i + c ; c (server control variate) rides in sstate
    c_i, c = cstate, sstate
    corr = tree_sub(c, c_i)
    theta0 = params
    theta, loss = _sgd_local(task, hp, params, batches,
                             extra_fn=lambda th: corr)
    k = batches_len(batches)
    # canonical option-II update: c_i⁺ = c_i − c + (θ0 − θ_K)/(K·η)
    c_i_new = tree_add(tree_sub(c_i, c),
                       tree_scale(tree_sub(theta0, theta), 1.0 / (k * hp.lr)))
    return {"theta": theta, "dc": tree_sub(c_i_new, c_i), "loss": loss}, c_i_new


def _scaffold_server(task, hp, params, sstate, msgs, part):
    theta = part.wmean(msgs["theta"])
    # c ← c + (S/N)·mean_S(Δc_i): explicit sampled fraction from part
    frac = part.n_sampled / jnp.float32(part.n_total)
    c = tree_add(sstate, tree_scale(part.wmean(msgs["dc"]), frac))
    new = tree_add(params, tree_scale(tree_sub(theta, params), hp.server_lr))
    return new, c


def _fedadam_init_server(task, hp, params):
    return (tree_zeros_like(params), tree_zeros_like(params))


def _fedadam_client(task, hp, params, cstate, sstate, batches, rng):
    theta, loss = _sgd_local(task, hp, params, batches)
    return {"delta": tree_sub(theta, params), "loss": loss}, cstate


def _fedadam_server(task, hp, params, sstate, msgs, part):
    m, v = sstate
    d = part.wmean(msgs["delta"])
    m = tree_add(tree_scale(m, hp.beta1), tree_scale(d, 1 - hp.beta1))
    v = jax.tree.map(lambda vv, dd: hp.beta2 * vv + (1 - hp.beta2) * dd * dd, v, d)
    upd = jax.tree.map(lambda mm, vv: mm / (jnp.sqrt(vv) + hp.tau), m, v)
    return tree_axpy(hp.server_lr, upd, params), (m, v)


# ======================================================= SOGM (flat only) ===

def _fednl_client(task, hp, params, cstate, sstate, batches, rng):
    first = jax.tree.map(lambda x: x[0], batches)
    _, g = task.loss_grad(params, first)
    h = task.hessian(params, first)
    return {"grad": g, "hess": h}, cstate


def _fednl_server(task, hp, params, sstate, msgs, part):
    g = part.wmean(msgs["grad"])
    h = part.wmean(msgs["hess"])
    step = inv.solve(h, g[:, None], hp.damping, method=hp.inverse_method,
                     ns_iters=hp.ns_iters)[:, 0]
    return params - hp.lr * step, sstate


def _fedns_init_server(task, hp, params):
    """The sketch frame is SHARED across clients: built once here and
    broadcast to every client via ``sstate`` (it rides into the vmapped
    client fn as a closure, not per-client state).  Orthonormal columns
    (QR of a gaussian): a raw square gaussian has cond ≈ d, which squares
    through the Nyström core solve and destroys fp32 accuracy."""
    d = params.shape[0]
    s = hp.sketch or d
    gauss = jax.random.normal(jax.random.PRNGKey(42), (d, s))
    omega, _ = jnp.linalg.qr(gauss)
    return omega


def _fedns_client(task, hp, params, cstate, sstate, batches, rng):
    first = jax.tree.map(lambda x: x[0], batches)
    _, g = task.loss_grad(params, first)
    h = task.hessian(params, first)
    omega = sstate                                        # broadcast frame
    return {"grad": g, "sketch": h @ omega}, cstate


def _fedns_server(task, hp, params, sstate, msgs, part):
    """Explicit Nyström reconstruction Ĥ = Y(ΩᵀY)⁻¹Yᵀ, then a damped solve.
    (A Woodbury identity solve is cheaper but loses ~30% accuracy to fp32
    cancellation at δ ≲ 1e-3 — measured; EXPERIMENTS.md §Repro notes.)"""
    g = part.wmean(msgs["grad"])
    y = part.wmean(msgs["sketch"])
    omega = sstate                                        # shared frame
    core = omega.T @ y
    core = 0.5 * (core + core.T) + 1e-6 * jnp.eye(core.shape[0])
    h_hat = y @ jnp.linalg.solve(core, y.T)
    h_hat = 0.5 * (h_hat + h_hat.T)
    x = inv.solve(h_hat, g[:, None], max(hp.damping, 1e-6),
                  method=hp.inverse_method, ns_iters=hp.ns_iters)[:, 0]
    return params - hp.lr * x, sstate


# ================================================ SOPM with full Hessian ====

def _newton_local(task, hp, params, batches):
    def step(theta, batch):
        _, g = task.loss_grad(theta, batch)
        h = task.hessian(theta, batch)
        d = inv.solve(h, g[:, None], hp.damping, method=hp.inverse_method,
                      ns_iters=hp.ns_iters)[:, 0]
        return theta - hp.lr * d, h

    theta, hs = jax.lax.scan(step, params, batches)
    return theta, jax.tree.map(lambda x: x[-1], hs)   # last-iterate Hessian


def _localnewton_full_client(task, hp, params, cstate, sstate, batches, rng):
    theta, _ = _newton_local(task, hp, params, batches)
    return {"theta": theta}, cstate


def _fedpm_full_client(task, hp, params, cstate, sstate, batches, rng):
    theta, h_last = _newton_local(task, hp, params, batches)
    return {"theta": theta, "precond": h_last}, cstate


def _fedpm_full_server(task, hp, params, sstate, msgs, part):
    """Preconditioned mixing (Eq. 9/10): θ = (P̄)⁻¹ · mean_i P_i θ_i."""
    pbar = part.wmean(msgs["precond"])
    ptheta = part.wmean(
        jax.vmap(lambda p, t: p @ t)(msgs["precond"], msgs["theta"]))
    theta = inv.solve(pbar, ptheta[:, None], 0.0, method=hp.inverse_method,
                      ns_iters=hp.ns_iters)[:, 0]
    return theta, sstate


# ==================================================== SOPM with FOOF ========

def _foof_local(task, hp, params, batches):
    """K FOOF-preconditioned steps (Eq. 11).  Grams for preconditioning are
    computed once at θ₀ (first batch) and the gram bank is FACTORED ONCE
    outside the scan — every one of the K steps applies the cached
    factors/inverses (pure cho_solve/matmul work), so per-round
    factorization cost is independent of K (paper Table 2 cost model;
    asserted structurally in tests).  Transmitted grams follow
    hp.foof_timing — 'end' recomputes at θ_K (the paper's efficiency trick,
    Sec 4.2 hyperparameter notes)."""
    first = jax.tree.map(lambda x: x[0], batches)
    grams0 = task.grams(params, first)
    precond = F.build_preconditioner(grams0, damping=hp.damping,
                                     method=hp.inverse_method,
                                     ns_iters=hp.ns_iters)

    def step(theta, batch):
        loss, g = task.loss_grad(theta, batch)
        if hp.weight_decay:
            g = tree_axpy(hp.weight_decay, theta, g)
        g = global_norm_clip(g, hp.clip)
        pre = F.apply_preconditioner(precond, theta, g)
        return tree_axpy(-hp.lr, pre, theta), loss

    theta, losses = jax.lax.scan(step, params, batches)
    if hp.foof_timing == "end":
        last = jax.tree.map(lambda x: x[-1], batches)
        grams_tx = task.grams(theta, last)
    else:
        grams_tx = grams0
    return theta, grams_tx, jnp.mean(losses)


def _localnewton_foof_client(task, hp, params, cstate, sstate, batches, rng):
    theta, _, loss = _foof_local(task, hp, params, batches)
    return {"theta": theta, "loss": loss}, cstate


def _fedpm_foof_client(task, hp, params, cstate, sstate, batches, rng):
    theta, grams, loss = _foof_local(task, hp, params, batches)
    return {"theta": theta, "grams": grams, "loss": loss}, cstate


def _fedpm_foof_server(task, hp, params, sstate, msgs, part):
    """Preconditioned mixing with FOOF blocks (Eq. 12) over the gathered
    participants, weighted by ``part.weights``.  ``part.axes`` rides into
    the bank mixer so the sharded engine's per-shard participant buckets
    reduce via one psum per block-size group."""
    mixed = F.mix_preconditioned(msgs["theta"], msgs["grams"],
                                 damping=hp.damping,
                                 method=hp.inverse_method,
                                 ns_iters=hp.ns_iters, weights=part.weights,
                                 axes=part.axes)
    return mixed, sstate


# ------------------------------------------------ diagonal SOPM baselines ---

def _diag_local(task, hp, params, batches, *, sophia: bool):
    """LTDA / FedSophia local steps with a diagonal curvature estimate
    (squared-gradient Fisher diagonal; Sophia adds sign-bounded clipping)."""
    def step(carry, batch):
        theta, m, h = carry
        loss, g = task.loss_grad(theta, batch)
        if hp.weight_decay:
            g = tree_axpy(hp.weight_decay, theta, g)
        g = global_norm_clip(g, hp.clip)
        h = jax.tree.map(lambda hh, gg: hp.beta2 * hh + (1 - hp.beta2) * gg * gg,
                         h, g)
        if sophia:
            m = jax.tree.map(lambda mm, gg: hp.beta1 * mm + (1 - hp.beta1) * gg,
                             m, g)
            upd = jax.tree.map(
                lambda mm, hh: jnp.clip(mm / jnp.maximum(hp.sophia_gamma * hh,
                                                         1e-12), -1.0, 1.0),
                m, h)
        else:
            upd = jax.tree.map(lambda gg, hh: gg / (jnp.sqrt(hh) + hp.damping),
                               g, h)
        theta = tree_axpy(-hp.lr, upd, theta)
        return (theta, m, h), loss

    z = tree_zeros_like(params)
    (theta, _, _), losses = jax.lax.scan(step, (params, z, z), batches)
    return theta, jnp.mean(losses)


def _ltda_client(task, hp, params, cstate, sstate, batches, rng):
    theta, loss = _diag_local(task, hp, params, batches, sophia=False)
    return {"theta": theta, "loss": loss}, cstate


def _fedsophia_client(task, hp, params, cstate, sstate, batches, rng):
    theta, loss = _diag_local(task, hp, params, batches, sophia=True)
    return {"theta": theta, "loss": loss}, cstate


# ================================================================ registry ==

def batches_len(batches) -> int:
    return jax.tree.leaves(batches)[0].shape[0]


def _alg(name, cat, client, server, init_server=_no_server_state,
         init_client=_no_client_state, **kw) -> Algorithm:
    return Algorithm(name=name, category=cat, client=client, server=server,
                     init_server=init_server, init_client=init_client, **kw)


ALGORITHMS: dict[str, Algorithm] = {
    "psgd": _alg("psgd", "FOGM", _psgd_client, _psgd_server),
    "fedavg": _alg("fedavg", "FOPM", _fedavg_client, _fedavg_server),
    "fedavgm": _alg("fedavgm", "FOPM", _fedavg_client, _fedavgm_server,
                    init_server=lambda task, hp, p: tree_zeros_like(p)),
    "fedprox": _alg("fedprox", "FOPM", _fedprox_client, _fedavg_server),
    "scaffold": _alg("scaffold", "FOPM", _scaffold_client, _scaffold_server,
                     init_server=_scaffold_init_server,
                     init_client=_scaffold_init_client),
    "fedadam": _alg("fedadam", "FOPM", _fedadam_client, _fedadam_server,
                    init_server=_fedadam_init_server),
    "fednl": _alg("fednl", "SOGM", _fednl_client, _fednl_server,
                  needs_hessian=True),
    "fedns": _alg("fedns", "SOGM", _fedns_client, _fedns_server,
                  init_server=_fedns_init_server, needs_hessian=True),
    "localnewton": _alg("localnewton", "SOPM", _localnewton_full_client,
                        _fedavg_server, needs_hessian=True),
    "fedpm": _alg("fedpm", "SOPM", _fedpm_full_client, _fedpm_full_server,
                  needs_hessian=True),
    "localnewton_foof": _alg("localnewton_foof", "SOPM",
                             _localnewton_foof_client, _fedavg_server,
                             needs_grams=True),
    "ltda": _alg("ltda", "SOPM", _ltda_client, _fedavg_server),
    "fedsophia": _alg("fedsophia", "SOPM", _fedsophia_client, _fedavg_server),
    "fedpm_foof": _alg("fedpm_foof", "SOPM", _fedpm_foof_client,
                       _fedpm_foof_server, needs_grams=True),
}


def get_algorithm(name: str) -> Algorithm:
    if name not in ALGORITHMS:
        raise KeyError(f"unknown algorithm {name!r}; "
                       f"choose from {sorted(ALGORITHMS)}")
    return ALGORITHMS[name]

"""The FL algorithm zoo (paper Table 1 + Sec 4 comparison methods).

Every algorithm is a COMPOSITION registered through
:mod:`repro.core.api`::

    register(name, category, local_update, server_mixer, wire=transform?)

* :class:`~repro.core.api.LocalUpdate` — the client-side solver.  Each
  declares ``provides`` (message fields it can furnish, some lazily) and
  ``hparams`` (the :class:`HParams` fields it reads).
* :class:`~repro.core.api.Message` — the typed pytree that crosses the
  wire (built by the registry from exactly the mixer's ``needs`` plus the
  solver's metric fields).
* :class:`~repro.core.api.ServerMixer` — the server aggregation,
  consuming a ``Participation`` so it is engine-agnostic.

The engine contract is unchanged: ``get_algorithm(name)`` returns an
``Algorithm`` whose pure functions

    init_server(task, hp, params)                  -> sstate
    client(task, hp, params, cstate, sstate, batches, rng) -> (msg, cstate)
    server(task, hp, params, sstate, msgs, part)   -> (new_params, sstate)

are vmapped over clients by ``repro.fl.simulate``.  ``batches`` has a
leading local-step axis K.  The 14 named compositions below reproduce the
pre-compositional monolithic closures BIT-FOR-BIT (contract-tested in
tests/test_api.py against the frozen oracle in tests/legacy_zoo.py).

Participation contract (client sampling, Appendix D.2): the engine gathers
the S sampled clients BEFORE the client vmap, so ``msgs`` are stacked over
the S participants only — every gathered message participates.  ``part`` is
a ``Participation`` carrying the per-participant aggregation ``weights``
([S], ones for plain sampling) and the static total client count
``n_total`` (N), which algorithms that scale by the sampled fraction
(SCAFFOLD's S/N control-variate term) read explicitly instead of inferring
it from a full-length mask.

Categories (paper Table 1):
  FOGM : psgd
  FOPM : fedavg, fedavgm, fedprox, scaffold, fedadam
  SOGM : fednl, fedns                        (flat params + full Hessian)
  SOPM : localnewton, ltda, fedsophia        (simple mixing)
         fedpm                               (preconditioned mixing — ours)

``localnewton`` and ``fedpm`` have both a ``full`` backend (exact Hessian,
Test 1's convex model) and a ``foof`` backend (per-layer input covariance,
Test 2's DNNs).  FedPM with K = 1 and full Hessians is algebraically equal
to FedNL's global update (Eq. 9 ≡ Eq. 6) — asserted in tests.

Cross-products beyond the paper (one-line registrations near the bottom):
``fedprox_pm`` (prox local + preconditioned mixing), ``scaffold_pm``
(SCAFFOLD control variates + preconditioned mixing), and wire-transform
scenarios ``fedavg_bf16`` / ``fedadam_topk`` / ``fedpm_foof_sketch``.

Round-body PURITY contract: client/server fns (and anything they put in
``msgs`` — per-round metrics like ``loss`` included) must be pure jax —
no host callbacks (``jax.debug.callback`` / ``io_callback`` / ``print``
side channels), no host-dependent control flow.  ``FedSim.run_scanned``
compiles whole chunks of rounds into one ``lax.scan`` program; a host
callback in the round body would force a host round-trip per round and
break the scanned driver's one-dispatch-per-chunk guarantee (and its
bit-for-bit equivalence with the per-round oracle).  Metrics that need
host aggregation belong at chunk boundaries (``eval_fn``), not in the
round body.  Typed messages are plain pytrees, so the contract survives
the compositional registry (wire transforms included).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import foof as F
from repro.core import inverse as inv
from repro.core.api import (ALGORITHMS, Algorithm, Bf16Wire, GramSketchWire,
                            LocalUpdate, ServerMixer, TopKWire, get_algorithm,
                            register, register_local, register_mixer)
from repro.utils import (tree_add, tree_axpy, tree_scale, tree_sub,
                         tree_zeros_like, global_norm_clip)

PyTree = Any

__all__ = ["HParams", "Participation", "Algorithm", "ALGORITHMS",
           "get_algorithm", "batches_len"]


@dataclass(frozen=True)
class HParams:
    """The experiment-level hyperparameter record.

    Deliberately flat (one config object per run), but no longer an
    implicit grab-bag: every LocalUpdate/ServerMixer declares the subset
    it reads (``Algorithm.hparams`` is the union;
    ``api.unused_hparams(algo, hp)`` lints a config against it, and the
    registry sweep test enforces the declarations bitwise).
    """
    lr: float = 0.1
    local_steps: int = 1
    damping: float = 1.0            # δ for SO methods ({1.0, 0.01, 1e-4} in paper)
    clip: float | None = None       # gradient-clipping max norm
    weight_decay: float = 0.0
    momentum: float = 0.9           # fedavgm
    server_lr: float = 1.0          # fedadam / scaffold global lr
    prox_mu: float = 0.001          # fedprox
    beta1: float = 0.9              # fedadam / fedsophia
    beta2: float = 0.99
    tau: float = 1e-3               # fedadam ε
    sketch: int = 0                 # fedns sketch size (0 → d)
    inverse_method: str = "cholesky"  # cholesky | cholesky_safe | ns | pallas_ns | pallas_chol
    ns_iters: int = 20
    foof_timing: str = "end"        # grams at round "end" (paper trick) | "start"
    sophia_gamma: float = 0.05
    stale_decay: float = 0.5        # ρ: stale gram damping Ã_i = ρ^τ_i A_i


class Participation(NamedTuple):
    """Who is in this round's aggregation.

    ``weights``: [S_local] nonnegative weights over the GATHERED message
    stack (ones for uniform sampling; fractional weights support e.g.
    data-size weighting).  ``n_total``: static total client count N.

    ``axes``: mesh axes the participant stack is sharded over — empty in
    the vmap engine (the stack holds ALL S participants), and
    ``("clients",)`` inside ``repro.fl.sharded``'s manual region, where
    each shard holds only its local participant bucket (zero-weight
    padding slots included) and cross-shard totals are psums.  Server fns
    aggregate through ``part`` (``wmean`` / ``n_sampled``) and stay
    engine-agnostic: per-shard partial reductions + one collective, never
    a full gathered stack on one device.

    ``staleness``: optional int [S_local] per-report round-age, fed by
    the buffered-async engine (``None`` — semantically all-zeros — from
    the synchronous engines).  Engine-level staleness WEIGHT damping
    already lands in ``weights``; ``staleness`` exists so a mixer that
    declared a ``ServerMixer.damping`` hook can additionally attenuate
    each report's CURVATURE (gram bank) before the preconditioned mix.
    Mixers without the declared hook must ignore it — enforced bitwise
    by the registry sweep test, like undeclared hparams.
    """
    weights: jax.Array
    n_total: int
    axes: tuple = ()
    staleness: jax.Array | None = None

    @property
    def n_sampled(self) -> jax.Array:
        """Participant count S = number of positive-weight entries (weight
        mass is aggregation emphasis, not cohort size — fractional weights
        must not shrink fraction-of-N terms like SCAFFOLD's S/N)."""
        s = jnp.sum((self.weights > 0).astype(jnp.float32))
        return jax.lax.psum(s, self.axes) if self.axes else s

    def wmean(self, tree_stack: PyTree) -> PyTree:
        """Weighted mean over the (possibly sharded) participant axis."""
        return _wmean(tree_stack, self)


def _wmean(tree_stack: PyTree, part: Participation) -> PyTree:
    """Weighted mean over the gathered participant axis.

    Normalizes by the true weight sum (epsilon floor only), so fractional
    weights (e.g. data-size weighting) aggregate correctly — matching
    ``foof.mix_preconditioned``.  Accumulates in fp32 and casts back to the
    leaf dtype (also matching ``mix_preconditioned``), so bf16 runs don't
    drift through server aggregation.  The engine never dispatches an
    empty cohort (``FedSim.round`` short-circuits S = 0).

    With ``part.axes`` set (sharded engine), the stack is each shard's
    local bucket: the numerator/denominator partial sums cross shards as
    ONE psum, so no device ever materializes the full [S] stack.  This is
    also the engines' ``client_loss`` metric aggregation — both the vmap
    and sharded metric paths go through here.

    ALL-MASKED GUARD: when every weight is zero (the fault-quarantine
    engine's fully-rejected round) the weighted mean is 0/0 — instead of
    the epsilon-floored zeros (or NaN) this falls back to the UNWEIGHTED
    mean of the stack, on both the vmap and psum paths.  The normal path
    is value-identical to the historical code (the select picks the same
    ``num / max(den, eps)`` quotient bit-for-bit); on the sharded engine
    the fallback's unweighted mean includes zero-weight PADDING slots —
    acceptable by contract, because an all-masked round's aggregates are
    only ever consumed after the engine's alive-select discards them.
    """
    wf = part.weights.astype(jnp.float32)
    num = jax.tree.map(
        lambda x: jnp.tensordot(wf, x.astype(jnp.float32), axes=1),
        tree_stack)
    den = jnp.sum(wf)
    num0 = jax.tree.map(
        lambda x: jnp.sum(x.astype(jnp.float32), axis=0), tree_stack)
    cnt = jnp.float32(wf.shape[0])
    if part.axes:
        num, den, num0, cnt = jax.lax.psum((num, den, num0, cnt),
                                           part.axes)
    deng = jnp.maximum(den, 1e-12)
    return jax.tree.map(
        lambda n, n0, x: jnp.where(den > 0, n / deng,
                                   n0 / cnt).astype(x.dtype),
        num, num0, tree_stack)


def batches_len(batches) -> int:
    return jax.tree.leaves(batches)[0].shape[0]


# ========================================================== local solvers ==

def _grad_step(task, hp, params, batch, extra=None):
    loss, g = task.loss_grad(params, batch)
    if extra is not None:
        g = tree_add(g, extra)
    if hp.weight_decay:
        g = tree_axpy(hp.weight_decay, params, g)
    g = global_norm_clip(g, hp.clip)
    return tree_axpy(-hp.lr, g, params), loss


def _sgd_local(task, hp, params, batches, extra_fn=None):
    """K local SGD steps; extra_fn(theta) adds a correction to the grad."""
    def step(theta, batch):
        extra = extra_fn(theta) if extra_fn is not None else None
        theta, loss = _grad_step(task, hp, theta, batch, extra)
        return theta, loss

    theta, losses = jax.lax.scan(step, params, batches)
    return theta, jnp.mean(losses)


def _tx_grams(task, hp, theta, params, batches):
    """Grams to TRANSMIT for preconditioned mixing, per ``hp.foof_timing``
    — 'end' computes at θ_K on the last batch (the paper's trick), 'start'
    at θ₀ on the first.  Lazily attached to every theta-producing local
    solver so any of them composes with a preconditioned mixer."""
    if hp.foof_timing == "end":
        last = jax.tree.map(lambda x: x[-1], batches)
        return task.grams(theta, last)
    first = jax.tree.map(lambda x: x[0], batches)
    return task.grams(params, first)


def _derived(out, task, hp, params, theta, batches):
    """Lazy cross-product fields every theta-producing solver can furnish:
    ``delta`` (θ − θ₀, for delta-consuming mixers like adam) and ``grams``
    (for preconditioned mixers).  Thunks — only materialized when the
    registered mixer's message actually carries the field."""
    out.setdefault("delta", lambda: tree_sub(theta, params))
    out.setdefault("grams", lambda: _tx_grams(task, hp, theta, params,
                                              batches))
    return out


# ------------------------------------------------------------- grad-only ---

def _grad_only_run(task, hp, params, cstate, sstate, batches, rng):
    first = jax.tree.map(lambda x: x[0], batches)
    _, g = task.loss_grad(params, first)
    g = global_norm_clip(g, hp.clip)
    return {"grad": g}, cstate


# ------------------------------------------------------------------- sgd ----

def _sgd_run(task, hp, params, cstate, sstate, batches, rng):
    theta, loss = _sgd_local(task, hp, params, batches)
    return _derived({"theta": theta, "loss": loss},
                    task, hp, params, theta, batches), cstate


def _prox_run(task, hp, params, cstate, sstate, batches, rng):
    theta0 = params
    theta, loss = _sgd_local(
        task, hp, params, batches,
        extra_fn=lambda th: tree_scale(tree_sub(th, theta0), hp.prox_mu))
    return _derived({"theta": theta, "loss": loss},
                    task, hp, params, theta, batches), cstate


def _scaffold_init_client(task, params):
    return tree_zeros_like(params)


def _scaffold_run(task, hp, params, cstate, sstate, batches, rng):
    # correction: g - c_i + c ; c (server control variate) rides in sstate
    c_i, c = cstate, sstate
    corr = tree_sub(c, c_i)
    theta0 = params
    theta, loss = _sgd_local(task, hp, params, batches,
                             extra_fn=lambda th: corr)
    k = batches_len(batches)
    # canonical option-II update: c_i⁺ = c_i − c + (θ0 − θ_K)/(K·η)
    c_i_new = tree_add(tree_sub(c_i, c),
                       tree_scale(tree_sub(theta0, theta), 1.0 / (k * hp.lr)))
    out = _derived({"theta": theta, "dc": tree_sub(c_i_new, c_i),
                    "loss": loss}, task, hp, params, theta, batches)
    return out, c_i_new


# ------------------------------------------------- full-Hessian solvers -----

def _fednl_run(task, hp, params, cstate, sstate, batches, rng):
    first = jax.tree.map(lambda x: x[0], batches)
    _, g = task.loss_grad(params, first)
    h = task.hessian(params, first)
    # sketch: h @ Ω against the server-broadcast frame (FedNS; the frame
    # is shared via sstate, so it never rides the uplink)
    return {"grad": g, "hess": h, "sketch": lambda: h @ sstate}, cstate


def _newton_local(task, hp, params, batches):
    def step(theta, batch):
        _, g = task.loss_grad(theta, batch)
        h = task.hessian(theta, batch)
        d = inv.solve(h, g[:, None], hp.damping, method=hp.inverse_method,
                      ns_iters=hp.ns_iters)[:, 0]
        return theta - hp.lr * d, h

    theta, hs = jax.lax.scan(step, params, batches)
    return theta, jax.tree.map(lambda x: x[-1], hs)   # last-iterate Hessian


def _newton_run(task, hp, params, cstate, sstate, batches, rng):
    theta, h_last = _newton_local(task, hp, params, batches)
    return {"theta": theta, "precond": h_last}, cstate


# ---------------------------------------------------------------- foof ------

def _foof_local(task, hp, params, batches):
    """K FOOF-preconditioned steps (Eq. 11).  Grams for preconditioning are
    computed once at θ₀ (first batch) and the gram bank is FACTORED ONCE
    outside the scan — every one of the K steps applies the cached
    factors/inverses (pure cho_solve/matmul work), so per-round
    factorization cost is independent of K (paper Table 2 cost model;
    asserted structurally in tests).  Transmitted grams follow
    hp.foof_timing — 'end' recomputes at θ_K (the paper's efficiency trick,
    Sec 4.2 hyperparameter notes)."""
    first = jax.tree.map(lambda x: x[0], batches)
    grams0 = task.grams(params, first)
    precond = F.build_preconditioner(grams0, damping=hp.damping,
                                     method=hp.inverse_method,
                                     ns_iters=hp.ns_iters)

    def step(theta, batch):
        loss, g = task.loss_grad(theta, batch)
        if hp.weight_decay:
            g = tree_axpy(hp.weight_decay, theta, g)
        g = global_norm_clip(g, hp.clip)
        pre = F.apply_preconditioner(precond, theta, g)
        return tree_axpy(-hp.lr, pre, theta), loss

    theta, losses = jax.lax.scan(step, params, batches)
    if hp.foof_timing == "end":
        last = jax.tree.map(lambda x: x[-1], batches)
        grams_tx = task.grams(theta, last)
    else:
        grams_tx = grams0
    return theta, grams_tx, jnp.mean(losses)


def _foof_run(task, hp, params, cstate, sstate, batches, rng):
    theta, grams, loss = _foof_local(task, hp, params, batches)
    out = {"theta": theta, "grams": grams, "loss": loss,
           "delta": lambda: tree_sub(theta, params)}
    return out, cstate


# ------------------------------------------------ diagonal SOPM solvers -----

def _diag_local(task, hp, params, batches, *, sophia: bool):
    """LTDA / FedSophia local steps with a diagonal curvature estimate
    (squared-gradient Fisher diagonal; Sophia adds sign-bounded clipping)."""
    def step(carry, batch):
        theta, m, h = carry
        loss, g = task.loss_grad(theta, batch)
        if hp.weight_decay:
            g = tree_axpy(hp.weight_decay, theta, g)
        g = global_norm_clip(g, hp.clip)
        h = jax.tree.map(lambda hh, gg: hp.beta2 * hh + (1 - hp.beta2) * gg * gg,
                         h, g)
        if sophia:
            m = jax.tree.map(lambda mm, gg: hp.beta1 * mm + (1 - hp.beta1) * gg,
                             m, g)
            upd = jax.tree.map(
                lambda mm, hh: jnp.clip(mm / jnp.maximum(hp.sophia_gamma * hh,
                                                         1e-12), -1.0, 1.0),
                m, h)
        else:
            upd = jax.tree.map(lambda gg, hh: gg / (jnp.sqrt(hh) + hp.damping),
                               g, h)
        theta = tree_axpy(-hp.lr, upd, theta)
        return (theta, m, h), loss

    z = tree_zeros_like(params)
    (theta, _, _), losses = jax.lax.scan(step, (params, z, z), batches)
    return theta, jnp.mean(losses)


def _diag_run(task, hp, params, cstate, sstate, batches, rng, *, sophia):
    theta, loss = _diag_local(task, hp, params, batches, sophia=sophia)
    return _derived({"theta": theta, "loss": loss},
                    task, hp, params, theta, batches), cstate


# ============================================================ server mixers ==

def _mean_mix(task, hp, params, sstate, msg, part):
    return part.wmean(msg.theta), sstate


def _momentum_mix(task, hp, params, sstate, msg, part):
    delta = tree_sub(part.wmean(msg.theta), params)
    v = tree_axpy(hp.momentum, sstate, delta)   # v = m·v + Δ
    return tree_add(params, v), v


def _grad_step_mix(task, hp, params, sstate, msg, part):
    g = part.wmean(msg.grad)
    return tree_axpy(-hp.lr, g, params), sstate


def _scaffold_init_server(task, hp, params):
    return tree_zeros_like(params)


def _scaffold_mix(task, hp, params, sstate, msg, part):
    theta = part.wmean(msg.theta)
    # c ← c + (S/N)·mean_S(Δc_i): explicit sampled fraction from part
    frac = part.n_sampled / jnp.float32(part.n_total)
    c = tree_add(sstate, tree_scale(part.wmean(msg.dc), frac))
    new = tree_add(params, tree_scale(tree_sub(theta, params), hp.server_lr))
    return new, c


def _fedadam_init_server(task, hp, params):
    return (tree_zeros_like(params), tree_zeros_like(params))


def _adam_mix(task, hp, params, sstate, msg, part):
    m, v = sstate
    d = part.wmean(msg.delta)
    m = tree_add(tree_scale(m, hp.beta1), tree_scale(d, 1 - hp.beta1))
    v = jax.tree.map(lambda vv, dd: hp.beta2 * vv + (1 - hp.beta2) * dd * dd, v, d)
    upd = jax.tree.map(lambda mm, vv: mm / (jnp.sqrt(vv) + hp.tau), m, v)
    return tree_axpy(hp.server_lr, upd, params), (m, v)


def _newton_mix(task, hp, params, sstate, msg, part):
    g = part.wmean(msg.grad)
    h = part.wmean(msg.hess)
    step = inv.solve(h, g[:, None], hp.damping, method=hp.inverse_method,
                     ns_iters=hp.ns_iters)[:, 0]
    return params - hp.lr * step, sstate


def _fedns_init_server(task, hp, params):
    """The sketch frame is SHARED across clients: built once here and
    broadcast to every client via ``sstate`` (it rides into the vmapped
    client fn as a closure, not per-client state).  Orthonormal columns
    (QR of a gaussian): a raw square gaussian has cond ≈ d, which squares
    through the Nyström core solve and destroys fp32 accuracy."""
    d = params.shape[0]
    s = hp.sketch or d
    gauss = jax.random.normal(jax.random.PRNGKey(42), (d, s))
    omega, _ = jnp.linalg.qr(gauss)
    return omega


def _nystrom_mix(task, hp, params, sstate, msg, part):
    """Explicit Nyström reconstruction Ĥ = Y(ΩᵀY)⁻¹Yᵀ, then a damped solve.
    (A Woodbury identity solve is cheaper but loses ~30% accuracy to fp32
    cancellation at δ ≲ 1e-3 — measured; EXPERIMENTS.md §Repro notes.)"""
    g = part.wmean(msg.grad)
    y = part.wmean(msg.sketch)
    omega = sstate                                        # shared frame
    core = omega.T @ y
    core = 0.5 * (core + core.T) + 1e-6 * jnp.eye(core.shape[0])
    h_hat = y @ jnp.linalg.solve(core, y.T)
    h_hat = 0.5 * (h_hat + h_hat.T)
    x = inv.solve(h_hat, g[:, None], max(hp.damping, 1e-6),
                  method=hp.inverse_method, ns_iters=hp.ns_iters)[:, 0]
    return params - hp.lr * x, sstate


def _precond_full_mix(task, hp, params, sstate, msg, part):
    """Preconditioned mixing (Eq. 9/10): θ = (P̄)⁻¹ · mean_i P_i θ_i."""
    pbar = part.wmean(msg.precond)
    ptheta = part.wmean(
        jax.vmap(lambda p, t: p @ t)(msg.precond, msg.theta))
    theta = inv.solve(pbar, ptheta[:, None], 0.0, method=hp.inverse_method,
                      ns_iters=hp.ns_iters)[:, 0]
    return theta, sstate


def _stale_gram_scale(hp, staleness):
    """The declared ``ServerMixer.damping`` hook for the preconditioned
    mixers: exponential staleness decay of each report's curvature,
    ``Ã_i = ρ^τ_i A_i`` with ρ = ``hp.stale_decay``.  A τ-stale gram was
    measured against dispatch-time params, so under drift it attenuates
    toward zero and the mix degrades gracefully toward plain weighted
    averaging of the stale θ — exactly the preconditioner-drift failure
    mode staleness compounds.  ``ρ**0 == 1.0`` EXACTLY (IEEE pow), so a
    zero-staleness async round scales every gram by 1.0 and stays
    bitwise identical to the synchronous mix."""
    return jnp.float32(hp.stale_decay) ** staleness.astype(jnp.float32)


def _precond_foof_mix(task, hp, params, sstate, msg, part):
    """Preconditioned mixing with FOOF blocks (Eq. 12) over the gathered
    participants, weighted by ``part.weights``.  ``part.axes`` rides into
    the bank mixer so the sharded engine's per-shard participant buckets
    reduce via one psum per block-size group.  ``part.staleness`` (async
    engine only) rides in as a per-report gram scale via the declared
    damping hook."""
    gs = (None if part.staleness is None
          else _stale_gram_scale(hp, part.staleness))
    mixed = F.mix_preconditioned(msg.theta, msg.grams,
                                 damping=hp.damping,
                                 method=hp.inverse_method,
                                 ns_iters=hp.ns_iters, weights=part.weights,
                                 axes=part.axes, gram_scale=gs)
    return mixed, sstate


def _scaffold_pm_mix(task, hp, params, sstate, msg, part):
    """SCAFFOLD control variates + FedPM preconditioned mixing: the
    cross-product the compositional registry exists for — drift-corrected
    local steps whose results still mix through Eq. 12."""
    gs = (None if part.staleness is None
          else _stale_gram_scale(hp, part.staleness))
    mixed = F.mix_preconditioned(msg.theta, msg.grams,
                                 damping=hp.damping,
                                 method=hp.inverse_method,
                                 ns_iters=hp.ns_iters, weights=part.weights,
                                 axes=part.axes, gram_scale=gs)
    frac = part.n_sampled / jnp.float32(part.n_total)
    c = tree_add(sstate, tree_scale(part.wmean(msg.dc), frac))
    new = tree_add(params, tree_scale(tree_sub(mixed, params), hp.server_lr))
    return new, c


# ============================================================ registrations ==

_SGD_HP = ("lr", "weight_decay", "clip")
_GRAMS_HP = {"grams": ("foof_timing",)}
_SOLVE_HP = ("damping", "inverse_method", "ns_iters")

register_local(LocalUpdate(
    "grad_only", _grad_only_run, provides=("grad",), hparams=("clip",)))
register_local(LocalUpdate(
    "sgd", _sgd_run, provides=("theta", "delta", "grams", "loss"),
    metrics=("loss",), hparams=_SGD_HP, field_hparams=_GRAMS_HP))
register_local(LocalUpdate(
    "prox", _prox_run, provides=("theta", "delta", "grams", "loss"),
    metrics=("loss",), hparams=_SGD_HP + ("prox_mu",),
    field_hparams=_GRAMS_HP))
register_local(LocalUpdate(
    "scaffold_sgd", _scaffold_run,
    provides=("theta", "dc", "delta", "grams", "loss"), metrics=("loss",),
    hparams=_SGD_HP, field_hparams=_GRAMS_HP,
    init_client=_scaffold_init_client))
register_local(LocalUpdate(
    "grad_hess", _fednl_run, provides=("grad", "hess", "sketch"),
    needs_hessian=True))
register_local(LocalUpdate(
    "full_newton", _newton_run, provides=("theta", "precond"),
    hparams=("lr",) + _SOLVE_HP, needs_hessian=True))
register_local(LocalUpdate(
    "foof", _foof_run, provides=("theta", "grams", "delta", "loss"),
    metrics=("loss",), hparams=_SGD_HP + _SOLVE_HP + ("foof_timing",),
    needs_grams=True))
register_local(LocalUpdate(
    "diag_ltda", partial(_diag_run, sophia=False),
    provides=("theta", "delta", "grams", "loss"), metrics=("loss",),
    hparams=_SGD_HP + ("damping", "beta2"), field_hparams=_GRAMS_HP))
register_local(LocalUpdate(
    "diag_sophia", partial(_diag_run, sophia=True),
    provides=("theta", "delta", "grams", "loss"), metrics=("loss",),
    hparams=_SGD_HP + ("beta1", "beta2", "sophia_gamma"),
    field_hparams=_GRAMS_HP))

register_mixer(ServerMixer("grad_step", needs=("grad",), mix=_grad_step_mix,
                           hparams=("lr",)))
register_mixer(ServerMixer("mean", needs=("theta",), mix=_mean_mix))
register_mixer(ServerMixer(
    "momentum", needs=("theta",), mix=_momentum_mix,
    init_server=lambda task, hp, p: tree_zeros_like(p),
    hparams=("momentum",)))
register_mixer(ServerMixer(
    "scaffold", needs=("theta", "dc"), mix=_scaffold_mix,
    init_server=_scaffold_init_server, hparams=("server_lr",),
    broadcasts_state=True))
register_mixer(ServerMixer(
    "adam", needs=("delta",), mix=_adam_mix,
    init_server=_fedadam_init_server,
    hparams=("server_lr", "beta1", "beta2", "tau")))
register_mixer(ServerMixer("newton", needs=("grad", "hess"), mix=_newton_mix,
                           hparams=("lr",) + _SOLVE_HP))
register_mixer(ServerMixer(
    "nystrom", needs=("grad", "sketch"), mix=_nystrom_mix,
    init_server=_fedns_init_server, hparams=("lr", "sketch") + _SOLVE_HP,
    broadcasts_state=True))
register_mixer(ServerMixer(
    "precond_full", needs=("theta", "precond"), mix=_precond_full_mix,
    hparams=("inverse_method", "ns_iters")))
register_mixer(ServerMixer(
    "precond_foof", needs=("theta", "grams"), mix=_precond_foof_mix,
    hparams=_SOLVE_HP + ("stale_decay",), damping=_stale_gram_scale))
register_mixer(ServerMixer(
    "scaffold_precond_foof", needs=("theta", "grams", "dc"),
    mix=_scaffold_pm_mix, init_server=_scaffold_init_server,
    hparams=_SOLVE_HP + ("server_lr", "stale_decay"),
    broadcasts_state=True, damping=_stale_gram_scale))

# ---- the paper zoo (Table 1): bit-compatible with the pre-compositional
# ---- monolithic closures (tests/test_api.py vs tests/legacy_zoo.py) -------
register("psgd", "FOGM", "grad_only", "grad_step")
register("fedavg", "FOPM", "sgd", "mean")
register("fedavgm", "FOPM", "sgd", "momentum")
register("fedprox", "FOPM", "prox", "mean")
register("scaffold", "FOPM", "scaffold_sgd", "scaffold")
register("fedadam", "FOPM", "sgd", "adam")
register("fednl", "SOGM", "grad_hess", "newton")
register("fedns", "SOGM", "grad_hess", "nystrom")
register("localnewton", "SOPM", "full_newton", "mean")
register("fedpm", "SOPM", "full_newton", "precond_full")
register("localnewton_foof", "SOPM", "foof", "mean")
register("ltda", "SOPM", "diag_ltda", "mean")
register("fedsophia", "SOPM", "diag_sophia", "mean")
register("fedpm_foof", "SOPM", "foof", "precond_foof")

# ---- cross-products beyond the paper: one-line scenario registrations -----
register("fedprox_pm", "SOPM", "prox", "precond_foof")
register("scaffold_pm", "SOPM", "scaffold_sgd", "scaffold_precond_foof")

# ---- wire-transform scenarios: same compositions, cheaper uplink ----------
register("fedavg_bf16", "FOPM", "sgd", "mean", wire=Bf16Wire())
register("fedadam_topk", "FOPM", "sgd", "adam",
         wire=TopKWire(frac=0.125, fields=("delta",)))
register("fedpm_foof_sketch", "SOPM", "foof", "precond_foof",
         wire=GramSketchWire(rank=8, fields=("grams",)))

"""Minimal optimizers for centralized training loops (examples/launcher).

The FL algorithms carry their own update rules; these are for the
non-federated driver paths (examples/lm_federated.py warmup, smoke tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import tree_axpy, tree_zeros_like

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState]]


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        return OptState(jnp.int32(0), tree_zeros_like(params), ())

    def update(grads, state, params):
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
        else:
            mu = grads
        new = tree_axpy(-lr, mu, params)
        return new, OptState(state.step + 1, mu if momentum else state.mu, ())

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return OptState(jnp.int32(0), tree_zeros_like(params),
                        tree_zeros_like(params))

    def update(grads, state, params):
        t = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        upd = jax.tree.map(
            lambda m, v: (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu)
        if weight_decay:
            upd = jax.tree.map(lambda u, p: u + weight_decay * p, upd, params)
        new = tree_axpy(-lr, upd, params)
        return new, OptState(t, mu, nu)

    return Optimizer(init, update)

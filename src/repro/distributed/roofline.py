"""Roofline model for the multi-pod dry-run (DESIGN.md §6).

This container is CPU-only; TPU v5e is the compile *target*.  The three
roofline terms are derived from the compiled artifact:

  compute    = HLO_FLOPs_per_chip  / peak_flops
  memory     = HLO_bytes_per_chip  / hbm_bw
  collective = wire_bytes_per_chip / ici_bw

``compiled.cost_analysis()`` (post-SPMD, per-partition program) supplies
FLOPs and bytes-accessed.  Collective bytes are not in cost_analysis, so we
parse the post-partitioning HLO text and sum the result sizes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute,
each scaled by its ring-algorithm wire factor over its replica-group size.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "%ar = bf16[128,1024]{1,0} all-reduce-start(...)" or tuple results.
_OP_RE = re.compile(
    r"=\s*(?P<rtype>\(.*?\)|[\w\[\],{}/ ]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<variant>-start)?\(",
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+[0-9]*[a-z0-9]*)\[(?P<dims>[0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float      # per chip, bf16
    hbm_bw: float          # bytes/s per chip
    ici_bw: float          # bytes/s per link


# TPU v5e (per system prompt): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
V5E = HardwareSpec(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)

# Envelope for the CI container's CPU host where the interpret/jnp kernel
# benches run: ~150 GFLOP/s f32 matmul throughput (calibrated against the
# fixed-iteration NS reference, which is pure batched matmul and must not
# beat the bound) and ~20 GB/s effective memory bandwidth.  Only the
# RATIO achieved/bound is reported (bench_roofline.kernel_section) — the
# envelope anchors it but is not itself a gate.
CPU_HOST = HardwareSpec(name="cpu_host", peak_flops=1.5e11, hbm_bw=2e10,
                        ici_bw=1e10)


@dataclasses.dataclass(frozen=True)
class KernelRoofline:
    """Analytic per-launch roofline for one gram-bank kernel.

    ``flops``/``bytes`` are the algorithmic minimum work and the
    unavoidable HBM traffic (inputs read once + outputs written once —
    the fused kernels' whole point is that intermediates stay in VMEM, so
    the traffic term contains NO intermediates).  ``bound_us`` is the
    max of the compute and bandwidth terms: no implementation beats it,
    and achieved/bound says how much headroom a measured launch leaves.
    """
    name: str
    flops: float
    bytes: float

    def bound_us(self, hw: HardwareSpec = CPU_HOST) -> float:
        return max(self.flops / hw.peak_flops, self.bytes / hw.hbm_bw) * 1e6

    def dominant(self, hw: HardwareSpec = CPU_HOST) -> str:
        return ("compute" if self.flops / hw.peak_flops
                >= self.bytes / hw.hbm_bw else "memory")


def chol_solve_roofline(nb: int, bs: int, k: int) -> KernelRoofline:
    """Batched Schur/Cholesky solve of [nb, bs, bs] against [nb, bs, k]:
    the inverse costs ~2bs³ per block (Schur recursion is matmul-
    dominated; classical factor+two-trisolve is the same order), the
    apply 2bs²k.  Traffic: read A and B, write X@B."""
    flops = nb * (2.0 * bs ** 3 + 2.0 * bs ** 2 * k)
    byts = 4.0 * nb * (bs * bs + 2.0 * bs * k)
    return KernelRoofline("chol_solve", flops, byts)


def ns_solve_roofline(nb: int, bs: int, k: int, iters: int) -> KernelRoofline:
    """Fused Newton–Schulz invert-and-apply: two bs³ matmuls per
    iteration (4bs³ flops) plus the final 2bs²k apply.  ``iters`` is the
    budget ceiling — the adaptive kernel's convergence test exits early,
    so achieved time can beat a bound computed at the ceiling."""
    flops = nb * (4.0 * bs ** 3 * iters + 2.0 * bs ** 2 * k)
    byts = 4.0 * nb * (bs * bs + 2.0 * bs * k)
    return KernelRoofline("ns_solve", flops, byts)


def mix_roofline(s: int, r: int, bs: int, k: int, iters: int
                 ) -> KernelRoofline:
    """Fused Eq. 12 mixing over a stacked [S, R, bs, ·] client bank:
    per (client, row) one (A+δI)Θ matmul (2bs²k) and the two weighted
    reductions (2bs² + 2bs·k), then per row one NS inverse (4bs³·iters)
    and the final apply (2bs²k).  Traffic: the client bank streams in
    once, only the mixed [R, bs, k] block leaves."""
    flops = (s * r * (2.0 * bs ** 2 * k + 2.0 * bs * bs + 2.0 * bs * k)
             + r * (4.0 * bs ** 3 * iters + 2.0 * bs ** 2 * k))
    byts = 4.0 * (s * r * (bs * bs + bs * k) + r * bs * k + s)
    return KernelRoofline("mix", flops, byts)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [num_groups, group_size]<=[total]
        return int(m.group(2))
    return default


def _wire_factor(op: str, group: int) -> float:
    """Per-chip wire bytes ÷ result bytes for ring algorithms."""
    g = max(group, 1)
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return (g - 1) / g
    return 1.0  # collective-permute


def collective_bytes_from_hlo(hlo_text: str, num_devices: int) -> dict:
    """Sum per-chip collective wire bytes from post-partitioning HLO text.

    Returns {'total': bytes, 'by_op': {op: bytes}, 'count': int}.
    ``-done`` ops are skipped (their ``-start`` already counted).
    """
    by_op: dict[str, float] = defaultdict(float)
    count = 0
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        size = _shape_bytes(m.group("rtype"))
        if size == 0:
            continue
        g = _group_size(line, num_devices)
        by_op[op] += size * _wire_factor(op, g)
        count += 1
    return {"total": float(sum(by_op.values())), "by_op": dict(by_op), "count": count}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    num_devices: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_by_op: dict
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float          # 6·N_active·D per step (global)
    useful_ratio: float         # model_flops / (flops_per_chip · chips)
    peak_memory_bytes: int      # per-chip peak from memory_analysis
    hw: str

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_from_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                           num_devices: int, model_flops: float,
                           hw: HardwareSpec = V5E) -> RooflineReport:
    # cost_analysis() counts while bodies ONCE (measured) — useless for
    # scanned layer stacks.  Use the static HLO analyzer, which multiplies
    # by known_trip_count (validated exact on nested scans).
    from repro.distributed.hlo_analysis import analyze_hlo
    hlo = analyze_hlo(compiled.as_text(), num_devices)
    flops = float(hlo["flops"])
    byts = float(hlo["hbm_bytes"])
    coll = {"total": hlo["collective_bytes"], "by_op": hlo["collective_by_op"]}

    compute_s = flops / hw.peak_flops
    memory_s = byts / hw.hbm_bw
    collective_s = coll["total"] / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)

    mem = compiled.memory_analysis()
    peak = 0
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes"):
        peak += int(getattr(mem, attr, 0) or 0)

    total_flops = flops * num_devices
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, num_devices=num_devices,
        flops_per_chip=flops, bytes_per_chip=byts,
        coll_bytes_per_chip=coll["total"], coll_by_op=coll["by_op"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        useful_ratio=(model_flops / total_flops) if total_flops else 0.0,
        peak_memory_bytes=peak, hw=hw.name,
    )

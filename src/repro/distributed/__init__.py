"""Distribution layer: mesh axes, logical sharding rules, roofline model."""
from repro.distributed.axes import (
    CLIENT_AXES,
    MODEL_AXIS,
    POD_AXIS,
    DATA_AXIS,
    client_axis_size,
)
from repro.distributed.roofline import (
    V5E,
    HardwareSpec,
    RooflineReport,
    collective_bytes_from_hlo,
    roofline_from_compiled,
)

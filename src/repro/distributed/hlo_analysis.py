"""Static analysis of post-partitioning HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE — for a model
whose layers live in a ``lax.scan`` (all of ours) that undercounts FLOPs,
bytes and collectives by the trip count (measured: 8 scanned matmuls report
1 matmul of FLOPs).  This module parses ``compiled.as_text()`` and computes

  flops             — dot/convolution FLOPs, fusions recursed,
                      while bodies × known_trip_count
  hbm_bytes         — Σ (operands + output) of top-level instructions
                      (fusion internals excluded: they live in
                      registers/VMEM), while bodies × trip count
  collective_bytes  — per-chip wire bytes of all-reduce / all-gather /
                      reduce-scatter / all-to-all / collective-permute,
                      ring-algorithm wire factors over the replica-group
                      size, while bodies × trip count

All shapes in a post-SPMD module are per-partition, so every number is
per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# header params may be tuple-typed (nested parens) — match loosely
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^=]*?\)|[\w\[\],{}\/ ]+?)\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_GROUPS_BRACE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str) -> tuple[str, list[int]] | None:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str          # everything after the opening paren


@dataclasses.dataclass
class Computation:
    name: str
    instrs: dict
    order: list


_COMMENT = re.compile(r"/\*[^*]*\*/")


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    hlo = _COMMENT.sub("", hlo)   # strip /*index=N*/ tuple-type comments
    for line in hlo.splitlines():
        hdr = _COMP_HDR.match(line.strip())
        if hdr and line.rstrip().endswith("{"):
            cur = Computation(hdr.group(1), {}, [])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2).strip(), m.group(3), m.group(4))
            cur.instrs[ins.name] = ins
            cur.order.append(ins.name)
    return comps


def _wire_factor(op: str, group: int) -> float:
    g = max(group, 1)
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return (g - 1) / g
    return 1.0


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_BRACE.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA.search(rest)
    if m:
        return int(m.group(2))
    return default


class HloCost:
    def __init__(self, hlo_text: str, num_devices: int):
        self.comps = parse_computations(hlo_text)
        self.num_devices = num_devices
        self._memo: dict[str, tuple] = {}

    # (flops, hbm_bytes, coll_bytes_by_op)
    def analyze(self, comp_name: str):
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return (0.0, 0.0, {})
        self._memo[comp_name] = (0.0, 0.0, {})  # cycle guard
        flops = 0.0
        byts = 0.0
        coll: dict[str, float] = defaultdict(float)

        for name in comp.order:
            ins = comp.instrs[name]
            op = ins.op
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all"):
                continue
            if op == "while":
                trip = 1
                mt = _TRIP.search(ins.rest)
                if mt:
                    trip = int(mt.group(1))
                mb = _BODY.search(ins.rest)
                if mb:
                    f, b, c = self.analyze(mb.group(1))
                    flops += trip * f
                    byts += trip * b
                    for k, v in c.items():
                        coll[k] += trip * v
                continue
            if op in ("fusion", "call", "custom-call", "conditional",
                      "async-start"):
                mc = _CALLS.search(ins.rest)
                if mc:
                    f, b, c = self.analyze(mc.group(1))
                    flops += f          # fusion internals: flops yes
                    for k, v in c.items():
                        coll[k] += v
                # hbm traffic: fusion boundary only
                byts += self._io_bytes(comp, ins)
                continue
            stripped = op[:-6] if op.endswith("-start") else op
            if stripped in _COLLECTIVES:
                size = _type_bytes(ins.type_str)
                g = self._collective_group(ins)
                coll[stripped] += size * _wire_factor(stripped, g)
                byts += self._io_bytes(comp, ins)
                continue
            if op.endswith("-done"):
                continue
            if op in ("dot", "dot_general"):
                flops += self._dot_flops(comp, ins)
                byts += self._io_bytes(comp, ins)
                continue
            if op == "convolution":
                flops += self._conv_flops(comp, ins)
                byts += self._io_bytes(comp, ins)
                continue
            # plain top-level op: count traffic; elementwise flops ignored
            byts += self._io_bytes(comp, ins)

        result = (flops, byts, dict(coll))
        self._memo[comp_name] = result
        return result

    # ------------------------------------------------------------ helpers --

    def _collective_group(self, ins: Instr) -> int:
        return _group_size(ins.rest, self.num_devices)

    def _operand_names(self, ins: Instr) -> list[str]:
        # operands appear before the first "),"-style closure; cheap approx:
        head = ins.rest.split(")", 1)[0]
        return _OPERAND.findall(head)

    def _operand_type(self, comp: Computation, opname: str) -> str | None:
        ins = comp.instrs.get(opname)
        return ins.type_str if ins else None

    def _io_bytes(self, comp: Computation, ins: Instr) -> float:
        total = float(_type_bytes(ins.type_str))
        for opn in self._operand_names(ins):
            t = self._operand_type(comp, opn)
            if t:
                total += _type_bytes(t)
        return total

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out = _first_shape(ins.type_str)
        if out is None:
            return 0.0
        out_elems = 1
        for d in out[1]:
            out_elems *= d
        contract = 1
        mdims = _LHS_CONTRACT.search(ins.rest)
        ops = self._operand_names(ins)
        if mdims and ops:
            lhs_t = self._operand_type(comp, ops[0])
            if lhs_t:
                lhs = _first_shape(lhs_t)
                if lhs:
                    for d in mdims.group(1).split(","):
                        if d and int(d) < len(lhs[1]):
                            contract *= lhs[1][int(d)]
        return 2.0 * out_elems * contract

    def _conv_flops(self, comp: Computation, ins: Instr) -> float:
        out = _first_shape(ins.type_str)
        ops = self._operand_names(ins)
        if out is None or len(ops) < 2:
            return 0.0
        out_elems = 1
        for d in out[1]:
            out_elems *= d
        k_t = self._operand_type(comp, ops[1])
        if not k_t:
            return 0.0
        k = _first_shape(k_t)
        if not k:
            return 0.0
        k_elems = 1
        for d in k[1]:
            k_elems *= d
        # flops ≈ 2 · out · (kernel / out_channels); out_channels ≈ last dim
        oc = max(k[1][-1], 1) if k[1] else 1
        return 2.0 * out_elems * k_elems / oc

    # ------------------------------------------------------------ entry ----

    def totals(self) -> dict:
        entry = None
        for name in self.comps:
            if name.startswith("main") or ".main" in name or entry is None:
                if "main" in name:
                    entry = name
        if entry is None:
            entry = next(iter(self.comps))
        f, b, c = self.analyze(entry)
        return {"flops": f, "hbm_bytes": b, "collective_by_op": c,
                "collective_bytes": float(sum(c.values())), "entry": entry}


def analyze_hlo(hlo_text: str, num_devices: int) -> dict:
    return HloCost(hlo_text, num_devices).totals()

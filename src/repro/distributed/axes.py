"""Canonical mesh-axis names + version-gated jax mesh/shard_map compat.

The FL mapping (DESIGN.md §3): clients ARE the data-parallel axis.
Single-pod mesh: ("data", "model"); multi-pod: ("pod", "data", "model").
Server-side mixing = collectives over CLIENT_AXES ∩ mesh.axis_names.

The compat layer papers over the `jax.sharding.AxisType` /
`jax.set_mesh` / `jax.shard_map` API moves: current jax exposes all
three at the top level, while 0.4.x has neither ``AxisType`` nor
``set_mesh`` and keeps ``shard_map`` under ``jax.experimental`` with
``check_rep``/``auto`` instead of ``check_vma``/``axis_names``.  Every
mesh/shard_map touchpoint in the repo goes through these three helpers.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"
#: axes that together enumerate client cohorts (present axes only are used)
CLIENT_AXES = (POD_AXIS, DATA_AXIS)
#: the simulation engine's client-bank axis: ``repro.fl.sharded`` places the
#: stacked [N, ...] client-state bank (and per-client batches) on this axis
CLIENTS_AXIS = "clients"

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_TOP_SHARD_MAP = hasattr(jax, "shard_map")


def make_auto_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with every axis in Auto mode on both jax APIs.

    New jax takes ``axis_types=(AxisType.Auto, ...)``; old jax has no
    ``axis_types`` kwarg and every axis is implicitly auto.
    """
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def use_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh(mesh)`` where available; on old jax the Mesh object
    itself is the context manager (it sets the thread-resources env that
    sharding-constraint resolution and shard_map read).
    """
    if _HAS_SET_MESH:
        return jax.set_mesh(mesh)
    return mesh


def ambient_mesh():
    """The mesh installed by ``use_mesh``, or None outside any context.

    Gated on the same predicate as ``use_mesh`` so the read path always
    matches the write path: with ``jax.set_mesh`` we read the abstract
    mesh it installs; otherwise ``use_mesh`` fell back to ``with mesh:``
    and we read the thread-resources physical mesh that sets.
    """
    if _HAS_SET_MESH:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and getattr(m, "axis_names", ()):
            return m
        return None
    from jax._src.mesh import thread_resources
    m = thread_resources.env.physical_mesh
    return None if m.empty else m


def _ambient_mesh() -> jax.sharding.Mesh:
    m = ambient_mesh()
    if m is None:
        raise ValueError("shard_map without mesh= needs an enclosing "
                         "use_mesh(mesh) context")
    return m


def shard_map(f: Callable, *, mesh: jax.sharding.Mesh | None = None,
              in_specs: Any, out_specs: Any,
              axis_names: set | None = None, check: bool = False):
    """Version-gated ``shard_map`` with partial-manual axes.

    ``axis_names`` is the set of *manual* axes (new-jax semantics);
    ``None`` means all mesh axes.  On old jax this maps to
    ``auto = mesh.axis_names - axis_names`` and ``check_rep=check``.
    """
    if _HAS_TOP_SHARD_MAP:
        kw = dict(in_specs=in_specs, out_specs=out_specs, check_vma=check)
        if mesh is not None:
            kw["mesh"] = mesh
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    m = mesh if mesh is not None else _ambient_mesh()
    auto = (frozenset(m.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    return _shard_map(f, m, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check, auto=auto)


def make_client_mesh(n_shards: int | None = None) -> jax.sharding.Mesh:
    """A 1-D ``("clients",)`` mesh over ``n_shards`` devices (all by
    default) — the mesh shape ``repro.fl.sharded`` shards client banks
    over.  Routed through ``make_auto_mesh`` so both jax APIs work."""
    n = n_shards if n_shards is not None else len(jax.devices())
    return make_auto_mesh((n,), (CLIENTS_AXIS,))


def present_client_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(a for a in CLIENT_AXES if a in mesh.axis_names)


def client_axis_size(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for a in present_client_axes(mesh):
        n *= mesh.shape[a]
    return n

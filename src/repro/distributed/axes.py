"""Canonical mesh-axis names.

The FL mapping (DESIGN.md §3): clients ARE the data-parallel axis.
Single-pod mesh: ("data", "model"); multi-pod: ("pod", "data", "model").
Server-side mixing = collectives over CLIENT_AXES ∩ mesh.axis_names.
"""
from __future__ import annotations

import jax

POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"
#: axes that together enumerate client cohorts (present axes only are used)
CLIENT_AXES = (POD_AXIS, DATA_AXIS)


def present_client_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(a for a in CLIENT_AXES if a in mesh.axis_names)


def client_axis_size(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for a in present_client_axes(mesh):
        n *= mesh.shape[a]
    return n

"""Production FL engine for the assigned architectures (DESIGN.md §3b).

Two modes:

``fused_k1``  — FedPM with K = 1 collapses to the ideal global second-order
step  θ ← θ − η·(P̄+δI)⁻¹·ḡ  with P̄/ḡ the client means (Eq. 6 ≡ Eq. 9).
Implemented as a plain pjit step: the batch axis IS the client axis, so the
token-contraction in each gram and the mean-loss gradient are exactly the
client means, inserted as all-reduces by GSPMD.  No per-client parameter
replicas → scales to llama3-405b with FSDP param sharding.

``local_steps`` — K > 1 local FOOF steps per round.  shard_map *manual* over
the client axes ("pod","data") so local gradients do NOT sync across
clients, while the "model" axis stays under GSPMD auto-partitioning
(tensor/expert parallelism inside each client cohort).  The round ends with
preconditioned mixing (Eq. 12) as psums over the client axes.  Requires a
full (model-sharded) parameter replica per cohort — the memory wall that
rules out 405B-scale (DESIGN.md §3b).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import foof as F
from repro.core.algorithms import HParams
from repro.core.api import wire_bytes
from repro.distributed.axes import present_client_axes, shard_map
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.utils import tree_axpy, global_norm_clip

PyTree = Any


# ============================================================== fused K=1 ===

def make_fused_k1_step(cfg: ModelConfig, hp: HParams):
    """(params, batch) -> (params, metrics): one FedPM round, K = 1.

    Under pjit with batch sharded over the client axes, every client-mean in
    Eq. 9 is realized by a GSPMD all-reduce; the FOOF preconditioner P̄ is
    the token-pooled gram (= mean of per-client grams for equal shards).
    """

    def step(params, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch, collect_foof=True),
            has_aux=True)(params)
        if hp.weight_decay:
            grads = tree_axpy(hp.weight_decay, params, grads)
        grads = global_norm_clip(grads, hp.clip)
        pre = F.precondition_tree(params, grads, aux["grams"],
                                  damping=hp.damping,
                                  method=hp.inverse_method,
                                  ns_iters=hp.ns_iters)
        new_params = tree_axpy(-hp.lr, pre, params)
        return new_params, {"loss": loss}

    return step


def make_amortized_steps(cfg: ModelConfig, hp: HParams):
    """(refresh_step, steady_step) — §Perf C4 (the paper's once-per-round
    FOOF trick as a first-class feature).

      refresh: (params, batch) -> (params, inverses, metrics)
               collects grams, inverts once, applies.
      steady:  (params, inverses, batch) -> (params, metrics)
               pure matmul preconditioning with the cached inverses.

    A round with refresh interval F costs (refresh + (F−1)·steady)/F.
    """

    def refresh(params, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch, collect_foof=True),
            has_aux=True)(params)
        if hp.weight_decay:
            grads = tree_axpy(hp.weight_decay, params, grads)
        grads = global_norm_clip(grads, hp.clip)
        inverses = F.invert_grams(aux["grams"], damping=hp.damping,
                                  method=hp.inverse_method,
                                  ns_iters=hp.ns_iters)
        pre = F.apply_inverses(params, grads, inverses)
        return tree_axpy(-hp.lr, pre, params), inverses, {"loss": loss}

    def steady(params, inverses, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch)[0])(params)
        if hp.weight_decay:
            grads = tree_axpy(hp.weight_decay, params, grads)
        grads = global_norm_clip(grads, hp.clip)
        pre = F.apply_inverses(params, grads, inverses)
        return tree_axpy(-hp.lr, pre, params), {"loss": loss}

    return refresh, steady


def abstract_inverses(cfg: ModelConfig, batch):
    """ShapeDtypeStructs of the cached-inverse tree (mirrors grams)."""
    def fn(params, b):
        _, aux = T.loss_fn(cfg, params, b, collect_foof=True)
        return F.invert_grams(aux["grams"], damping=1.0)
    return jax.eval_shape(fn, T.abstract_params(cfg), batch)


def make_fedavg_step(cfg: ModelConfig, hp: HParams):
    """First-order baseline round (PSGD/FedAvg-K1): θ ← θ − η·ḡ."""

    def step(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch)[0])(params)
        if hp.weight_decay:
            grads = tree_axpy(hp.weight_decay, params, grads)
        grads = global_norm_clip(grads, hp.clip)
        return tree_axpy(-hp.lr, grads, params), {"loss": loss}

    return step


# ============================================================ local steps ===

def make_local_steps_round(cfg: ModelConfig, hp: HParams,
                           mesh: jax.sharding.Mesh, k_steps: int):
    """(params, batch) -> (params, metrics): one FedPM round with K > 1.

    batch leaves are [B_global, ...] sharded over the client axes; inside
    the manual region each cohort reshapes its slice into K microbatches.
    Params must be replicated over the client axes (fsdp=False).
    """
    client_axes = present_client_axes(mesh)
    n_clients = 1
    for a in client_axes:
        n_clients *= mesh.shape[a]

    def per_client(params, batch):
        local = jax.tree.map(
            lambda x: x.reshape(k_steps, x.shape[0] // k_steps, *x.shape[1:]),
            batch)
        first = jax.tree.map(lambda x: x[0], local)
        grams0 = T.loss_fn(cfg, params, first, collect_foof=True)[1]["grams"]
        # factor the gram bank ONCE at θ0; the K scan steps below apply the
        # cached factors (pure solves/matmuls — no per-step factorization)
        precond = F.build_preconditioner(grams0, damping=hp.damping,
                                         method=hp.inverse_method,
                                         ns_iters=hp.ns_iters)

        def sgd(theta, mb):
            (loss, _), g = jax.value_and_grad(
                lambda p: T.loss_fn(cfg, p, mb), has_aux=True)(theta)
            if hp.weight_decay:
                g = tree_axpy(hp.weight_decay, theta, g)
            g = global_norm_clip(g, hp.clip)
            pre = F.apply_preconditioner(precond, theta, g)
            return tree_axpy(-hp.lr, pre, theta), loss

        theta, losses = jax.lax.scan(sgd, params, local)
        if hp.foof_timing == "end":
            last = jax.tree.map(lambda x: x[-1], local)
            grams = T.loss_fn(cfg, theta, last, collect_foof=True)[1]["grams"]
        else:
            grams = grams0
        # ---- preconditioned mixing (Eq. 12) over the client axes ----
        mixed = F.mix_preconditioned_psum(theta, grams, axes=client_axes,
                                          damping=hp.damping,
                                          method=hp.inverse_method,
                                          ns_iters=hp.ns_iters)
        return mixed, jnp.mean(losses)

    def round_fn(params, batch):
        bspecs = jax.tree.map(lambda _: P(client_axes), batch)
        pspecs = jax.tree.map(lambda _: P(), params)
        mixed, loss = shard_map(
            per_client, mesh=mesh, in_specs=(pspecs, bspecs),
            out_specs=(pspecs, P()), axis_names=set(client_axes),
            check=False)(params, batch)
        return mixed, {"loss": loss}

    return round_fn


def round_wire_cost(cfg: ModelConfig, batch, hp: HParams) -> dict:
    """Exact per-cohort communication volume of one ``local_steps`` round
    (what the mesh collectives move per client cohort): uplink is the
    Eq. 12 mixing payload — local params θ_K plus the transmitted FOOF
    grams — and downlink is the mixed params broadcast.  Pure
    ``jax.eval_shape`` (safe at 405B-scale configs); same accounting as
    ``repro.core.api.comm_cost`` for the simulation engines."""
    params = T.abstract_params(cfg)
    grams = jax.eval_shape(
        lambda p, b: T.loss_fn(cfg, p, b, collect_foof=True)[1]["grams"],
        params, batch)
    p_bytes = wire_bytes(params)
    return {"bytes_up": p_bytes + wire_bytes(grams), "bytes_down": p_bytes}


# ============================================================== serving =====

def make_decode_step(cfg: ModelConfig):
    def step(params, cache, batch, pos):
        return T.decode_step(cfg, params, cache, batch, pos)
    return step


def make_prefill_step(cfg: ModelConfig):
    def step(params, batch):
        return T.prefill(cfg, params, batch)
    return step

"""Federated runtime: partitioning, client sampling, simulate + distributed
execution engines."""
from repro.fl.partition import dirichlet_partition, even_partition
from repro.fl.schedule import (ArraySchedule, BufferedSchedule,
                               CohortSchedule, SampledSchedule, trace)
from repro.fl.simulate import FedSim, FedState
from repro.fl.tasks import ConvexTask, DNNTask

"""The paper-scale FL execution engine: a gather/compute/scatter core.

One round = gather the S participating clients' states and batches
(``jnp.take`` along the client axis), vmap local training over exactly
those S clients, aggregate their messages on the server, and scatter the
updated client states back with ``.at[idx].set(...)``.  Non-participants'
states are provably untouched — earlier revisions ran all N clients and
unconditionally overwrote every client's state, silently corrupting
sampled-out SCAFFOLD control variates (and any future stateful client:
drift correctors, cached per-client preconditioners) — and per-round
compute/memory scale with S, not N.

Client sampling (Appendix D.2) therefore costs S/N of a full round; the
jit cache keys on S's shape, so a fixed cohort size compiles once.

This engine reproduces Test 1 / Test 2 / FEMNIST-class experiments.  The
production engine for the 10 assigned architectures is
``repro.fl.distributed`` (mesh collectives instead of a vmap axis; every
cohort participates there, matching the gathered contract).

``mesh=`` switches execution to the mesh-sharded engine
(``repro.fl.sharded``): the client bank and batch bank live sharded on a
``("clients",)`` axis, the round runs as shard_map over client shards,
and server aggregation is per-shard partial reductions + cross-shard
psums.  The default vmap path stays the single-device oracle the sharded
path is contract-tested against.
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import (Algorithm, HParams, Participation,
                                   get_algorithm)

PyTree = Any


@dataclass
class FedState:
    params: PyTree
    server: PyTree
    clients: PyTree       # stacked leading N
    round: int = 0


def _batch_fn_takes_participants(batch_fn) -> bool:
    """Does batch_fn accept a third (participants) argument?

    Only REQUIRED positional params count — a default-valued third param
    is the standard capture idiom (``lambda t, k, ds=ds: ...``), not a
    request for the participant array.
    """
    try:
        sig = inspect.signature(batch_fn)
    except (TypeError, ValueError):
        return False
    params = list(sig.parameters.values())
    if any(p.kind == inspect.Parameter.VAR_POSITIONAL for p in params):
        return True
    required = [p for p in params
                if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                              inspect.Parameter.POSITIONAL_OR_KEYWORD)
                and p.default is inspect.Parameter.empty]
    return len(required) >= 3


class FedSim:
    """Federated simulation of N clients with algorithm ``algo``."""

    def __init__(self, task, algo: str | Algorithm, hp: HParams,
                 n_clients: int, *, mesh=None):
        self.task = task
        self.algo = get_algorithm(algo) if isinstance(algo, str) else algo
        self.hp = hp
        self.n = n_clients
        self.mesh = mesh
        # one jit object; XLA caches a program per participant count S
        # (``full`` is static: the full-cohort program has no gather/scatter)
        self._round_jit = jax.jit(self._round, static_argnames=("full",))
        if mesh is not None:
            from repro.fl import sharded as Sh
            self._sharded = Sh
            self._n_shards = Sh._n_shards(mesh)
            # jit cache keys on the cohort size S only: bucket shapes are
            # [n_shards, min(S, shard_n)] regardless of the random cohort
            self._sharded_round_jit = jax.jit(
                Sh.make_sharded_round(task, self.algo, hp, n_clients, mesh),
                static_argnames=("s", "bucketed"))

    def init(self, rng) -> FedState:
        params = self.task.init(rng)
        server = self.algo.init_server(self.task, self.hp, params)
        one_client = self.algo.init_client(self.task, params)
        clients = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n, *x.shape)), one_client)
        if self.mesh is not None:
            # the bank lives sharded: per-device memory is N / n_shards rows
            clients = self._sharded.shard_clients(self.mesh, clients)
            params = self._sharded.replicate(self.mesh, params)
            server = self._sharded.replicate(self.mesh, server)
        return FedState(params=params, server=server, clients=clients)

    # ------------------------------------------------------------ round ----

    def _round(self, params, server, clients, client_batches, rng, idx,
               weights, full):
        """One gather/compute/scatter round over the participants ``idx``.

        ``client_batches`` leaves lead with either N (full bank, client
        order — gathered here; this interpretation wins when S == N) or
        S == len(idx) < N (caller already built batches in participant
        order, the data path that scales with S).  ``idx`` must be
        duplicate-free (the scatter writes each participant's slot
        exactly once).  ``full`` (static) marks the identity cohort —
        the hot full-participation path skips gather and scatter
        entirely.
        """
        s = self.n if full else idx.shape[0]
        rngs = jax.random.split(rng, s)
        nb = jax.tree.leaves(client_batches)[0].shape[0]
        # ---- gather: only the S participants' states and batches --------
        if full:
            if nb != self.n:
                raise ValueError(f"client_batches lead with {nb}; expected "
                                 f"N={self.n} for a full round")
            gathered, batches = clients, client_batches
        else:
            gathered = jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                                    clients)
            if nb == self.n:
                batches = jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                                       client_batches)
            elif nb == s:
                batches = client_batches
            else:
                raise ValueError(
                    f"client_batches lead with {nb}; expected N={self.n} "
                    f"or S={s} participants")

        # ---- compute: vmap over exactly the S participants --------------
        def client_fn(cstate, cbatches, crng):
            return self.algo.client(self.task, self.hp, params, cstate,
                                    server, cbatches, crng)

        msgs, updated = jax.vmap(client_fn)(gathered, batches, rngs)
        part = Participation(weights=weights, n_total=self.n)
        new_params, new_server = self.algo.server(
            self.task, self.hp, params, server, msgs, part)

        # ---- scatter: write back ONLY the participants' states ----------
        new_clients = updated if full else jax.tree.map(
            lambda bank, upd: bank.at[idx].set(upd), clients, updated)
        metrics = {}
        if isinstance(msgs, dict) and "loss" in msgs:
            metrics["client_loss"] = jnp.sum(msgs["loss"] * weights) / \
                jnp.maximum(jnp.sum(weights), 1e-12)
        return new_params, new_server, new_clients, metrics

    def round(self, state: FedState, client_batches, rng,
              mask=None, *, participants=None) -> tuple[FedState, dict]:
        """One round.

        ``participants``: host int array [S] of unique client ids
        (preferred).  ``mask``: legacy {0,1}^N participation mask —
        converted host-side to (participants, weights); its nonzero
        entries become the per-participant aggregation weights.

        A full cohort (S == N) is canonicalized to client order — the id
        set is all of [0, N), so order carries no information, and
        ``client_batches`` is then unambiguously the client-ordered bank
        (pre-gathered batches in a permuted participant order are only
        meaningful for S < N).
        """
        if participants is not None:
            idx = np.asarray(participants)
            weights = jnp.ones((idx.shape[0],), jnp.float32)
        elif mask is not None:
            mask_np = np.asarray(mask)
            idx = np.flatnonzero(mask_np > 0)
            weights = jnp.asarray(mask_np[idx], jnp.float32)
        else:
            idx = np.arange(self.n)
            weights = jnp.ones((self.n,), jnp.float32)
        if idx.size == 0:
            # empty cohort: nothing trains, nothing aggregates
            return FedState(params=state.params, server=state.server,
                            clients=state.clients,
                            round=state.round + 1), {}
        if idx.min() < 0 or idx.max() >= self.n:
            raise ValueError(f"participant ids must be in [0, {self.n}); "
                             f"got {idx.min()}..{idx.max()}")
        if np.unique(idx).size != idx.size:
            raise ValueError("participant ids must be unique (the scatter "
                             "writes each slot exactly once)")
        full = idx.size == self.n
        if full and not np.array_equal(idx, np.arange(self.n)):
            # canonicalize: unique + in-range + S == N means the id set is
            # exactly [0, N); reorder weights to match client order
            order = np.argsort(idx)
            idx = idx[order]
            weights = weights[jnp.asarray(order)]
        if self.mesh is not None:
            p, s, c, metrics = self._round_sharded(state, client_batches,
                                                   rng, idx, weights)
        else:
            p, s, c, metrics = self._round_jit(
                state.params, state.server, state.clients, client_batches,
                rng, jnp.asarray(idx, jnp.int32), weights, full=full)
        return FedState(params=p, server=s, clients=c,
                        round=state.round + 1), metrics

    def _round_sharded(self, state: FedState, client_batches, rng, idx,
                       weights):
        """One round on the mesh-sharded engine: host-side participant
        bucketing, then shard_map gather/compute/scatter."""
        s = int(idx.size)
        local, pos, w = self._sharded.bucket_participants(
            idx, np.asarray(weights, np.float32), self.n, self._n_shards)
        nb = jax.tree.leaves(client_batches)[0].shape[0]
        if nb == self.n:
            batches, bucketed = client_batches, False
        elif nb == s:
            # pre-gathered [S] participant batches → pre-bucketed rows
            # [n_shards·cap] in shard order (padding clamps to row 0)
            flat_pos = jnp.asarray(pos.reshape(-1))
            batches = jax.tree.map(
                lambda x: jnp.take(x, flat_pos, axis=0), client_batches)
            bucketed = True
        else:
            raise ValueError(
                f"client_batches lead with {nb}; expected N={self.n} "
                f"or S={s} participants")
        return self._sharded_round_jit(
            state.params, state.server, state.clients, batches, rng,
            jnp.asarray(local), jnp.asarray(pos), jnp.asarray(w),
            s=s, bucketed=bucketed)

    # ------------------------------------------------------------ loop -----

    def run(self, rng, batch_fn, rounds: int, *, sample_clients: int = 0,
            eval_fn=None, eval_every: int = 1, seed: int = 0):
        """batch_fn(round, rng) -> client_batches [N, K, ...], or
        batch_fn(round, rng, participants) -> [S, K, ...] to build batches
        for the sampled cohort only (the data path that scales with S).

        ``sample_clients`` > 0 enables per-round uniform client sampling.
        Returns (final_state, history dict of lists).
        """
        state = self.init(rng)
        hist = {"round": [], "metric": [], "loss": []}
        np_rng = np.random.default_rng(seed)
        takes_participants = _batch_fn_takes_participants(batch_fn)
        for t in range(rounds):
            rng, kb, kr = jax.random.split(rng, 3)
            if sample_clients and sample_clients < self.n:
                chosen = np.sort(np_rng.choice(self.n, size=sample_clients,
                                               replace=False))
            else:
                chosen = np.arange(self.n)
            batches = (batch_fn(t, kb, chosen) if takes_participants
                       else batch_fn(t, kb))
            state, metrics = self.round(state, batches, kr,
                                        participants=chosen)
            if eval_fn is not None and (t % eval_every == 0 or t == rounds - 1):
                hist["round"].append(t)
                hist["metric"].append(float(eval_fn(state.params)))
                hist["loss"].append(float(metrics.get("client_loss", jnp.nan)))
        return state, hist

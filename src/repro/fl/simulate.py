"""The paper-scale FL execution engine.

Clients are a vmapped leading axis; one jitted ``round`` = vmapped local
training on all N clients + one server aggregation.  Client sampling
(Appendix D.2) gathers a fixed-size subset before aggregation so every
algorithm sees exactly the participating messages.

This engine reproduces Test 1 / Test 2 / FEMNIST-class experiments.  The
production engine for the 10 assigned architectures is
``repro.fl.distributed`` (mesh collectives instead of a vmap axis).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import Algorithm, HParams, get_algorithm

PyTree = Any


@dataclass
class FedState:
    params: PyTree
    server: PyTree
    clients: PyTree       # stacked leading N
    round: int = 0


class FedSim:
    """Federated simulation of N clients with algorithm ``algo``."""

    def __init__(self, task, algo: str | Algorithm, hp: HParams,
                 n_clients: int):
        self.task = task
        self.algo = get_algorithm(algo) if isinstance(algo, str) else algo
        self.hp = hp
        self.n = n_clients
        self._round_jit = jax.jit(self._round)

    def init(self, rng) -> FedState:
        params = self.task.init(rng)
        server = self.algo.init_server(self.task, params)
        one_client = self.algo.init_client(self.task, params)
        clients = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n, *x.shape)), one_client)
        return FedState(params=params, server=server, clients=clients)

    # ------------------------------------------------------------ round ----

    def _round(self, params, server, clients, client_batches, rng,
               mask):
        """client_batches: pytree with leading [N, K, ...]."""
        rngs = jax.random.split(rng, self.n)

        def client_fn(cstate, batches, crng):
            return self.algo.client(self.task, self.hp, params, cstate,
                                    server, batches, crng)

        msgs, new_clients = jax.vmap(client_fn)(clients, client_batches, rngs)
        new_params, new_server = self.algo.server(
            self.task, self.hp, params, server, msgs, mask)
        metrics = {}
        if isinstance(msgs, dict) and "loss" in msgs:
            metrics["client_loss"] = jnp.sum(msgs["loss"] * mask) / \
                jnp.maximum(jnp.sum(mask), 1.0)
        return new_params, new_server, new_clients, metrics

    def round(self, state: FedState, client_batches, rng,
              mask=None) -> tuple[FedState, dict]:
        if mask is None:
            mask = jnp.ones((self.n,), jnp.float32)
        p, s, c, metrics = self._round_jit(state.params, state.server,
                                           state.clients, client_batches,
                                           rng, mask)
        return FedState(params=p, server=s, clients=c,
                        round=state.round + 1), metrics

    # ------------------------------------------------------------ loop -----

    def run(self, rng, batch_fn, rounds: int, *, sample_clients: int = 0,
            eval_fn=None, eval_every: int = 1, seed: int = 0):
        """batch_fn(round, rng) -> client_batches [N, K, ...].

        ``sample_clients`` > 0 enables per-round uniform client sampling.
        Returns (final_state, history dict of lists).
        """
        state = self.init(rng)
        hist = {"round": [], "metric": [], "loss": []}
        np_rng = np.random.default_rng(seed)
        for t in range(rounds):
            rng, kb, kr = jax.random.split(rng, 3)
            batches = batch_fn(t, kb)
            if sample_clients and sample_clients < self.n:
                chosen = np_rng.choice(self.n, size=sample_clients,
                                       replace=False)
                mask = jnp.zeros((self.n,), jnp.float32).at[chosen].set(1.0)
            else:
                mask = jnp.ones((self.n,), jnp.float32)
            state, metrics = self.round(state, batches, kr, mask)
            if eval_fn is not None and (t % eval_every == 0 or t == rounds - 1):
                hist["round"].append(t)
                hist["metric"].append(float(eval_fn(state.params)))
                hist["loss"].append(float(metrics.get("client_loss", jnp.nan)))
        return state, hist

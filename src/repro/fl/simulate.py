"""The paper-scale FL execution engine: a gather/compute/scatter core.

One round = gather the S participating clients' states and batches
(``jnp.take`` along the client axis), vmap local training over exactly
those S clients, aggregate their messages on the server, and scatter the
updated client states back with ``.at[idx].set(...)``.  Non-participants'
states are provably untouched — earlier revisions ran all N clients and
unconditionally overwrote every client's state, silently corrupting
sampled-out SCAFFOLD control variates (and any future stateful client:
drift correctors, cached per-client preconditioners) — and per-round
compute/memory scale with S, not N.

Client sampling (Appendix D.2) therefore costs S/N of a full round; the
jit cache keys on S's shape, so a fixed cohort size compiles once.

This engine reproduces Test 1 / Test 2 / FEMNIST-class experiments.  The
production engine for the 10 assigned architectures is
``repro.fl.distributed`` (mesh collectives instead of a vmap axis; every
cohort participates there, matching the gathered contract).

``mesh=`` switches execution to the mesh-sharded engine
(``repro.fl.sharded``): the client bank and batch bank live sharded on a
``("clients",)`` axis, the round runs as shard_map over client shards,
and server aggregation is per-shard partial reductions + cross-shard
psums.  The default vmap path stays the single-device oracle the sharded
path is contract-tested against.

Two drivers sit on top of the round:

* ``run`` — the per-round host loop (numpy cohort sampling, host
  ``batch_fn``, one jit dispatch per round);
* ``run_scanned`` — the scan-compiled driver: chunks of ``eval_every``
  rounds compile into ONE ``lax.scan`` program, cohorts drawn in-graph
  (:func:`sample_cohort`) and batches drawn in-graph from the task's
  resident :class:`~repro.data.federated.DeviceDataBank`.  The banked
  per-round ``round(client_batches=None)`` over :func:`round_keys` keys
  is its bit-for-bit oracle.

Every round/chunk jit DONATES (params, server, clients): the [N, ...]
client bank updates in place (single-buffered) and a ``FedState`` is
consumed by the round it enters — chain states forward or
``state.copy()`` to branch.  Reusing a consumed state is caught at the
``round`` entry and re-raised with an actionable message.

Client residency (``repro.fl.store``)
-------------------------------------
Where the per-client rows live is a :class:`~repro.fl.store.ClientStore`
decision, not an engine assumption.  With a RESIDENT data bank
(``ds.device_bank``) everything above is unchanged — the resident store
is today's behavior, bit-for-bit.  With a PAGED bank
(``ds.paged_bank``) the engine runs out-of-core: client state lives in
a host :class:`~repro.fl.store.HostStateStore`, cohorts are drawn
host-side from the SAME key stream (:func:`sample_cohort` is the
documented oracle, so eager draws equal in-graph draws), and each
chunk stages only the union of its cohorts' rows to device — the same
scanned programs run over a ``[cap, ...]`` staged bank, with
``cap = min(chunk · S, N)``, so device memory is bounded by the cohort
schedule while N grows to 10⁵+.  Paging (gather/scatter/prefetch)
happens ONLY at chunk boundaries, outside the scanned graph; the next
chunk's data rows prefetch while the current chunk computes.

Buffered-async rounds (``repro.fl.schedule``)
---------------------------------------------
``run_scanned(cohorts=BufferedSchedule(...))`` runs the FedBuff-style
buffered-async engine: the arrival process (dispatch round, completion
delay, report round, buffer flush at the goal size) is resolved
host-side into ``(cohorts, staleness)`` arrays, and the SCANNED graph
consumes them as just another schedule — a flush round is a cohort row,
a fill round is an all--1 row the ``lax.cond`` skips, so the whole
stream still compiles to one ``lax.scan`` per chunk with the same
donation discipline.  The async carry adds a params RING
(``[window, ...]`` snapshots, ``window = max staleness + 1``, donated):
round ``t`` snapshots its params into slot ``t % window`` and each
flushed report trains against the slot it was dispatched from, so
training compute happens at flush time against dispatch-time inputs —
equivalent by round-body purity, and zero host work mid-chunk.
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api as API
from repro.core.algorithms import (Algorithm, HParams, Participation,
                                   get_algorithm)
from repro.fl import faults as FLT
from repro.fl import schedule as SCH
from repro.fl.store import HostStateStore, plan_chunk, round_up

PyTree = Any


def round_metrics(msgs, part: Participation) -> dict:
    """Engine-shared per-round metrics from the stacked client messages:
    the weighted-mean ``client_loss`` (when the message carries a loss),
    aggregated through ``part.wmean`` so the vmap and sharded engines
    share ONE fp32 aggregation path (``part.axes`` inserts the
    cross-shard psum)."""
    loss = API.client_loss(msgs)
    return {} if loss is None else {"client_loss": part.wmean(loss)}


@dataclass
class FedState:
    params: PyTree
    server: PyTree
    clients: PyTree       # stacked leading N
    round: int = 0

    def copy(self) -> "FedState":
        """A deep copy.  The round jits DONATE params/server/clients (the
        [N, ...] bank updates in place instead of double-buffering), so a
        state is consumed by the round it enters — copy first to round
        twice from the same state.  A paged state's clients are a
        :class:`~repro.fl.store.HostStateStore` (mutated in place by the
        chunk scatters); its copy is a deep host copy."""
        cp = partial(jax.tree.map, jnp.copy)
        cl = (self.clients.copy()
              if isinstance(self.clients, HostStateStore)
              else cp(self.clients))
        return FedState(params=cp(self.params), server=cp(self.server),
                        clients=cl, round=self.round)


def sample_cohort(key, n: int, s: int) -> jax.Array:
    """Draw S unique participant ids from [0, N), sorted ascending.

    THE in-graph sampling oracle contract: ``run_scanned`` calls this
    inside the scanned round body, and evaluating the same function
    eagerly at the same key reproduces the scanned cohort exactly — the
    per-round ``FedSim.round`` loop fed those cohorts is the bit-for-bit
    oracle the scanned driver is contract-tested against.  (The host
    numpy sampler in ``FedSim.run`` stays the seeded oracle for the
    legacy per-round driver.)
    """
    return jnp.sort(jax.random.permutation(key, n)[:s]).astype(jnp.int32)


@partial(jax.jit, static_argnums=(1, 2))
def _draw_cohorts(keys, n: int, s: int) -> jax.Array:
    """Eager replay of the scanned driver's in-graph cohort draws:
    :func:`sample_cohort` at the ``kc`` each round splits off (the oracle
    contract above).  Module-level jit so the paged driver pays one
    compile per (rounds, N, S) — not one per ``run_scanned`` call."""
    return jax.vmap(
        lambda k: sample_cohort(jax.random.split(k, 3)[0], n, s))(keys)


def round_keys(rng, rounds: int):
    """``run_scanned``'s rng discipline: ``(init_key, keys[rounds])``.

    Round ``t`` consumes ``kc, kb, kr = jax.random.split(keys[t], 3)`` —
    cohort draw, batch draw, round rng.  Oracle loops reproduce the
    scanned stream by doing the same splits host-side.
    """
    k_init, k_rounds = jax.random.split(rng)
    return k_init, jax.random.split(k_rounds, rounds)


def _batch_fn_takes_participants(batch_fn) -> bool:
    """Does batch_fn accept a third (participants) argument?

    Only REQUIRED positional params count — a default-valued third param
    is the standard capture idiom (``lambda t, k, ds=ds: ...``), not a
    request for the participant array.
    """
    try:
        sig = inspect.signature(batch_fn)
    except (TypeError, ValueError):
        return False
    params = list(sig.parameters.values())
    if any(p.kind == inspect.Parameter.VAR_POSITIONAL for p in params):
        return True
    required = [p for p in params
                if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                              inspect.Parameter.POSITIONAL_OR_KEYWORD)
                and p.default is inspect.Parameter.empty]
    return len(required) >= 3


class FedSim:
    """Federated simulation of N clients with algorithm ``algo``."""

    def __init__(self, task, algo: str | Algorithm, hp: HParams,
                 n_clients: int, *, mesh=None, scatter_overlap: bool = True):
        self.task = task
        self.algo = get_algorithm(algo) if isinstance(algo, str) else algo
        self.hp = hp
        self.n = n_clients
        self.mesh = mesh
        #: paged driver: drain each chunk's state write-back on a
        #: background thread under the next chunk's compute (stores that
        #: implement ``scatter_async``/``fence``); False forces the
        #: synchronous scatter (the overlap on/off bench axis)
        self.scatter_overlap = scatter_overlap
        # one jit object; XLA caches a program per participant count S
        # (``full`` is static: the full-cohort program has no gather/scatter).
        # params/server/clients are DONATED: the scatter aliases the [N, ...]
        # client bank in place instead of allocating a second copy — a state
        # is consumed by the round it enters (FedState.copy to reuse one).
        self._round_jit = jax.jit(self._round1, static_argnames=("full",),
                                  donate_argnums=(0, 1, 2))
        self._scan_jit = jax.jit(self._scan_rounds,
                                 static_argnames=("s", "scheduled"),
                                 donate_argnums=(0, 1, 2))
        # buffered-async chunk jit: the params RING joins the donated
        # carry (argnum 3) — snapshots single-buffer in place like the
        # client bank
        self._scan_async_jit = jax.jit(
            self._scan_rounds_async,
            static_argnames=("s", "window", "wpow"),
            donate_argnums=(0, 1, 2, 3))
        # fault-tolerant (quarantine) chunk jits: SEPARATE programs, not a
        # branch inside the plain ones — the zero-fault contract is
        # FaultModel-run ≡ plain-engine-run, and keeping the plain jits'
        # graphs untouched is what makes that checkable bitwise
        self._scan_q_jit = jax.jit(
            self._scan_rounds_q, static_argnames=("s", "clip"),
            donate_argnums=(0, 1, 2))
        self._scan_async_q_jit = jax.jit(
            self._scan_rounds_async_q,
            static_argnames=("s", "window", "wpow", "clip"),
            donate_argnums=(0, 1, 2, 3))
        self._full_idx = None         # cached identity-cohort device arrays
        self._full_w = None
        self._comm_cache = {}         # per-batch-struct (up, down) bytes
        self._stage_sh = None         # paged staging placement (mesh only)
        if mesh is None:
            self._banked_jit = jax.jit(self._round_banked,
                                       static_argnames=("s", "sample"),
                                       donate_argnums=(0, 1, 2))
        else:
            from repro.fl import sharded as Sh
            self._sharded = Sh
            self._n_shards = Sh._n_shards(mesh)
            # jit cache keys on the cohort size S only: bucket shapes are
            # [n_shards, min(S, shard_n)] regardless of the random cohort
            self._sharded_round_fn = Sh.make_sharded_round(
                task, self.algo, hp, n_clients, mesh)
            self._sharded_round_jit = jax.jit(
                self._sharded_round1, static_argnames=("s", "bucketed"),
                donate_argnums=(0, 1, 2))
            self._scan_sharded_jit = jax.jit(
                self._scan_rounds_sharded,
                static_argnames=("s", "scheduled"), donate_argnums=(0, 1, 2))
            self._sharded_round_async_fn = Sh.make_sharded_round_async(
                task, self.algo, hp, n_clients, mesh)
            self._scan_async_jit = jax.jit(
                self._scan_rounds_async_sharded,
                static_argnames=("s", "window", "wpow"),
                donate_argnums=(0, 1, 2, 3))
            self._sharded_round_q_fn = Sh.make_sharded_round_q(
                task, self.algo, hp, n_clients, mesh)
            self._sharded_round_async_q_fn = Sh.make_sharded_round_async_q(
                task, self.algo, hp, n_clients, mesh)
            self._scan_q_jit = jax.jit(
                self._scan_rounds_sharded_q,
                static_argnames=("s", "clip"), donate_argnums=(0, 1, 2))
            self._scan_async_q_jit = jax.jit(
                self._scan_rounds_async_sharded_q,
                static_argnames=("s", "window", "wpow", "clip"),
                donate_argnums=(0, 1, 2, 3))
            self._banked_jit = jax.jit(self._sharded_round_banked,
                                       static_argnames=("s", "sample"),
                                       donate_argnums=(0, 1, 2))
            self._stage_sh = Sh.staging_sharding(mesh)

    @property
    def _paged(self) -> bool:
        """True when the task's data bank is a PAGED ClientStore — the
        single switch that moves client state to a host store and routes
        banked rounds / ``run_scanned`` through the paged driver."""
        bank = getattr(self.task, "data", None)
        return bank is not None and not getattr(bank, "is_resident", True)

    def init(self, rng) -> FedState:
        params = self.task.init(rng)
        server = self.algo.init_server(self.task, self.hp, params)
        one_client = self.algo.init_client(self.task, params)
        if self._paged:
            # paged mode: the [N, ...] bank lives COLD-side, on the same
            # residency rung as the data bank — a disk-tier bank pairs a
            # disk-tier state store (MmapPagedBank.state_store), anything
            # else falls back to the host-numpy store; stateless
            # algorithms get an empty store (zero paging cost)
            factory = getattr(self.task.data, "state_store", None)
            clients = (factory(one_client, self.n) if factory is not None
                       else HostStateStore.broadcast(one_client, self.n))
        else:
            clients = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.n, *x.shape)),
                one_client)
        if self.mesh is not None:
            # the bank lives sharded: per-device memory is N / n_shards
            # rows (paged banks stay host-side; their staged chunks are
            # placed shard-locally at gather time instead)
            if not self._paged:
                clients = self._sharded.shard_clients(self.mesh, clients)
            params = self._sharded.replicate(self.mesh, params)
            server = self._sharded.replicate(self.mesh, server)
        return FedState(params=params, server=server, clients=clients)

    def _guard_live(self, state: FedState) -> None:
        """Reject a donated-away state at the entry point, BEFORE jax
        surfaces its opaque donated-buffer RuntimeError from deep inside
        dispatch."""
        cl = () if isinstance(state.clients, HostStateStore) \
            else state.clients
        for leaf in jax.tree.leaves((state.params, state.server, cl)):
            if isinstance(leaf, jax.Array) and leaf.is_deleted():
                raise ValueError(
                    "this FedState was already consumed: round/run_scanned "
                    "jits DONATE params/server/clients (the client bank "
                    "updates in place), so a state can enter exactly one "
                    "round. Chain the returned state forward, or call "
                    "FedState.copy() BEFORE the round to keep a live "
                    "branch.")

    # ---------------------------------------------------- comm accounting --

    def _comm_metrics(self, state: FedState, one_batch, s: int) -> dict:
        """Per-round ``bytes_up``/``bytes_down`` for a cohort of S clients.

        ``one_batch`` is ONE client's ``[K, B, ...]`` batch pytree (arrays
        or structs).  Pure ``jax.eval_shape`` through the algorithm's
        client fn — the ENCODED message's declared WIRE fields are what's
        counted, so wire transforms (bf16 / top-k / gram sketch) show up
        directly in the metric.  Cached per batch struct; must run before
        the round jit (which donates/deletes the state's buffers).
        """
        key = tuple((tuple(x.shape), str(np.dtype(x.dtype)))
                    for x in jax.tree.leaves(one_batch))
        cached = self._comm_cache.get(key)
        if cached is None:
            sds = partial(jax.tree.map,
                          lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype))
            p, sv = sds(state.params), sds(state.server)
            cl = (state.clients.bank
                  if isinstance(state.clients, HostStateStore)
                  else state.clients)
            c = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), cl)
            msg = API.message_struct(self.algo, self.task, self.hp, p, c,
                                     sv, one_batch)
            up = API.message_wire_bytes(msg)
            down = API.downlink_bytes(self.algo, p, sv)
            cached = self._comm_cache[key] = (up, down)
        up, down = cached
        return {"bytes_up": up * s, "bytes_down": down * s}

    def _banked_batch_struct(self, bank):
        """ONE client's batch struct as drawn from a data bank (cached —
        the banked per-round path calls this every round).  Keyed by the
        bank's own leaf shapes/dtypes plus its static spec, never by
        object identity (ids get recycled, and the spec alone omits the
        feature shapes).  Works for both residency classes — paged banks
        answer from their host shapes without staging anything."""
        key = ("bank", bank.spec,
               tuple((tuple(x.shape), str(np.dtype(x.dtype)))
                     for x in (bank.x, bank.y, bank.sizes)))
        cached = self._comm_cache.get(key)
        if cached is None:
            cached = self._comm_cache[key] = bank.one_client_struct()
        return cached

    # ------------------------------------------------------------ round ----

    @staticmethod
    def _scan_of_one(round_fn, carry):
        """Run one round as a length-1 ``lax.scan`` over ``carry`` =
        (params, server, clients).

        The per-round jits go through here so their round body compiles in
        the SAME loop-body context as ``run_scanned``'s chunked scan — XLA
        fuses (FMA-contracts) straight-line code differently from while
        bodies by ~1 ulp, and the scanned driver is contract-tested to
        match the per-round oracle bit-for-bit (tests/test_scan.py).
        """
        def body(c, _):
            p, sv, cl, m = round_fn(*c)
            return (p, sv, cl), m

        (p, sv, cl), ms = jax.lax.scan(body, carry, None, length=1)
        return p, sv, cl, jax.tree.map(lambda x: x[0], ms)

    def _round1(self, params, server, clients, client_batches, rng, idx,
                weights, full):
        """jit target for :meth:`round` — ``_round`` via a length-1 scan."""
        return self._scan_of_one(
            lambda p, sv, c: self._round(p, sv, c, client_batches, rng,
                                         idx, weights, full=full),
            (params, server, clients))

    def _sharded_round1(self, params, server, clients, batches, rng, local,
                        pos, w, *, s, bucketed):
        """jit target for the sharded round — same length-1-scan context."""
        return self._scan_of_one(
            lambda p, sv, c: self._sharded_round_fn(
                p, sv, c, batches, rng, local, pos, w, s=s,
                bucketed=bucketed),
            (params, server, clients))

    # ------------------------------------------------- banked rounds -------

    def _cohort(self, kc, idx, *, s, sample):
        """The round body's cohort: identity, in-graph draw, or caller's."""
        if s == self.n:
            return jnp.arange(self.n, dtype=jnp.int32)
        return sample_cohort(kc, self.n, s) if sample else idx

    def _sharded_round_impl(self, params, server, clients, batches, kr, idx,
                            weights, s: int, n_rows: int):
        """One sharded round from a cohort + [S] batches, fully in-graph:
        bucket the cohort (``sharded.bucket_cohort``), pre-bucket the
        participant batches into shard order, run the shard_map round.

        ``n_rows`` is the CLIENT-BANK row count the bucketing addresses —
        N for resident banks (today's behavior, unchanged), the staged
        capacity for paged chunks (cohort ids are then staged-row
        positions; aggregation still uses the true ``n_total = N`` inside
        the round fn)."""
        local, pos, w = self._sharded.bucket_cohort(idx, weights, n_rows,
                                                    self._n_shards)
        flat_pos = pos.reshape(-1)
        b = jax.tree.map(lambda x: jnp.take(x, flat_pos, axis=0), batches)
        return self._sharded_round_fn(params, server, clients, b, kr, local,
                                      pos, w, s=s, bucketed=True)

    def _banked_body(self, round_impl, bank, *, s, sample):
        """One banked round: split the round key, draw cohort + batches
        in-graph, run the engine round.  Shared (same trace) between the
        banked per-round jits and ``run_scanned``'s scan body — that
        sharing is what makes the two bit-for-bit comparable."""
        def fn(key, idx, params, server, clients):
            kc, kb, kr = jax.random.split(key, 3)
            ii = self._cohort(kc, idx, s=s, sample=sample)
            weights = jnp.ones((s,), jnp.float32)
            batches = bank.sample(kb, ii)
            return round_impl(params, server, clients, batches, kr, ii,
                              weights)
        return fn

    def _vmap_round_impl(self, s: int):
        return lambda p, sv, c, b, kr, idx, w: self._round(
            p, sv, c, b, kr, idx, w, full=s == self.n)

    def _round_banked(self, params, server, clients, bank, key, idx, *,
                      s, sample):
        """jit target for banked rounds (``round(..., client_batches=None)``)
        on the vmap engine."""
        fn = self._banked_body(self._vmap_round_impl(s), bank, s=s,
                               sample=sample)
        return self._scan_of_one(
            lambda p, sv, c: fn(key, idx, p, sv, c),
            (params, server, clients))

    def _sharded_round_banked(self, params, server, clients, bank, key, idx,
                              *, s, sample):
        """Banked-round jit target on the mesh-sharded engine."""
        fn = self._banked_body(
            lambda p, sv, c, b, kr, ii, w: self._sharded_round_impl(
                p, sv, c, b, kr, ii, w, s, bank.n_clients),
            bank, s=s, sample=sample)
        return self._scan_of_one(
            lambda p, sv, c: fn(key, idx, p, sv, c),
            (params, server, clients))

    def _round(self, params, server, clients, client_batches, rng, idx,
               weights, full):
        """One gather/compute/scatter round over the participants ``idx``.

        ``client_batches`` leaves lead with either N (full bank, client
        order — gathered here; this interpretation wins when S == N) or
        S == len(idx) < N (caller already built batches in participant
        order, the data path that scales with S).  ``idx`` must be
        duplicate-free (the scatter writes each participant's slot
        exactly once).  ``full`` (static) marks the identity cohort —
        the hot full-participation path skips gather and scatter
        entirely.
        """
        s = self.n if full else idx.shape[0]
        rngs = jax.random.split(rng, s)
        nb = jax.tree.leaves(client_batches)[0].shape[0]
        # ---- gather: only the S participants' states and batches --------
        if full:
            if nb != self.n:
                raise ValueError(f"client_batches lead with {nb}; expected "
                                 f"N={self.n} for a full round")
            gathered, batches = clients, client_batches
        else:
            gathered = jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                                    clients)
            if nb == self.n:
                batches = jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                                       client_batches)
            elif nb == s:
                batches = client_batches
            else:
                raise ValueError(
                    f"client_batches lead with {nb}; expected N={self.n} "
                    f"or S={s} participants")

        # ---- compute: vmap over exactly the S participants --------------
        def client_fn(cstate, cbatches, crng):
            return self.algo.client(self.task, self.hp, params, cstate,
                                    server, cbatches, crng)

        msgs, updated = jax.vmap(client_fn)(gathered, batches, rngs)
        part = Participation(weights=weights, n_total=self.n)
        new_params, new_server = self.algo.server(
            self.task, self.hp, params, server, msgs, part)

        # ---- scatter: write back ONLY the participants' states ----------
        new_clients = updated if full else jax.tree.map(
            lambda bank, upd: bank.at[idx].set(upd), clients, updated)
        return new_params, new_server, new_clients, round_metrics(msgs, part)

    def round(self, state: FedState, client_batches, rng,
              mask=None, *, participants=None,
              sample_clients: int = 0) -> tuple[FedState, dict]:
        """One round.

        ``participants``: host int array [S] of unique client ids
        (preferred).  ``mask``: legacy {0,1}^N participation mask —
        converted host-side to (participants, weights); its nonzero
        entries become the per-participant aggregation weights.

        A full cohort (S == N) is canonicalized to client order — the id
        set is all of [0, N), so order carries no information, and
        ``client_batches`` is then unambiguously the client-ordered bank
        (pre-gathered batches in a permuted participant order are only
        meaningful for S < N).

        Returned metrics include the round's exact communication volume
        (``bytes_up``/``bytes_down`` — host ints from the eval_shape
        accounting in :mod:`repro.core.api`, scaled by the cohort size)
        and, when the algorithm's message carries a loss, the
        ``client_loss`` weighted mean.

        ``client_batches=None`` selects the BANKED round: the task's
        resident data bank draws the batches in-graph, and ``rng`` is the
        round key (split three ways inside the program — cohort, batch,
        round, exactly :func:`round_keys`' discipline).  With
        ``sample_clients`` ∈ (0, N) the cohort itself is drawn in-graph
        by :func:`sample_cohort`; with ``participants`` (sorted unique)
        the cohort is the caller's; with neither, everyone participates.
        A banked ``round()`` loop over :func:`round_keys` keys is the
        per-round oracle ``run_scanned`` matches bit-for-bit.

        With a PAGED data bank (``ds.paged_bank``) only banked rounds are
        supported — explicit ``client_batches`` presuppose a resident
        client bank to index into.
        """
        self._guard_live(state)
        if client_batches is None:
            return self._round_banked_host(state, rng, mask, participants,
                                           sample_clients)
        if self._paged:
            raise ValueError(
                "a paged data bank supports banked rounds only "
                "(client_batches=None); explicit client_batches assume a "
                "resident [N, ...] bank. Use ds.device_bank(...) for the "
                "explicit-batch path.")
        if sample_clients:
            raise ValueError("sample_clients= is the banked round's "
                             "in-graph cohort draw (client_batches=None); "
                             "with explicit batches pass participants= for "
                             "the cohort they belong to")
        # weights stay NUMPY through canonicalization — one device upload
        # at the jit boundary, no host→device transfer per reorder
        if participants is not None:
            idx = np.asarray(participants)
            weights = np.ones((idx.shape[0],), np.float32)
        elif mask is not None:
            mask_np = np.asarray(mask)
            idx = np.flatnonzero(mask_np > 0)
            weights = np.asarray(mask_np[idx], np.float32)
        else:
            idx = np.arange(self.n)
            weights = np.ones((self.n,), np.float32)
        if idx.size == 0:
            # empty cohort: nothing trains, nothing aggregates
            return FedState(params=state.params, server=state.server,
                            clients=state.clients,
                            round=state.round + 1), {}
        if idx.min() < 0 or idx.max() >= self.n:
            raise ValueError(f"participant ids must be in [0, {self.n}); "
                             f"got {idx.min()}..{idx.max()}")
        if np.unique(idx).size != idx.size:
            raise ValueError("participant ids must be unique (the scatter "
                             "writes each slot exactly once)")
        full = idx.size == self.n
        if full and not np.array_equal(idx, np.arange(self.n)):
            # canonicalize: unique + in-range + S == N means the id set is
            # exactly [0, N); reorder weights to match client order
            order = np.argsort(idx)
            idx = idx[order]
            weights = weights[order]
        one_batch = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
            client_batches)
        comm = self._comm_metrics(state, one_batch, int(idx.size))
        if self.mesh is not None:
            p, s, c, metrics = self._round_sharded(state, client_batches,
                                                   rng, idx, weights)
        else:
            if full and np.all(weights == 1.0):
                # identity cohort: reuse the cached device arrays instead
                # of re-uploading idx/ones every round
                if self._full_idx is None:
                    self._full_idx = jnp.arange(self.n, dtype=jnp.int32)
                    self._full_w = jnp.ones((self.n,), jnp.float32)
                idx_dev, w_dev = self._full_idx, self._full_w
            else:
                idx_dev = jnp.asarray(idx, jnp.int32)
                w_dev = jnp.asarray(weights, jnp.float32)
            p, s, c, metrics = self._round_jit(
                state.params, state.server, state.clients, client_batches,
                rng, idx_dev, w_dev, full=full)
        metrics = dict(metrics, **comm)
        return FedState(params=p, server=s, clients=c,
                        round=state.round + 1), metrics

    def _round_banked_host(self, state: FedState, rng, mask, participants,
                           sample_clients: int):
        """Host-side half of the banked round: resolve the cohort mode,
        validate, dispatch the engine's banked jit (resident), or stage
        through the stores (paged)."""
        bank = getattr(self.task, "data", None)
        if bank is None:
            raise ValueError("banked rounds (client_batches=None) need a "
                             "data bank: task.with_data("
                             "ds.device_bank(steps, batch)) or "
                             "task.with_data(ds.paged_bank(steps, batch))")
        if mask is not None:
            raise ValueError("banked rounds take participants=/"
                             "sample_clients=, not mask= (weights are "
                             "uniform in-graph)")
        if sample_clients and participants is not None:
            raise ValueError("pass sample_clients= OR participants=")
        idx = None
        if 0 < sample_clients < self.n:
            s, sample = int(sample_clients), True
        elif participants is not None:
            idx = np.asarray(participants)
            if idx.size == 0:
                return FedState(params=state.params, server=state.server,
                                clients=state.clients,
                                round=state.round + 1), {}
            if (idx.min() < 0 or idx.max() >= self.n
                    or np.unique(idx).size != idx.size
                    or not np.all(np.diff(idx) > 0)):
                raise ValueError("banked participants must be sorted unique "
                                 f"ids in [0, {self.n})")
            s, sample = int(idx.size), False
        else:
            s, sample = self.n, False
        if self._paged:
            return self._round_banked_paged(state, bank, rng, s, sample, idx)
        comm = self._comm_metrics(state, self._banked_batch_struct(bank), s)
        idx_dev = (jnp.asarray(idx, jnp.int32)
                   if idx is not None and s < self.n else None)
        p, sv, c, metrics = self._banked_jit(
            state.params, state.server, state.clients, bank, rng, idx_dev,
            s=s, sample=sample)
        metrics = dict(metrics, **comm)
        return FedState(params=p, server=sv, clients=c,
                        round=state.round + 1), metrics

    def _round_banked_paged(self, state: FedState, bank, rng, s: int,
                            sample: bool, idx):
        """Paged banked round: resolve the cohort HOST-side, stage its
        rows, run the SAME banked jit over the ``[cap, ...]`` staged
        views, write updated state rows back.

        An in-graph ``sample_clients`` draw is reproduced eagerly —
        :func:`sample_cohort` over the same ``kc`` the scanned body would
        split off (the documented oracle contract), so paged and resident
        runs see identical cohorts; the jit then runs with the cohort
        SCHEDULED (``sample=False``) against staged-row positions, which
        leaves ``kb``/``kr`` — and therefore every batch draw and client
        rng — unchanged.
        """
        if not isinstance(state.clients, HostStateStore):
            raise ValueError(
                "paged rounds need a paged FedState (clients held in a "
                "HostStateStore): build the sim on a task carrying "
                f"ds.paged_bank(...) BEFORE sim.init; got clients of type "
                f"{type(state.clients).__name__}")
        if sample:
            kc = jax.random.split(rng, 3)[0]
            idx = np.asarray(sample_cohort(kc, self.n, s))
        elif idx is None:
            idx = np.arange(self.n)
        nd = self._n_shards if self.mesh is not None else 1
        cap = round_up(min(s, self.n), nd)
        union, n_live, local = plan_chunk(np.asarray(idx)[None, :], cap)
        staged_bank = bank.gather(union, sharding=self._stage_sh)
        staged_clients = state.clients.gather(union,
                                              sharding=self._stage_sh)
        comm = self._comm_metrics(state, self._banked_batch_struct(bank), s)
        idx_dev = None if s == self.n else jnp.asarray(local[0])
        p, sv, c, metrics = self._banked_jit(
            state.params, state.server, staged_clients, staged_bank, rng,
            idx_dev, s=s, sample=False)
        state.clients.scatter(union[:n_live], c)
        metrics = dict(metrics, **comm)
        return FedState(params=p, server=sv, clients=state.clients,
                        round=state.round + 1), metrics

    def _round_sharded(self, state: FedState, client_batches, rng, idx,
                       weights):
        """One round on the mesh-sharded engine: host-side participant
        bucketing, then shard_map gather/compute/scatter."""
        s = int(idx.size)
        local, pos, w = self._sharded.bucket_participants(
            idx, np.asarray(weights, np.float32), self.n, self._n_shards)
        nb = jax.tree.leaves(client_batches)[0].shape[0]
        if nb == self.n:
            batches, bucketed = client_batches, False
        elif nb == s:
            # pre-gathered [S] participant batches → pre-bucketed rows
            # [n_shards·cap] in shard order (padding clamps to row 0)
            flat_pos = jnp.asarray(pos.reshape(-1))
            batches = jax.tree.map(
                lambda x: jnp.take(x, flat_pos, axis=0), client_batches)
            bucketed = True
        else:
            raise ValueError(
                f"client_batches lead with {nb}; expected N={self.n} "
                f"or S={s} participants")
        return self._sharded_round_jit(
            state.params, state.server, state.clients, batches, rng,
            jnp.asarray(local), jnp.asarray(pos), jnp.asarray(w),
            s=s, bucketed=bucketed)

    # ------------------------------------------------- scanned rounds ------

    def _scan_body(self, s: int, scheduled: bool, bank, round_impl):
        """Shared scan body for both engines: one :meth:`_banked_body`
        round per step.  A scheduled row whose first id is negative marks
        an EMPTY cohort — the round is skipped entirely (lax.cond
        identity), matching ``round()``'s S == 0 short-circuit.
        """
        fn = self._banked_body(round_impl, bank, s=s, sample=not scheduled)

        def body(carry, xs):
            key, cohort = xs if scheduled else (xs, None)

            def live(args):
                p, sv, c, m = fn(key, cohort, *args)
                loss = m.get("client_loss", jnp.float32(jnp.nan)) \
                    if isinstance(m, dict) else jnp.float32(jnp.nan)
                return p, sv, c, jnp.asarray(loss, jnp.float32)

            if scheduled:
                p, sv, c, loss = jax.lax.cond(
                    cohort[0] >= 0, live,
                    lambda args: (*args, jnp.float32(jnp.nan)), carry)
            else:
                p, sv, c, loss = live(carry)
            return (p, sv, c), loss

        return body

    def _scan_chunk(self, round_impl, carry, keys, cohorts, bank, *,
                    s: int, scheduled: bool):
        """Scan ``round_impl`` over one chunk of ``len(keys)`` rounds —
        the engine-agnostic chunk tail shared by both scan jits."""
        body = self._scan_body(s, scheduled, bank, round_impl)
        xs = (keys, cohorts) if scheduled else keys
        (p, sv, c), losses = jax.lax.scan(body, carry, xs)
        return p, sv, c, losses

    def _scan_rounds(self, params, server, clients, keys, cohorts, bank, *,
                     s: int, scheduled: bool):
        """One compiled chunk of ``len(keys)`` rounds on the vmap engine
        (jit cache keys once per (chunk length, S); carry donated)."""
        return self._scan_chunk(self._vmap_round_impl(s),
                                (params, server, clients), keys, cohorts,
                                bank, s=s, scheduled=scheduled)

    def _scan_rounds_sharded(self, params, server, clients, keys, cohorts,
                             bank, *, s: int, scheduled: bool):
        """One compiled chunk on the mesh-sharded engine: lax.scan OUTSIDE
        shard_map, in-graph cohort bucketing (``sharded.bucket_cohort``),
        fixed cohort cap ``min(S, shard_n)`` per chunk so the program
        compiles once per (chunk length, S)."""
        return self._scan_chunk(
            lambda p, sv, c, b, kr, idx, w: self._sharded_round_impl(
                p, sv, c, b, kr, idx, w, s, bank.n_clients),
            (params, server, clients), keys, cohorts, bank, s=s,
            scheduled=scheduled)

    # ---------------------------------------------- buffered-async rounds --

    def _round_async(self, params, server, clients, client_batches, rng,
                     idx, weights, tau, pstack):
        """One buffered-async round on the vmap engine.

        Like the S < N path of :meth:`_round`, except each participant
        trains against the params SNAPSHOT it was dispatched with —
        ``pstack`` [S, ...] rows gathered from the params ring, a MAPPED
        vmap axis where the sync round closes over broadcast params —
        and reports its round-age through ``Participation.staleness``.
        The server update applies to the CURRENT params (FedBuff
        semantics: stale deltas fold into the live model).  Compute
        happens AT FLUSH time, which is equivalent to dispatch-time
        training because a local update is a pure function of its
        dispatch-time inputs and a client is never re-dispatched while
        in flight — round-body purity buys the reordering.

        ``pstack=None`` marks STRUCTURALLY zero staleness (the schedule
        sized the ring at ``window == 1``, so every snapshot gather is
        the identity): the client fn then closes over the live params
        exactly like the sync round.  This is what makes zero-staleness
        async ≡ sync BITWISE on this engine — a mapped params axis
        batches the client matmuls differently (different FMA
        contraction, ~1 ulp), so the identity gather must be elided, not
        just value-equal.
        """
        s = idx.shape[0]
        rngs = jax.random.split(rng, s)
        gathered = jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                                clients)

        if pstack is None:
            def client_fn(cstate, cbatches, crng):
                return self.algo.client(self.task, self.hp, params, cstate,
                                        server, cbatches, crng)

            msgs, updated = jax.vmap(client_fn)(gathered, client_batches,
                                                rngs)
        else:
            def client_fn(cparams, cstate, cbatches, crng):
                return self.algo.client(self.task, self.hp, cparams,
                                        cstate, server, cbatches, crng)

            msgs, updated = jax.vmap(client_fn)(pstack, gathered,
                                                client_batches, rngs)
        # pstack=None proves tau == 0 structurally: report staleness as
        # None (not a zeros array) so damping-aware mixers take their
        # staleness-blind branch and the round graph matches the sync
        # engine op-for-op
        part = Participation(weights=weights, n_total=self.n,
                             staleness=None if pstack is None else tau)
        new_params, new_server = self.algo.server(
            self.task, self.hp, params, server, msgs, part)
        new_clients = jax.tree.map(
            lambda bank, upd: bank.at[idx].set(upd), clients, updated)
        return new_params, new_server, new_clients, round_metrics(msgs,
                                                                  part)

    def _sharded_round_async_impl(self, params, server, clients, batches,
                                  kr, idx, weights, tau, pstack, s: int,
                                  n_rows: int):
        """Async round on the mesh engine: bucket cohort + staleness
        (``bucket_cohort`` extras), pre-bucket batches AND the stale
        params rows into shard order (the ring gather happened outside —
        on replicated arrays), run the async shard_map round."""
        local, pos, w, ltau = self._sharded.bucket_cohort(
            idx, weights, n_rows, self._n_shards, tau)
        flat_pos = pos.reshape(-1)
        take = lambda x: jnp.take(x, flat_pos, axis=0)
        b = jax.tree.map(take, batches)
        ps = (jax.tree.map(
                  lambda x: jnp.broadcast_to(x[None],
                                             (flat_pos.shape[0], *x.shape)),
                  params)
              if pstack is None else jax.tree.map(take, pstack))
        return self._sharded_round_async_fn(
            params, server, clients, b, ps, kr, local, pos, w, ltau, s=s)

    def _banked_body_async(self, round_impl, bank, *, s, window, wpow):
        """Async twin of :meth:`_banked_body`, same key discipline
        (``kc`` is split and discarded — async cohorts are always
        scheduled, exactly like the sync scheduled path), plus the two
        staleness channels: engine-level WEIGHT damping
        ``w_i = (1 + tau_i)^-wpow`` (exactly 1.0 whenever ``tau == 0``
        or ``wpow == 0`` — IEEE pow), and the dispatch-time params
        gathered per participant from the ring at slot
        ``(t - tau) % window``."""
        def fn(key, idx, tau, t, ring, params, server, clients):
            kc, kb, kr = jax.random.split(key, 3)
            del kc
            # (1 + tau)^-wpow is exactly 1.0 whenever tau == 0 or
            # wpow == 0 — but only a COMPILE-TIME constant folds like
            # the sync path's jnp.ones (XLA simplifies constant-weight
            # reductions differently, ~1 ulp), so the wpow == 0 case
            # uses the literal constant
            weights = (jnp.ones((s,), jnp.float32) if wpow == 0.0 else
                       (1.0 + tau.astype(jnp.float32))
                       ** jnp.float32(-wpow))
            batches = bank.sample(kb, idx)
            # window == 1 proves every tau is 0: the ring gather would be
            # the identity, so elide it (pstack=None → the round closes
            # over live params like the sync engine — load-bearing for
            # the zero-staleness bitwise contract)
            pstack = None if window == 1 else jax.tree.map(
                lambda r: jnp.take(r, (t - tau) % window, axis=0), ring)
            return round_impl(params, server, clients, batches, kr, idx,
                              weights, tau, pstack)
        return fn

    def _scan_body_async(self, s, window, wpow, bank, round_impl):
        """Scan body for buffered-async chunks.  The carry grows a
        params RING (``[window, ...]`` per leaf): round ``t`` writes the
        round-START params into slot ``t % window`` BEFORE the skip
        cond — a round that flushes nothing still dispatched clients,
        and they must later train against THESE params.  ``tau <=
        window - 1`` (the schedule sized the ring) guarantees the slot
        read back at flush time still holds round ``t - tau``'s
        snapshot."""
        fn = self._banked_body_async(round_impl, bank, s=s, window=window,
                                     wpow=wpow)

        def body(carry, xs):
            key, cohort, tau, t = xs
            p, sv, c, ring = carry
            if window > 1:
                # never read at window == 1 (the gather is elided), so
                # skip the write too — keeps the zero-staleness scan
                # body free of extra ops around the params leaves
                ring = jax.tree.map(
                    lambda r, x: jax.lax.dynamic_update_index_in_dim(
                        r, x, t % window, 0), ring, p)

            def live(args):
                p0, sv0, c0 = args
                p1, sv1, c1, m = fn(key, cohort, tau, t, ring, p0, sv0,
                                    c0)
                loss = m.get("client_loss", jnp.float32(jnp.nan)) \
                    if isinstance(m, dict) else jnp.float32(jnp.nan)
                return p1, sv1, c1, jnp.asarray(loss, jnp.float32)

            p, sv, c, loss = jax.lax.cond(
                cohort[0] >= 0, live,
                lambda args: (*args, jnp.float32(jnp.nan)), (p, sv, c))
            return (p, sv, c, ring), loss

        return body

    def _scan_chunk_async(self, round_impl, carry, keys, cohorts, stale,
                          ts, bank, *, s: int, window: int, wpow: float):
        body = self._scan_body_async(s, window, wpow, bank, round_impl)
        (p, sv, c, ring), losses = jax.lax.scan(
            body, carry, (keys, cohorts, stale, ts))
        return p, sv, c, ring, losses

    def _scan_rounds_async(self, params, server, clients, ring, keys,
                           cohorts, stale, ts, bank, *, s: int,
                           window: int, wpow: float):
        """One compiled buffered-async chunk on the vmap engine.  ``ts``
        carries ABSOLUTE round numbers so ring slots stay aligned across
        chunk boundaries (the driver threads the ring through)."""
        return self._scan_chunk_async(
            self._round_async, (params, server, clients, ring), keys,
            cohorts, stale, ts, bank, s=s, window=window, wpow=wpow)

    def _scan_rounds_async_sharded(self, params, server, clients, ring,
                                   keys, cohorts, stale, ts, bank, *,
                                   s: int, window: int, wpow: float):
        """Buffered-async chunk on the mesh engine: scan outside
        shard_map, ring replicated (params-sized state is server-side),
        per-round bucketing of cohort + staleness + stale params rows."""
        return self._scan_chunk_async(
            lambda p, sv, c, b, kr, idx, w, tau, ps:
                self._sharded_round_async_impl(
                    p, sv, c, b, kr, idx, w, tau, ps, s, bank.n_clients),
            (params, server, clients, ring), keys, cohorts, stale, ts,
            bank, s=s, window=window, wpow=wpow)

    # ------------------------------------- fault-tolerant (quarantine) -----

    def _aggregate_q(self, params, server, msgs, weights, codes, clip,
                     staleness):
        """Replicated-engine half of the in-graph QUARANTINE (the mesh
        twin is ``sharded._quarantine_local``): inject the schedule's
        fault codes into the encoded messages, decode ONCE, validate
        every decoded leaf (all-finite AND wire-norm ≤ ``clip``),
        SANITIZE rejected slots to zero, and mix with effective weights.

        Sanitizing is load-bearing, not belt-and-braces: ``0 · NaN`` is
        NaN, so a poisoned leaf inside a ``tensordot`` weighted reduction
        survives a zero weight — the rejected slot's values themselves
        must be replaced before any reduction sees them.  Crashed slots
        (sync-engine crash marks; buffered crashes never reach a flush
        row) carry finite untrained messages: they are excluded from the
        mix via ``keep`` but NOT counted in ``n_rejected`` — that counter
        is the in-graph validity verdict, host crash accounting lives in
        ``plan.n_failed``.  An all-rejected round degrades to a
        params-carrying no-op through the ``alive`` select.  With an
        all-zero code row every select here collapses to its identity
        branch — the zero-fault run is the plain engine's mix bit-for-bit
        (the decode+mix composition equals ``algo.server``'s internal
        decode-then-mix).
        """
        msgs = FLT.inject(msgs, codes)
        dec = API.decode_msgs(self.algo, msgs, params)
        valid = FLT.validity(dec, clip)
        keep = valid & (codes != FLT.FAULT_CRASH)
        dec = FLT.sanitize(dec, keep)
        w_eff = jnp.where(keep, weights, jnp.float32(0.0))
        part = Participation(weights=w_eff, n_total=self.n,
                             staleness=staleness)
        cand_p, cand_sv = API.mix_decoded(self.algo, self.task, self.hp,
                                          params, server, dec, part)
        alive = jnp.sum(w_eff) > 0
        new_p = jax.tree.map(lambda a, b: jnp.where(alive, a, b),
                             cand_p, params)
        new_sv = jax.tree.map(lambda a, b: jnp.where(alive, a, b),
                              cand_sv, server)
        n_rej = jnp.sum((~valid) & (weights > 0)).astype(jnp.int32)
        m = round_metrics(dec, part)
        m["alive"] = alive
        m["n_rejected"] = n_rej
        return new_p, new_sv, keep, m

    @staticmethod
    def _restore_rejected(keep, updated, gathered):
        """Rejected/crashed clients keep their pre-round state
        BIT-UNTOUCHED: a client whose report was quarantined must not
        commit the local state its poisoned round produced (a SCAFFOLD
        control variate trained through a fault would drift silently)."""
        s = keep.shape[0]
        return jax.tree.map(
            lambda u, g: jnp.where(
                keep.reshape((s,) + (1,) * (u.ndim - 1)), u, g),
            updated, gathered)

    def _round_q(self, params, server, clients, client_batches, rng, idx,
                 weights, codes, clip):
        """Quarantining twin of the S < N :meth:`_round` path."""
        s = idx.shape[0]
        rngs = jax.random.split(rng, s)
        gathered = jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                                clients)

        def client_fn(cstate, cbatches, crng):
            return self.algo.client(self.task, self.hp, params, cstate,
                                    server, cbatches, crng)

        msgs, updated = jax.vmap(client_fn)(gathered, client_batches, rngs)
        new_p, new_sv, keep, m = self._aggregate_q(
            params, server, msgs, weights, codes, clip, None)
        restored = self._restore_rejected(keep, updated, gathered)
        new_clients = jax.tree.map(
            lambda bank, upd: bank.at[idx].set(upd), clients, restored)
        return new_p, new_sv, new_clients, m

    def _round_async_q(self, params, server, clients, client_batches, rng,
                       idx, weights, tau, pstack, codes, clip):
        """Quarantining twin of :meth:`_round_async` — same pstack
        elision (``pstack=None`` proves zero staleness structurally),
        same quarantine semantics as :meth:`_round_q`."""
        s = idx.shape[0]
        rngs = jax.random.split(rng, s)
        gathered = jax.tree.map(lambda x: jnp.take(x, idx, axis=0),
                                clients)

        if pstack is None:
            def client_fn(cstate, cbatches, crng):
                return self.algo.client(self.task, self.hp, params, cstate,
                                        server, cbatches, crng)

            msgs, updated = jax.vmap(client_fn)(gathered, client_batches,
                                                rngs)
        else:
            def client_fn(cparams, cstate, cbatches, crng):
                return self.algo.client(self.task, self.hp, cparams,
                                        cstate, server, cbatches, crng)

            msgs, updated = jax.vmap(client_fn)(pstack, gathered,
                                                client_batches, rngs)
        new_p, new_sv, keep, m = self._aggregate_q(
            params, server, msgs, weights, codes, clip,
            None if pstack is None else tau)
        restored = self._restore_rejected(keep, updated, gathered)
        new_clients = jax.tree.map(
            lambda bank, upd: bank.at[idx].set(upd), clients, restored)
        return new_p, new_sv, new_clients, m

    def _sharded_round_q_impl(self, params, server, clients, batches, kr,
                              idx, weights, codes, s: int, n_rows: int,
                              clip: float):
        """Sharded quarantine round: bucket cohort + fault codes
        (``bucket_cohort`` extras — padding slots carry code 0 and weight
        0), pre-bucket batches, run the quarantining shard_map round."""
        local, pos, w, lcodes = self._sharded.bucket_cohort(
            idx, weights, n_rows, self._n_shards, codes)
        flat_pos = pos.reshape(-1)
        b = jax.tree.map(lambda x: jnp.take(x, flat_pos, axis=0), batches)
        return self._sharded_round_q_fn(params, server, clients, b, kr,
                                        local, pos, w, lcodes, s=s,
                                        clip=clip)

    def _sharded_round_async_q_impl(self, params, server, clients, batches,
                                    kr, idx, weights, tau, pstack, codes,
                                    s: int, n_rows: int, clip: float):
        """Sharded async quarantine round: staleness AND fault codes ride
        the ``bucket_cohort`` extras channel together."""
        local, pos, w, ltau, lcodes = self._sharded.bucket_cohort(
            idx, weights, n_rows, self._n_shards, tau, codes)
        flat_pos = pos.reshape(-1)
        take = lambda x: jnp.take(x, flat_pos, axis=0)
        b = jax.tree.map(take, batches)
        ps = (jax.tree.map(
                  lambda x: jnp.broadcast_to(x[None],
                                             (flat_pos.shape[0], *x.shape)),
                  params)
              if pstack is None else jax.tree.map(take, pstack))
        return self._sharded_round_async_q_fn(
            params, server, clients, b, ps, kr, local, pos, w, ltau,
            lcodes, s=s, clip=clip)

    def _banked_body_q(self, round_impl, bank, *, s):
        """Quarantine twin of :meth:`_banked_body`.  Fault schedules are
        always SCHEDULED (the fault mask is slot-aligned with explicit
        cohort rows), so ``kc`` is split and discarded exactly like the
        scheduled sync path — batch draws and round rngs stay identical
        to the plain engine's."""
        def fn(key, idx, codes, params, server, clients):
            kc, kb, kr = jax.random.split(key, 3)
            del kc
            weights = jnp.ones((s,), jnp.float32)
            batches = bank.sample(kb, idx)
            return round_impl(params, server, clients, batches, kr, idx,
                              weights, codes)
        return fn

    def _scan_body_q(self, s, bank, round_impl):
        """Scan body for quarantined sync chunks: ys are ``(loss,
        n_rejected)`` per round.  A dead round (all--1 cohort row) skips
        like the plain body and reports 0 rejections; an all-rejected
        LIVE round reports NaN loss (the ``alive`` flag masks the
        carried-forward metric, which aggregates nothing)."""
        fn = self._banked_body_q(round_impl, bank, s=s)

        def body(carry, xs):
            key, cohort, codes = xs

            def live(args):
                p, sv, c, m = fn(key, cohort, codes, *args)
                loss = jnp.where(
                    m["alive"],
                    jnp.asarray(m.get("client_loss", jnp.float32(jnp.nan)),
                                jnp.float32),
                    jnp.float32(jnp.nan))
                return p, sv, c, loss, m["n_rejected"]

            p, sv, c, loss, nrej = jax.lax.cond(
                cohort[0] >= 0, live,
                lambda args: (*args, jnp.float32(jnp.nan), jnp.int32(0)),
                carry)
            return (p, sv, c), (loss, nrej)

        return body

    def _scan_rounds_q(self, params, server, clients, keys, cohorts,
                       faults, bank, *, s: int, clip: float):
        """One compiled quarantined chunk on the vmap engine (``clip`` is
        static — one program per (chunk, S, clip))."""
        body = self._scan_body_q(
            s, bank,
            lambda p, sv, c, b, kr, idx, w, codes: self._round_q(
                p, sv, c, b, kr, idx, w, codes, clip))
        (p, sv, c), (losses, nrej) = jax.lax.scan(
            body, (params, server, clients), (keys, cohorts, faults))
        return p, sv, c, losses, nrej

    def _scan_rounds_sharded_q(self, params, server, clients, keys,
                               cohorts, faults, bank, *, s: int,
                               clip: float):
        """Quarantined chunk on the mesh engine."""
        body = self._scan_body_q(
            s, bank,
            lambda p, sv, c, b, kr, idx, w, codes:
                self._sharded_round_q_impl(p, sv, c, b, kr, idx, w, codes,
                                           s, bank.n_clients, clip))
        (p, sv, c), (losses, nrej) = jax.lax.scan(
            body, (params, server, clients), (keys, cohorts, faults))
        return p, sv, c, losses, nrej

    def _banked_body_async_q(self, round_impl, bank, *, s, window, wpow):
        """Quarantine twin of :meth:`_banked_body_async` — identical key
        discipline, staleness weights, and ring-gather elision."""
        def fn(key, idx, tau, t, codes, ring, params, server, clients):
            kc, kb, kr = jax.random.split(key, 3)
            del kc
            weights = (jnp.ones((s,), jnp.float32) if wpow == 0.0 else
                       (1.0 + tau.astype(jnp.float32))
                       ** jnp.float32(-wpow))
            batches = bank.sample(kb, idx)
            pstack = None if window == 1 else jax.tree.map(
                lambda r: jnp.take(r, (t - tau) % window, axis=0), ring)
            return round_impl(params, server, clients, batches, kr, idx,
                              weights, tau, pstack, codes)
        return fn

    def _scan_body_async_q(self, s, window, wpow, bank, round_impl):
        """Quarantined buffered-async scan body: the ring write stays
        BEFORE the skip cond (a flushless round still dispatched
        clients), ys are ``(loss, n_rejected)``."""
        fn = self._banked_body_async_q(round_impl, bank, s=s,
                                       window=window, wpow=wpow)

        def body(carry, xs):
            key, cohort, tau, t, codes = xs
            p, sv, c, ring = carry
            if window > 1:
                ring = jax.tree.map(
                    lambda r, x: jax.lax.dynamic_update_index_in_dim(
                        r, x, t % window, 0), ring, p)

            def live(args):
                p0, sv0, c0 = args
                p1, sv1, c1, m = fn(key, cohort, tau, t, codes, ring, p0,
                                    sv0, c0)
                loss = jnp.where(
                    m["alive"],
                    jnp.asarray(m.get("client_loss", jnp.float32(jnp.nan)),
                                jnp.float32),
                    jnp.float32(jnp.nan))
                return p1, sv1, c1, loss, m["n_rejected"]

            p, sv, c, loss, nrej = jax.lax.cond(
                cohort[0] >= 0, live,
                lambda args: (*args, jnp.float32(jnp.nan), jnp.int32(0)),
                (p, sv, c))
            return (p, sv, c, ring), (loss, nrej)

        return body

    def _scan_rounds_async_q(self, params, server, clients, ring, keys,
                             cohorts, stale, ts, faults, bank, *, s: int,
                             window: int, wpow: float, clip: float):
        """Quarantined buffered-async chunk on the vmap engine."""
        body = self._scan_body_async_q(
            s, window, wpow, bank,
            lambda p, sv, c, b, kr, idx, w, tau, ps, codes:
                self._round_async_q(p, sv, c, b, kr, idx, w, tau, ps,
                                    codes, clip))
        (p, sv, c, ring), (losses, nrej) = jax.lax.scan(
            body, (params, server, clients, ring),
            (keys, cohorts, stale, ts, faults))
        return p, sv, c, ring, losses, nrej

    def _scan_rounds_async_sharded_q(self, params, server, clients, ring,
                                     keys, cohorts, stale, ts, faults,
                                     bank, *, s: int, window: int,
                                     wpow: float, clip: float):
        """Quarantined buffered-async chunk on the mesh engine."""
        body = self._scan_body_async_q(
            s, window, wpow, bank,
            lambda p, sv, c, b, kr, idx, w, tau, ps, codes:
                self._sharded_round_async_q_impl(
                    p, sv, c, b, kr, idx, w, tau, ps, codes, s,
                    bank.n_clients, clip))
        (p, sv, c, ring), (losses, nrej) = jax.lax.scan(
            body, (params, server, clients, ring),
            (keys, cohorts, stale, ts, faults))
        return p, sv, c, ring, losses, nrej

    def run_scanned(self, rng, rounds: int, *, sample_clients: int = 0,
                    eval_fn=None, eval_every: int = 1, cohorts=None):
        """Scan-compiled multi-round driver: chunks of ``eval_every``
        rounds compile into ONE ``lax.scan`` program — one dispatch per
        chunk instead of one per round, no host round-trips between evals.

        Requires a task with a resident data bank
        (``task.with_data(ds.device_bank(...))``): batches are drawn
        in-graph by ``task.sample_batches``.  Cohorts are drawn in-graph
        by :func:`sample_cohort` when ``sample_clients`` ∈ (0, N), or
        supplied as ``cohorts`` — a host int array [rounds, S] of sorted
        unique ids per row (a row of all -1 is an empty cohort: that
        round is skipped, matching ``round()``'s short-circuit), e.g.
        pre-drawn by a seeded numpy oracle — or any
        :class:`repro.fl.schedule.CohortSchedule` (seeded generators,
        availability traces, :class:`~repro.fl.schedule.
        BufferedSchedule`).  Everything resolves through
        :func:`repro.fl.schedule.resolve`, which owns the shape /
        dead-row / sortedness validation; the raw-array path is
        bit-for-bit what it always was.

        A schedule that carries STALENESS (``BufferedSchedule``) routes
        to the buffered-async engine: same chunked ``lax.scan``, same
        donation discipline, plus a donated params RING of
        ``max(staleness)+1`` snapshots so each flushed report trains
        against its dispatch-time params; aggregation weights damp as
        ``(1+tau)^-weight_pow`` and mixers with a declared ``damping``
        hook see ``Participation.staleness``.  At zero staleness
        (``BufferedSchedule(delay=0, concurrency=goal)``) this
        reproduces the synchronous engine BITWISE on the vmap engine
        (fp32 mixing tolerance on the mesh engine) — the contract
        tests/test_async.py enforces.

        params/server/clients are donated through each chunk (the client
        bank updates in place); per-chunk boundaries run ``eval_fn`` on
        the host.  Returns ``(final_state, history)`` like ``run`` —
        evals land at chunk ends (rounds eval_every-1, 2·eval_every-1,
        ..., rounds-1) rather than ``run``'s chunk starts.

        Contract: at a fixed ``rng``, this matches the per-round banked
        ``round()`` oracle bit-for-bit on both engines
        (tests/test_scan.py)::

            k_init, keys = round_keys(rng, rounds)
            state = sim.init(k_init)
            for t in range(rounds):
                state, _ = sim.round(state, None, keys[t],
                                     sample_clients=S)   # or participants=

        With a PAGED bank (``task.with_data(ds.paged_bank(...))``) the
        same key stream drives the OUT-OF-CORE driver: cohorts are drawn
        host-side from the identical ``kc`` keys, each chunk stages only
        the union of its cohorts' client rows (state + data) to device,
        and the same scanned programs run over the staged views — device
        memory is bounded by ``min(eval_every · S, N)`` rows while the
        population stays host-side.  Matches the resident run to fp32
        tolerance (the staged program is shape-smaller, so XLA fusion may
        differ by ~1 ulp; every cohort, batch draw, and client rng is
        identical by construction).
        """
        bank = getattr(self.task, "data", None)
        if bank is None:
            raise ValueError(
                "run_scanned needs a data bank — resident data bank "
                "task.with_data(ds.device_bank(steps, batch)) or paged "
                "task.with_data(ds.paged_bank(steps, batch))")
        if eval_every < 1:
            raise ValueError(f"eval_every must be >= 1 (one chunk per "
                             f"eval); got {eval_every} — for no evals, "
                             f"pass eval_every=rounds and eval_fn=None")
        plan = SCH.resolve(cohorts, rounds=rounds, n=self.n,
                           sample_clients=sample_clients)
        k_init, keys = round_keys(rng, rounds)
        state = self.init(k_init)
        if self._paged:
            return self._run_scanned_paged(state, keys, rounds, bank, plan,
                                           eval_fn, eval_every)
        if plan.is_async:
            if plan.has_faults:
                return self._run_scanned_async_q(state, keys, rounds, bank,
                                                 plan, eval_fn, eval_every)
            return self._run_scanned_async(state, keys, rounds, bank, plan,
                                           eval_fn, eval_every)
        if plan.has_faults:
            return self._run_scanned_q(state, keys, rounds, bank, plan,
                                       eval_fn, eval_every)
        s, scheduled = plan.s, plan.scheduled
        scan = (self._scan_sharded_jit if self.mesh is not None
                else self._scan_jit)
        hist = {"round": [], "metric": [], "loss": []}
        t = 0
        while t < rounds:
            chunk = min(eval_every, rounds - t)
            co = (jnp.asarray(plan.cohorts[t:t + chunk]) if scheduled
                  else None)
            p, sv, c, losses = scan(state.params, state.server,
                                    state.clients, keys[t:t + chunk], co,
                                    bank, s=s, scheduled=scheduled)
            t += chunk
            state = FedState(params=p, server=sv, clients=c, round=t)
            if eval_fn is not None:
                hist["round"].append(t - 1)
                hist["metric"].append(float(eval_fn(state.params)))
                hist["loss"].append(float(losses[-1]))
        return state, hist

    def _make_ring(self, params, window: int):
        """The params ring: ``window`` snapshot slots per leaf,
        initialized by repeating the starting params (never read before
        round ``t`` writes slot ``t % window`` — staleness <= t is
        validated by the schedule).  Replicated on the mesh engine."""
        ring = jax.tree.map(lambda x: jnp.repeat(x[None], window, axis=0),
                            params)
        if self.mesh is not None:
            ring = self._sharded.replicate(self.mesh, ring)
        return ring

    def _run_scanned_async(self, state: FedState, keys, rounds: int, bank,
                           plan, eval_fn, eval_every: int):
        """Resident buffered-async driver: the sync chunk loop plus a
        params ring threaded (donated) through the chunks, absolute
        round numbers riding along so ring slots stay aligned."""
        ring = self._make_ring(state.params, plan.window)
        hist = {"round": [], "metric": [], "loss": []}
        t = 0
        while t < rounds:
            chunk = min(eval_every, rounds - t)
            p, sv, c, ring, losses = self._scan_async_jit(
                state.params, state.server, state.clients, ring,
                keys[t:t + chunk], jnp.asarray(plan.cohorts[t:t + chunk]),
                jnp.asarray(plan.staleness[t:t + chunk]),
                jnp.arange(t, t + chunk, dtype=jnp.int32), bank,
                s=plan.s, window=plan.window, wpow=plan.weight_pow)
            t += chunk
            state = FedState(params=p, server=sv, clients=c, round=t)
            if eval_fn is not None:
                hist["round"].append(t - 1)
                hist["metric"].append(float(eval_fn(state.params)))
                hist["loss"].append(float(losses[-1]))
        return state, hist

    def _fault_hist(self, plan, rounds: int) -> dict:
        """History skeleton for fault-tolerant runs: the host-side event
        counters land whole (they were resolved before round 0), the
        in-graph ``n_rejected`` stream is appended per chunk."""
        z = np.zeros(rounds, np.int32)
        return {"round": [], "metric": [], "loss": [],
                "n_failed": (np.asarray(plan.n_failed)
                             if plan.n_failed is not None else z),
                "n_retried": (np.asarray(plan.n_retried)
                              if plan.n_retried is not None else z.copy())}

    def _run_scanned_q(self, state: FedState, keys, rounds: int, bank,
                       plan, eval_fn, eval_every: int):
        """Resident sync driver for FAULT schedules: the plain chunk loop
        dispatching the quarantined jit, fault-code rows riding along and
        the per-round ``n_rejected`` stream collected into the history
        next to the host-side ``n_failed``/``n_retried`` counters."""
        hist = self._fault_hist(plan, rounds)
        nrej_chunks = []
        t = 0
        while t < rounds:
            chunk = min(eval_every, rounds - t)
            p, sv, c, losses, nrej = self._scan_q_jit(
                state.params, state.server, state.clients,
                keys[t:t + chunk], jnp.asarray(plan.cohorts[t:t + chunk]),
                jnp.asarray(plan.faults[t:t + chunk]), bank,
                s=plan.s, clip=plan.norm_clip)
            nrej_chunks.append(np.asarray(nrej))
            t += chunk
            state = FedState(params=p, server=sv, clients=c, round=t)
            if eval_fn is not None:
                hist["round"].append(t - 1)
                hist["metric"].append(float(eval_fn(state.params)))
                hist["loss"].append(float(losses[-1]))
        hist["n_rejected"] = np.concatenate(nrej_chunks)
        return state, hist

    def _run_scanned_async_q(self, state: FedState, keys, rounds: int,
                             bank, plan, eval_fn, eval_every: int):
        """Resident buffered-async driver for FAULT schedules — the
        async chunk loop plus fault-code rows and the counter stream."""
        ring = self._make_ring(state.params, plan.window)
        hist = self._fault_hist(plan, rounds)
        nrej_chunks = []
        t = 0
        while t < rounds:
            chunk = min(eval_every, rounds - t)
            p, sv, c, ring, losses, nrej = self._scan_async_q_jit(
                state.params, state.server, state.clients, ring,
                keys[t:t + chunk], jnp.asarray(plan.cohorts[t:t + chunk]),
                jnp.asarray(plan.staleness[t:t + chunk]),
                jnp.arange(t, t + chunk, dtype=jnp.int32),
                jnp.asarray(plan.faults[t:t + chunk]), bank,
                s=plan.s, window=plan.window, wpow=plan.weight_pow,
                clip=plan.norm_clip)
            nrej_chunks.append(np.asarray(nrej))
            t += chunk
            state = FedState(params=p, server=sv, clients=c, round=t)
            if eval_fn is not None:
                hist["round"].append(t - 1)
                hist["metric"].append(float(eval_fn(state.params)))
                hist["loss"].append(float(losses[-1]))
        hist["n_rejected"] = np.concatenate(nrej_chunks)
        return state, hist

    def _run_scanned_paged(self, state: FedState, keys, rounds: int, bank,
                           plan, eval_fn, eval_every: int):
        """The out-of-core half of :meth:`run_scanned`.

        Host side per chunk: plan the union of the chunk's cohorts padded
        to the STATIC capacity ``cap = min(eval_every · S, N)`` rounded to
        the shard count (one compiled program per (chunk, S) — never per
        random cohort; pad slots repeat the last live id, dead rows no
        cohort references and no scatter writes), stage the union's data
        and state rows, run the chunk's scan SCHEDULED over the remapped
        cohort positions, scatter the live rows back.  Both copy
        directions overlap the next chunk's compute: the next chunk's
        data and state rows prefetch (async ``device_put``; state
        read-ahead skips rows the write-behind still has in flight), and
        with ``scatter_overlap`` the state write-back itself retires on
        the store's drain thread (``scatter_async``), fenced before any
        re-gather of in-flight rows — so paged ≡ resident is unchanged
        by the overlap.  ``scatter_overlap=False`` (or a store without
        ``scatter_async``) keeps the synchronous scatter.

        Buffered-async plans compose with paging unchanged: a chunk's
        union is simply the union of its FLUSH rows (``plan_chunk``
        dedupes overlapping cohorts via ``np.unique``), the remapped
        local rows keep their -1 markers, staleness needs no remapping
        (it is per-report, not per-row-id), and the params ring is
        server-side state — untouched by client paging.
        """
        s, cohorts = plan.s, plan.cohorts
        if cohorts is None:
            if s == self.n:
                # full participation: every round's cohort is [0, N)
                cohorts = np.broadcast_to(
                    np.arange(self.n, dtype=np.int32), (rounds, self.n))
            else:
                cohorts = np.asarray(_draw_cohorts(keys, self.n, s))
        store = state.clients
        nd = self._n_shards if self.mesh is not None else 1
        cap = round_up(min(eval_every * s, self.n), nd)
        plans, t = [], 0
        while t < rounds:
            chunk = min(eval_every, rounds - t)
            plans.append((chunk, *plan_chunk(cohorts[t:t + chunk], cap)))
            t += chunk
        scan = (self._scan_sharded_jit if self.mesh is not None
                else self._scan_jit)
        ring = (self._make_ring(state.params, plan.window)
                if plan.is_async else None)
        sh = self._stage_sh
        # fault plans compose with paging like staleness does: the fault
        # mask is slot-aligned with the cohort rows, so the remapped local
        # positions need no code remapping — the codes ride along verbatim
        hist = (self._fault_hist(plan, rounds) if plan.has_faults
                else {"round": [], "metric": [], "loss": []})
        nrej_chunks = []
        overlap = self.scatter_overlap and hasattr(store, "scatter_async")
        bank.prefetch(plans[0][1], sharding=sh)
        t = 0
        for i, (chunk, union, n_live, local) in enumerate(plans):
            staged_bank = bank.gather(union, sharding=sh)
            staged_clients = store.gather(union, sharding=sh)
            if plan.is_async and plan.has_faults:
                p, sv, c, ring, losses, nrej = self._scan_async_q_jit(
                    state.params, state.server, staged_clients, ring,
                    keys[t:t + chunk], jnp.asarray(local),
                    jnp.asarray(plan.staleness[t:t + chunk]),
                    jnp.arange(t, t + chunk, dtype=jnp.int32),
                    jnp.asarray(plan.faults[t:t + chunk]),
                    staged_bank, s=s, window=plan.window,
                    wpow=plan.weight_pow, clip=plan.norm_clip)
                nrej_chunks.append(np.asarray(nrej))
            elif plan.is_async:
                p, sv, c, ring, losses = self._scan_async_jit(
                    state.params, state.server, staged_clients, ring,
                    keys[t:t + chunk], jnp.asarray(local),
                    jnp.asarray(plan.staleness[t:t + chunk]),
                    jnp.arange(t, t + chunk, dtype=jnp.int32),
                    staged_bank, s=s, window=plan.window,
                    wpow=plan.weight_pow)
            elif plan.has_faults:
                p, sv, c, losses, nrej = self._scan_q_jit(
                    state.params, state.server, staged_clients,
                    keys[t:t + chunk], jnp.asarray(local),
                    jnp.asarray(plan.faults[t:t + chunk]), staged_bank,
                    s=s, clip=plan.norm_clip)
                nrej_chunks.append(np.asarray(nrej))
            else:
                p, sv, c, losses = scan(state.params, state.server,
                                        staged_clients, keys[t:t + chunk],
                                        jnp.asarray(local), staged_bank,
                                        s=s, scheduled=True)
            if i + 1 < len(plans):
                # dispatch the NEXT chunk's data staging before blocking
                # on this chunk's write-back: the copy rides under compute
                bank.prefetch(plans[i + 1][1], sharding=sh)
            if overlap:
                # write-behind: the drain thread retires this chunk's
                # state rows under the next chunk's compute; the store
                # fences any re-gather/prefetch of in-flight rows
                store.scatter_async(union[:n_live], c)
            else:
                store.scatter(union[:n_live], c)
            if i + 1 < len(plans):
                # read-ahead the next chunk's STATE rows too (skipped
                # internally for rows the write-behind still has in
                # flight — the stale-read hazard rule)
                store.prefetch(plans[i + 1][1], sharding=sh)
            t += chunk
            state = FedState(params=p, server=sv, clients=store, round=t)
            if eval_fn is not None:
                hist["round"].append(t - 1)
                hist["metric"].append(float(eval_fn(state.params)))
                hist["loss"].append(float(losses[-1]))
        if overlap:
            store.fence()       # retire the last chunk's write-back
        if plan.has_faults:
            hist["n_rejected"] = np.concatenate(nrej_chunks)
        return state, hist

    # ------------------------------------------------------------ loop -----

    def run(self, rng, batch_fn, rounds: int, *, sample_clients: int = 0,
            eval_fn=None, eval_every: int = 1, seed: int = 0):
        """batch_fn(round, rng) -> client_batches [N, K, ...], or
        batch_fn(round, rng, participants) -> [S, K, ...] to build batches
        for the sampled cohort only (the data path that scales with S).

        ``sample_clients`` > 0 enables per-round uniform client sampling.
        Returns (final_state, history dict of lists).
        """
        state = self.init(rng)
        hist = {"round": [], "metric": [], "loss": []}
        np_rng = np.random.default_rng(seed)
        takes_participants = _batch_fn_takes_participants(batch_fn)
        for t in range(rounds):
            rng, kb, kr = jax.random.split(rng, 3)
            if sample_clients and sample_clients < self.n:
                chosen = np.sort(np_rng.choice(self.n, size=sample_clients,
                                               replace=False))
            else:
                chosen = np.arange(self.n)
            batches = (batch_fn(t, kb, chosen) if takes_participants
                       else batch_fn(t, kb))
            state, metrics = self.round(state, batches, kr,
                                        participants=chosen)
            if eval_fn is not None and (t % eval_every == 0 or t == rounds - 1):
                hist["round"].append(t)
                hist["metric"].append(float(eval_fn(state.params)))
                hist["loss"].append(float(metrics.get("client_loss", jnp.nan)))
        return state, hist

"""Fault injection + in-graph quarantine for federated rounds.

A production FL fleet sees three failure families every round, and a
second-order method is MORE exposed to each than a first-order one — a
poisoned gram corrupts the shared preconditioner for every client:

* **crashes** — a dispatched client never reports.  In the buffered-async
  event process this is a dispatch whose report time is "never"; the
  ``BufferedSchedule`` timeout declares it dead after ``timeout`` rounds,
  frees its concurrency slot and re-dispatches the client (bounded by
  ``max_retries``).  In a synchronous schedule a crash is a cohort slot
  whose report silently drops (weight zeroed in-graph).
* **stragglers** — heavy-tail completion delays.  Modeled as extra
  dispatch-to-report rounds on top of the schedule's own delay; an
  extreme straggler simply times out and becomes a crash.
* **corrupted reports** — NaN/inf message leaves or exploding update
  norms.  These ARE delivered; the engines' quarantine (a per-report
  validity mask computed AFTER wire decode) zeroes the rejected report's
  ``Participation`` weight, sanitizes its message leaves so ``0 * NaN``
  cannot reach any reduction, restores the client's state bit-untouched,
  and lets an all-rejected round degrade to a params-carrying no-op.

:class:`FaultModel` composes with any :class:`~repro.fl.schedule.
CohortSchedule` and resolves the whole fault story HOST-side into a
deterministic per-report fault-code array (one int8 per cohort slot)
riding the :class:`~repro.fl.schedule.BuiltSchedule` — the scanned
engines consume it as just another ``lax.scan`` input, exactly like
cohorts and staleness.  The fault rng stream is separate from the
schedule's, so a zero-fault ``FaultModel`` replays the inner schedule's
arrays bit-identically (and the quarantined engine it routes to is
contract-equal to the plain engine — the ``fault_overhead`` gate's
numerator).

The pure-jax half (:func:`inject` / :func:`validity` / :func:`sanitize`)
is shared by the vmap and mesh-sharded round bodies; injection happens
on the ENCODED stacked messages (corruption-on-the-wire), detection on
the DECODED messages — so quarantine provably catches poison that
survives bf16 casts, top-k sparsification and gram sketching
(tests/test_faults.py pins this for all three transforms).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api as API
from repro.fl import schedule as SCH

__all__ = ["FAULT_OK", "FAULT_CRASH", "FAULT_NAN", "FAULT_EXPLODE",
           "FaultModel", "inject", "validity", "sanitize",
           "expected_rejections"]

#: report arrived clean
FAULT_OK = 0
#: dispatched but never reports (host-side event; sync schedules only —
#: a buffered crash never flushes, so code 1 never reaches a cohort row)
FAULT_CRASH = 1
#: report leaves poisoned with NaN
FAULT_NAN = 2
#: report magnitude exploded past any sane clip threshold
FAULT_EXPLODE = 3


# ------------------------------------------------------------ host side ----

@dataclass(frozen=True)
class FaultModel(SCH.CohortSchedule):
    """A seeded fault process over an inner :class:`~repro.fl.schedule.
    CohortSchedule`.

    ``crash``/``straggle``/``corrupt`` are per-dispatch (buffered inner)
    or per-report (sync inner) probabilities, drawn from
    ``default_rng(seed)`` — a stream SEPARATE from the inner schedule's,
    so the dispatch choices and delays are bit-identical with the fault
    model on or off.  ``tail`` caps the heavy-tail (Pareto) straggler
    delay in rounds; ``norm_clip`` is the quarantine's update-norm bound
    (it must be finite for exploded-but-representable reports to be
    caught — the finiteness check alone misses a finite 1e30 report).

    Composition rules:

    * buffered inner + ``crash > 0`` requires ``timeout > 0`` on the
      inner schedule — a crashed dispatch with no timeout leaks its
      concurrency slot forever (the pre-PR-9 ROADMAP leak, now an error
      instead of a hang);
    * ``straggle > 0`` requires a buffered inner — a synchronous
      schedule has no dispatch-to-report time axis to stretch;
    * corrupted reports mark their flush slot with a fault code; the
      engines inject the corruption IN-GRAPH at the wire boundary and
      quarantine it after decode, so the host array is both the
      injection plan and the exact expected-rejection log
      (:func:`expected_rejections`).
    """
    inner: SCH.CohortSchedule
    crash: float = 0.0
    straggle: float = 0.0
    tail: int = 16
    corrupt: float = 0.0
    norm_clip: float = 1e6
    seed: int = 0

    @property
    def weight_pow(self) -> float:   # staleness damping is the inner's
        return float(getattr(self.inner, "weight_pow", 0.0) or 0.0)

    def _validate(self):
        for name in ("crash", "straggle", "corrupt"):
            p = float(getattr(self, name))
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be a probability in "
                                 f"[0, 1]; got {p}")
        if not self.norm_clip > 0:
            raise ValueError(f"norm_clip must be > 0 (finite for "
                             f"exploding-report detection); got "
                             f"{self.norm_clip}")
        if self.tail < 1:
            raise ValueError(f"tail must be >= 1 rounds; got {self.tail}")

    def _sample_code(self, frng) -> int:
        if self.corrupt and frng.random() < self.corrupt:
            return FAULT_NAN if frng.random() < 0.5 else FAULT_EXPLODE
        return FAULT_OK

    def build(self, n: int, rounds: int):
        self._validate()
        if isinstance(self.inner, SCH.BufferedSchedule):
            return self._build_buffered(n, rounds)
        return self._build_sync(n, rounds)

    def _build_buffered(self, n: int, rounds: int) -> SCH.BuiltSchedule:
        inner = self.inner
        if self.crash and inner.timeout == 0:
            raise ValueError(
                "crash > 0 on a BufferedSchedule with timeout=0: a "
                "crashed dispatch never reports and would leak its "
                "concurrency slot forever. Set timeout (and optionally "
                "max_retries) on the inner schedule.")
        lo, hi = inner._validate(n)
        frng = np.random.default_rng(self.seed)

        def sampler(c: int, t: int):
            crashed = bool(self.crash) and frng.random() < self.crash
            extra = 0
            if self.straggle and frng.random() < self.straggle:
                # heavy-tail straggler: Pareto delay, capped at `tail`
                # (an uncapped tail would blow the params-ring window;
                # with a timeout the cap is mostly moot — extreme
                # stragglers die and re-dispatch)
                extra = min(1 + int(frng.pareto(1.5)), self.tail)
            return crashed, extra, self._sample_code(frng)

        return SCH.buffered_events(
            n, rounds, goal=inner.goal, concurrency=inner.concurrency,
            lo=lo, hi=hi, rng=np.random.default_rng(inner.seed),
            timeout=inner.timeout, max_retries=inner.max_retries,
            fault_sampler=sampler)

    def _build_sync(self, n: int, rounds: int) -> SCH.BuiltSchedule:
        if self.straggle:
            raise ValueError(
                "straggle > 0 needs a BufferedSchedule inner — a "
                "synchronous schedule has no dispatch-to-report delay "
                "to stretch (model stragglers as buffered-async "
                "staleness + timeouts).")
        built = self.inner.build(n, rounds)
        if isinstance(built, SCH.BuiltSchedule):
            rows, taus = built.cohorts, built.staleness
        elif isinstance(built, tuple):
            rows, taus = built
        else:
            rows, taus = built, None
        rows = np.asarray(rows, np.int32)
        marks = np.zeros(rows.shape, np.int8)
        n_failed = np.zeros(rows.shape[0], np.int32)
        frng = np.random.default_rng(self.seed)
        for t in range(rows.shape[0]):
            if rows[t, 0] < 0:
                continue                     # dead round: nothing to mark
            for j in range(rows.shape[1]):
                if self.crash and frng.random() < self.crash:
                    # sync "crash": the report silently drops — the
                    # engine zeroes its weight; counted host-side
                    marks[t, j] = FAULT_CRASH
                    n_failed[t] += 1
                else:
                    marks[t, j] = self._sample_code(frng)
        return SCH.BuiltSchedule(
            cohorts=rows, staleness=taus, faults=marks,
            n_failed=n_failed,
            n_retried=np.zeros(rows.shape[0], np.int32))


def expected_rejections(faults: np.ndarray) -> np.ndarray:
    """The host-side expected per-round ``n_rejected`` for a fault array:
    corrupted marks (NAN/EXPLODE) are the reports the in-graph
    quarantine must catch — crashes are dropped by weight, not detected
    by validity, so they count under ``n_failed`` instead.  The
    acceptance contract is ``hist["n_rejected"] == expected_rejections(
    plan.faults)`` exactly (absent organic NaNs in the task itself)."""
    f = np.asarray(faults)
    return ((f == FAULT_NAN) | (f == FAULT_EXPLODE)).sum(
        axis=1).astype(np.int32)


# ------------------------------------------------------------- jax side ----

def _per_slot(codes: jax.Array, x: jax.Array) -> jax.Array:
    """Broadcast per-report codes [S] against a stacked leaf [S, ...]."""
    return codes.reshape(codes.shape + (1,) * (x.ndim - 1))


def inject(msgs, codes: jax.Array):
    """Corrupt the stacked (ENCODED) client messages per fault code.

    ``FAULT_NAN`` fills every inexact leaf with NaN; ``FAULT_EXPLODE``
    maps ``x -> x * 1e30 + 1e30`` so even an all-zero leaf lands at
    magnitude >= 1e30 — detection (and therefore the
    counter-exactness contract) cannot depend on the report's value.
    Code 0 slots pass through BIT-UNTOUCHED (``where`` with a false
    predicate selects the original lane exactly), which is what makes
    the zero-fault quarantined engine contract-equal to the plain one.
    Integer leaves (top-k indices) are never touched.
    """
    def leaf(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x
        c = _per_slot(codes, x)
        x = jnp.where(c == FAULT_NAN, jnp.asarray(jnp.nan, x.dtype), x)
        return jnp.where(c == FAULT_EXPLODE, x * 1e30 + 1e30, x)
    return jax.tree.map(leaf, msgs)


def _wire_part(msgs):
    """The wire payload of a stacked message (what the norm bound
    covers); metrics fields ride outside the wire."""
    if isinstance(msgs, API.Message):
        return msgs.wire_tree()
    if isinstance(msgs, dict):
        return {k: v for k, v in msgs.items() if k != "loss"}
    return msgs


def validity(msgs, norm_clip: float) -> jax.Array:
    """Per-report validity [S] of the stacked DECODED messages:
    every inexact leaf finite AND the wire payload's L2 norm within
    ``norm_clip``.

    The norm accumulates squares in fp32, so an exploded report
    overflows to inf and ``inf <= clip**2`` is False — and a NaN norm
    compares False too: poison can only ever FAIL the check.  Runs after
    wire decode by design (satellite contract): a NaN that rode through
    a bf16 cast, a top-k scatter or a gram-sketch reconstruction is
    caught HERE, not assumed away at encode time.
    """
    leaves = [x for x in jax.tree.leaves(msgs)
              if jnp.issubdtype(x.dtype, jnp.inexact)]
    if not leaves:
        return jnp.ones((), bool)
    finite = None
    for x in leaves:
        f = jnp.all(jnp.isfinite(x), axis=tuple(range(1, x.ndim)))
        finite = f if finite is None else finite & f
    nsq = None
    for x in jax.tree.leaves(_wire_part(msgs)):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            continue
        xf = x.astype(jnp.float32)
        s = jnp.sum(xf * xf, axis=tuple(range(1, x.ndim)))
        nsq = s if nsq is None else nsq + s
    ok_norm = (jnp.ones_like(finite) if nsq is None
               else nsq <= jnp.float32(norm_clip) ** 2)
    return finite & ok_norm


def sanitize(msgs, valid: jax.Array):
    """Zero every inexact leaf of rejected reports.

    Weight-zeroing alone is NOT enough: ``0 * NaN == NaN`` inside the
    ``tensordot``/matmul reductions every mixer runs, so a single
    poisoned report would still NaN the aggregate (and the loss metric).
    ``where`` on a true predicate returns the original lane exactly —
    valid reports are bit-untouched.
    """
    def leaf(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x
        return jnp.where(_per_slot(valid, x), x,
                         jnp.zeros((), x.dtype))
    return jax.tree.map(leaf, msgs)

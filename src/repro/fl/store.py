"""The ClientStore seam: where per-client rows live, and how cohorts move.

Every engine layer used to assume the stacked ``[N, ...]`` client bank
(state rows and data shards alike) is RESIDENT on device.  That caps the
simulable population at whatever one accelerator holds — second-order
clients carry heavy state (SCAFFOLD control variates, gram banks), so
N ≥ 10⁵ stateful clients cannot be device-resident at any useful model
size.  This module names the seam instead of the assumption:

ClientStore protocol
--------------------
A *client store* owns per-client rows (a pytree of ``[N, ...]`` leaves,
or an indexable host dataset) and exposes exactly three operations::

    gather(rows, sharding=None)  -> device rows   [len(rows), ...]
    scatter(rows, staged)        -> None          (write device rows back)
    prefetch(rows, sharding=None)-> None          (per-chunk staging hint)

plus ``n_clients`` and the static flag ``is_resident``.  Two residency
classes implement it:

* **resident** — the store's rows already live on device as one stacked
  bank.  ``gather``/``scatter`` are identities the ENGINE performs
  in-graph (``jnp.take`` / ``.at[idx].set`` inside the round jit), so the
  resident store preserves the scanned driver's donation aliasing and
  bit-for-bit contract exactly — it *is* today's behavior, renamed.
  :class:`repro.data.federated.DeviceDataBank` is the resident data
  store; the resident client-state store is the donated ``[N, ...]``
  pytree carried in ``FedState.clients``.
* **paged** — cold rows stay in host memory (numpy; pinned host buffers
  on accelerator backends ride the same ``device_put`` path), and only
  the HOT rows a chunk of rounds actually touches are staged to device.
  :class:`repro.data.federated.HostPagedBank` pages the federated data;
  :class:`HostStateStore` (here) pages the client-state bank.  Paging
  happens ONLY at chunk boundaries, outside the scanned graph — the
  round body stays pure and the per-chunk program is the same
  ``lax.scan`` the resident path compiles, just over a ``[U, ...]``
  staged bank instead of ``[N, ...]``.

Stateless algorithms (the FedAvg/FedAdam family — see
``repro.core.api.Algorithm.stateless``) have an EMPTY client-state tree:
their :class:`HostStateStore` holds no leaves, gathers stage zero bytes,
and scatters are no-ops — stateless registrations pay nothing for paging.

Chunk planning
--------------
:func:`plan_chunk` is the host-side half of the paged scanned driver:
given a chunk's cohort rows it computes the UNION of participating
clients, pads it to a static capacity (so the chunk program compiles once
per (chunk, S), never per random cohort), and remaps the cohort ids to
staged-row positions.  The capacity is ``min(chunk · S, N)`` rounded up
to the mesh shard count — device memory is therefore bounded by the
cohort schedule, not the population.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = ["ClientStore", "HostStateStore", "plan_chunk", "device_bytes",
           "round_up"]


@runtime_checkable
class ClientStore(Protocol):
    """Structural protocol every client store implements (see module
    docstring).  ``is_resident`` is a static class attribute: resident
    stores are gathered/scattered in-graph by the engine, paged stores
    at chunk boundaries by the driver."""

    is_resident: bool

    @property
    def n_clients(self) -> int: ...

    def gather(self, rows, *, sharding=None): ...

    def scatter(self, rows, staged) -> None: ...

    def prefetch(self, rows, *, sharding=None) -> None: ...


def device_bytes(tree: PyTree) -> int:
    """Total bytes of a pytree's array leaves (the exact staging cost of
    a gathered view — the number the paging bench gates on)."""
    return sum(int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree)
               if hasattr(x, "shape"))


def round_up(n: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= max(n, 1)."""
    n = max(int(n), 1)
    m = max(int(multiple), 1)
    return ((n + m - 1) // m) * m


def plan_chunk(rows: np.ndarray, cap: int):
    """Plan one paged chunk: staged-row ids + cohort remap.

    ``rows`` is the chunk's cohort schedule ``[chunk, S]`` (sorted unique
    ids per live row; all -1 marks an empty round).  Returns
    ``(union, n_live, local)``:

    * ``union`` — ``[cap]`` int32 client ids to stage, the sorted unique
      participants padded to the STATIC capacity ``cap`` (pad slots
      repeat the last live id — dead duplicate rows that no cohort
      references and no scatter writes back);
    * ``n_live`` — how many leading union entries are real (the rows a
      scatter must write back);
    * ``local`` — ``rows`` remapped to staged positions (``union[local]
      == rows`` elementwise on live entries; -1 rows stay -1), still
      sorted unique per live row, so the staged chunk replays the exact
      cohort schedule against the ``[cap, ...]`` staged bank.

    Buffered-async chunks (``repro.fl.schedule.BufferedSchedule``) have
    OVERLAPPING cohorts — the same client can flush in several rounds of
    one chunk.  ``np.unique`` collapses the overlap, so each client is
    staged once and every flush round remaps onto the same staged row;
    the in-scan scatter then applies the rounds in order, exactly like
    the resident bank.
    """
    rows = np.asarray(rows)
    live = rows >= 0
    union = np.unique(rows[live]).astype(np.int64)
    n_live = int(union.size)
    if n_live > cap:
        raise ValueError(f"chunk touches {n_live} distinct clients but the "
                         f"staging capacity is {cap}")
    pad_id = union[-1] if n_live else 0
    padded = np.full((cap,), pad_id, np.int32)
    padded[:n_live] = union
    local = np.full(rows.shape, -1, np.int32)
    local[live] = np.searchsorted(union, rows[live]).astype(np.int32)
    return padded, n_live, local


def _put(x: np.ndarray, sharding):
    return jax.device_put(x, sharding) if sharding is not None \
        else jnp.asarray(x)


class HostStateStore:
    """Host-paged client-state bank: the paged twin of the resident
    donated ``[N, ...]`` pytree in ``FedState.clients``.

    Rows live as host numpy; :meth:`gather` stages the requested rows to
    device (optionally placed with a mesh ``sharding``, so each mesh
    shard receives only its slice — shard-local paging), and
    :meth:`scatter` writes updated device rows back into the host bank
    in place.  A store with no leaves (stateless algorithms) stages and
    scatters NOTHING — zero paging cost, enforced by
    ``last_staged_bytes == 0``.

    The store is mutated in place by ``scatter`` — it is the single
    source of truth for client state across chunks, exactly like the
    donated resident bank.  Branch with :meth:`copy` (the paged analog
    of ``FedState.copy``).
    """

    is_resident = False

    def __init__(self, bank: PyTree, n: int | None = None):
        self.bank = jax.tree.map(
            lambda x: np.ascontiguousarray(np.asarray(x)), bank)
        leaves = jax.tree.leaves(self.bank)
        # a stateless store has no leaves to read N from — take it as given
        self._n = int(leaves[0].shape[0]) if leaves else int(n or 0)
        #: exact device bytes of the most recent gather (bench/tests)
        self.last_staged_bytes = 0

    @classmethod
    def broadcast(cls, one_client: PyTree, n: int) -> "HostStateStore":
        """Build the ``[N, ...]`` host bank from one client's init state
        (the paged counterpart of the engine's device broadcast_to)."""
        return cls(jax.tree.map(
            lambda x: np.broadcast_to(
                np.asarray(x), (n, *np.shape(x))).copy(), one_client), n=n)

    @property
    def n_clients(self) -> int:
        return self._n

    @property
    def stateless(self) -> bool:
        """No leaves → nothing to page (FedAvg/FedAdam-family state)."""
        return not jax.tree.leaves(self.bank)

    def host_bytes(self) -> int:
        return device_bytes(self.bank)

    def gather(self, rows, *, sharding=None) -> PyTree:
        """Stage ``rows`` to device as a ``[len(rows), ...]`` pytree."""
        rows = np.asarray(rows)
        staged = jax.tree.map(lambda x: _put(x[rows], sharding), self.bank)
        self.last_staged_bytes = device_bytes(staged)
        return staged

    def scatter(self, rows, staged: PyTree) -> None:
        """Write ``staged`` device rows back to the host bank in place.
        ``rows`` must be the LIVE (unpadded) prefix of the gathered ids;
        extra trailing staged rows (capacity padding) are ignored."""
        rows = np.asarray(rows)
        if rows.size == 0 or self.stateless:
            return
        k = int(rows.shape[0])
        jax.tree.map(
            lambda host, dev: host.__setitem__(rows, np.asarray(dev[:k])),
            self.bank, staged)

    def prefetch(self, rows, *, sharding=None) -> None:
        """No-op: state rows carry a chunk-to-chunk write dependency (the
        next chunk's rows may have been updated by the current one), so
        they stage synchronously after the previous scatter.  Only the
        read-only data bank double-buffers across the boundary."""

    def copy(self) -> "HostStateStore":
        return HostStateStore(jax.tree.map(np.copy, self.bank), n=self._n)

"""The ClientStore seam: where per-client rows live, and how cohorts move.

Every engine layer used to assume the stacked ``[N, ...]`` client bank
(state rows and data shards alike) is RESIDENT on device.  That caps the
simulable population at whatever one accelerator holds — second-order
clients carry heavy state (SCAFFOLD control variates, gram banks), so
N ≥ 10⁵ stateful clients cannot be device-resident at any useful model
size.  This module names the seam instead of the assumption:

ClientStore protocol
--------------------
A *client store* owns per-client rows (a pytree of ``[N, ...]`` leaves,
or an indexable host dataset) and exposes exactly three operations::

    gather(rows, sharding=None)  -> device rows   [len(rows), ...]
    scatter(rows, staged)        -> None          (write device rows back)
    prefetch(rows, sharding=None)-> None          (per-chunk staging hint)

plus ``n_clients`` and the static flag ``is_resident``.  Two residency
classes implement it:

* **resident** — the store's rows already live on device as one stacked
  bank.  ``gather``/``scatter`` are identities the ENGINE performs
  in-graph (``jnp.take`` / ``.at[idx].set`` inside the round jit), so the
  resident store preserves the scanned driver's donation aliasing and
  bit-for-bit contract exactly — it *is* today's behavior, renamed.
  :class:`repro.data.federated.DeviceDataBank` is the resident data
  store; the resident client-state store is the donated ``[N, ...]``
  pytree carried in ``FedState.clients``.
* **paged** — cold rows stay in host memory (numpy; pinned host buffers
  on accelerator backends ride the same ``device_put`` path), and only
  the HOT rows a chunk of rounds actually touches are staged to device.
  :class:`repro.data.federated.HostPagedBank` pages the federated data;
  :class:`HostStateStore` (here) pages the client-state bank.  Paging
  happens ONLY at chunk boundaries, outside the scanned graph — the
  round body stays pure and the per-chunk program is the same
  ``lax.scan`` the resident path compiles, just over a ``[U, ...]``
  staged bank instead of ``[N, ...]``.  The DISK rung of the same
  ladder — ``np.memmap`` cold files behind identical gather/scatter
  semantics — is :mod:`repro.fl.coldstore` (``MmapStateStore`` /
  ``MmapPagedBank``); it subclasses the host tier, so every contract
  below holds verbatim one tier further out.

Write-behind scatter (the overlap extension)
--------------------------------------------
The protocol proper is the three calls above; paged STATE stores
additionally implement the write-behind pair

    scatter_async(rows, staged) -> None   (enqueue the write-back)
    fence(rows=None)            -> None   (wait for in-flight writes)

``scatter_async`` hands the chunk's updated rows to a single FIFO drain
thread: the device→host copy blocks on the chunk's compute THERE, while
the driver's host loop moves on to plan and stage the next chunk — the
write side of the chunk boundary overlaps compute exactly like the data
bank's read-side ``prefetch`` has since the host tier shipped.  Ordering
is preserved by construction (one worker, submission order), and
:meth:`HostStateStore.gather`/:meth:`~HostStateStore.scatter` FENCE any
in-flight writes that intersect their rows before touching the bank, so
a chunk that re-gathers rows the previous chunk is still writing blocks
until those rows have landed — paged ≡ resident stays bitwise on vmap
and fp32 on the mesh with overlap enabled.  ``prefetch`` on a state
store is read-ahead staging with the same hazard rule: rows that
intersect an in-flight write are skipped (the later ``gather`` restages
them fresh) rather than staged stale.

Stateless algorithms (the FedAvg/FedAdam family — see
``repro.core.api.Algorithm.stateless``) have an EMPTY client-state tree:
their :class:`HostStateStore` holds no leaves, gathers stage zero bytes,
and scatters are no-ops — stateless registrations pay nothing for paging.

Chunk planning
--------------
:func:`plan_chunk` is the host-side half of the paged scanned driver:
given a chunk's cohort rows it computes the UNION of participating
clients, pads it to a static capacity (so the chunk program compiles once
per (chunk, S), never per random cohort), and remaps the cohort ids to
staged-row positions.  The capacity is ``min(chunk · S, N)`` rounded up
to the mesh shard count — device memory is therefore bounded by the
cohort schedule, not the population.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = ["ClientStore", "HostStateStore", "plan_chunk", "device_bytes",
           "round_up", "staged_host_rows"]


@runtime_checkable
class ClientStore(Protocol):
    """Structural protocol every client store implements (see module
    docstring).  ``is_resident`` is a static class attribute: resident
    stores are gathered/scattered in-graph by the engine, paged stores
    at chunk boundaries by the driver."""

    is_resident: bool

    @property
    def n_clients(self) -> int: ...

    def gather(self, rows, *, sharding=None): ...

    def scatter(self, rows, staged) -> None: ...

    def prefetch(self, rows, *, sharding=None) -> None: ...


def device_bytes(tree: PyTree) -> int:
    """Total bytes of a pytree's array leaves (the exact staging cost of
    a gathered view — the number the paging bench gates on)."""
    return sum(int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree)
               if hasattr(x, "shape"))


def round_up(n: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= max(n, 1)."""
    n = max(int(n), 1)
    m = max(int(multiple), 1)
    return ((n + m - 1) // m) * m


def plan_chunk(rows: np.ndarray, cap: int):
    """Plan one paged chunk: staged-row ids + cohort remap.

    ``rows`` is the chunk's cohort schedule ``[chunk, S]`` (sorted unique
    ids per live row; all -1 marks an empty round).  Returns
    ``(union, n_live, local)``:

    * ``union`` — ``[cap]`` int32 client ids to stage, the sorted unique
      participants padded to the STATIC capacity ``cap`` (pad slots
      repeat the last live id — dead duplicate rows that no cohort
      references and no scatter writes back);
    * ``n_live`` — how many leading union entries are real (the rows a
      scatter must write back);
    * ``local`` — ``rows`` remapped to staged positions (``union[local]
      == rows`` elementwise on live entries; -1 rows stay -1), still
      sorted unique per live row, so the staged chunk replays the exact
      cohort schedule against the ``[cap, ...]`` staged bank.

    Buffered-async chunks (``repro.fl.schedule.BufferedSchedule``) have
    OVERLAPPING cohorts — the same client can flush in several rounds of
    one chunk.  ``np.unique`` collapses the overlap, so each client is
    staged once and every flush round remaps onto the same staged row;
    the in-scan scatter then applies the rounds in order, exactly like
    the resident bank.
    """
    rows = np.asarray(rows)
    live = rows >= 0
    union = np.unique(rows[live]).astype(np.int64)
    n_live = int(union.size)
    if n_live > cap:
        raise ValueError(f"chunk touches {n_live} distinct clients but the "
                         f"staging capacity is {cap}")
    pad_id = union[-1] if n_live else 0
    padded = np.full((cap,), pad_id, np.int32)
    padded[:n_live] = union
    local = np.full(rows.shape, -1, np.int32)
    local[live] = np.searchsorted(union, rows[live]).astype(np.int32)
    return padded, n_live, local


def _put(x: np.ndarray, sharding):
    return jax.device_put(x, sharding) if sharding is not None \
        else jnp.asarray(x)


def staged_host_rows(x, k: int) -> np.ndarray:
    """Host copy of the first ``k`` rows of a staged device leaf.

    Mesh-sharded ``jax.Array`` leaves are assembled shard-by-shard (each
    addressable shard D2H-copies its own slice), so no compiled slice or
    cross-device gather is ever dispatched — which is what lets the
    write-behind drain thread (:meth:`HostStateStore.scatter_async`) call
    this off the main thread.  Replicated and plain-numpy leaves fall
    through to a single copy.  Blocks until the rows' producing compute
    has finished (the D2H copy waits on the buffer).
    """
    if k <= 0:
        return np.asarray(x)[:0]
    if not isinstance(x, jax.Array):
        return np.asarray(x)[:k]
    out = None
    for s in x.addressable_shards:
        first = s.index[0] if s.index else slice(None)
        start = int(first.start or 0) if isinstance(first, slice) else 0
        if start >= k:
            continue
        data = np.asarray(s.data)
        if start == 0 and data.shape[0] >= k:
            return np.ascontiguousarray(data[:k])
        if out is None:
            out = np.empty((k, *x.shape[1:]), x.dtype)
        take = min(start + data.shape[0], k) - start
        out[start:start + take] = data[:take]
    return out if out is not None else np.asarray(x)[:k]


class HostStateStore:
    """Host-paged client-state bank: the paged twin of the resident
    donated ``[N, ...]`` pytree in ``FedState.clients``.

    Rows live as host numpy; :meth:`gather` stages the requested rows to
    device (optionally placed with a mesh ``sharding``, so each mesh
    shard receives only its slice — shard-local paging), and
    :meth:`scatter` writes updated device rows back into the host bank
    in place.  A store with no leaves (stateless algorithms) stages and
    scatters NOTHING — zero paging cost, enforced by
    ``last_staged_bytes == 0``.

    The store is mutated in place by ``scatter`` — it is the single
    source of truth for client state across chunks, exactly like the
    donated resident bank.  Branch with :meth:`copy` (the paged analog
    of ``FedState.copy``).

    Write-behind: :meth:`scatter_async` enqueues the write-back on a
    single FIFO drain thread so the D2H copy blocks on the chunk's
    compute off the main thread; :meth:`fence` waits for in-flight
    writes, and :meth:`gather`/:meth:`scatter` fence any pending writes
    intersecting their rows before touching the bank (see the module
    docstring).  :meth:`prefetch` is read-ahead staging for the next
    chunk's rows, skipped for rows an in-flight write still owns.
    """

    is_resident = False

    def __init__(self, bank: PyTree, n: int | None = None):
        self.bank = jax.tree.map(
            lambda x: np.ascontiguousarray(np.asarray(x)), bank)
        leaves = jax.tree.leaves(self.bank)
        # a stateless store has no leaves to read N from — take it as given
        self._n = int(leaves[0].shape[0]) if leaves else int(n or 0)
        self._init_runtime()

    def _init_runtime(self) -> None:
        """Per-instance staging state shared with the disk-tier subclass
        (which skips ``__init__``'s pull-into-RAM normalization)."""
        #: exact device bytes of the most recent gather (bench/tests)
        self.last_staged_bytes = 0
        self._cache: dict = {}        # prefetch key -> (rows, staged tree)
        self._pending: list = []      # [(rows, future)] in submission order
        self._pool: ThreadPoolExecutor | None = None

    @classmethod
    def broadcast(cls, one_client: PyTree, n: int) -> "HostStateStore":
        """Build the ``[N, ...]`` host bank from one client's init state
        (the paged counterpart of the engine's device broadcast_to)."""
        return cls(jax.tree.map(
            lambda x: np.broadcast_to(
                np.asarray(x), (n, *np.shape(x))).copy(), one_client), n=n)

    @property
    def n_clients(self) -> int:
        return self._n

    @property
    def stateless(self) -> bool:
        """No leaves → nothing to page (FedAvg/FedAdam-family state)."""
        return not jax.tree.leaves(self.bank)

    def host_bytes(self) -> int:
        return device_bytes(self.bank)

    def _stage(self, rows: np.ndarray, sharding) -> PyTree:
        return jax.tree.map(lambda x: _put(x[rows], sharding), self.bank)

    def gather(self, rows, *, sharding=None) -> PyTree:
        """Stage ``rows`` to device as a ``[len(rows), ...]`` pytree,
        consuming a matching :meth:`prefetch` if one is staged.  Fences
        any in-flight ``scatter_async`` writes intersecting ``rows``
        first — a re-gather never observes a half-landed chunk."""
        rows = np.asarray(rows)
        self.fence(rows)
        hit = self._cache.pop((rows.tobytes(), sharding), None)
        staged = hit[1] if hit is not None else self._stage(rows, sharding)
        self.last_staged_bytes = device_bytes(staged)
        return staged

    def _write_back(self, rows: np.ndarray, staged: PyTree) -> None:
        k = int(rows.shape[0])
        jax.tree.map(
            lambda host, dev: host.__setitem__(
                rows, staged_host_rows(dev, k)),
            self.bank, staged)

    def scatter(self, rows, staged: PyTree) -> None:
        """Write ``staged`` device rows back to the host bank in place.
        ``rows`` must be the LIVE (unpadded) prefix of the gathered ids;
        extra trailing staged rows (capacity padding) are ignored.
        Blocks until the write has landed (fencing queued async writes
        first, so writes land in program order)."""
        rows = np.asarray(rows)
        self.fence(rows)
        self._invalidate(rows)
        if rows.size == 0 or self.stateless:
            return
        self._write_back(rows, staged)

    def scatter_async(self, rows, staged: PyTree) -> None:
        """Enqueue :meth:`scatter` on the store's single drain thread and
        return immediately: the device→host copy blocks on the chunk's
        compute THERE while the caller stages the next chunk.  One FIFO
        worker keeps writes in submission order; :meth:`fence` (or any
        gather/scatter touching the same rows) waits for them."""
        rows = np.asarray(rows)
        self._invalidate(rows)
        if rows.size == 0 or self.stateless:
            return
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="clientstore-drain")
        self._pending.append(
            (rows.copy(), self._pool.submit(self._write_back, rows.copy(),
                                            staged)))

    def fence(self, rows=None) -> None:
        """Block until in-flight :meth:`scatter_async` writes have landed.

        ``rows=None`` drains the whole queue (the paged driver's final
        barrier before a run returns its state); otherwise only pending
        writes whose row sets INTERSECT ``rows`` are waited on — the
        correctness fence before re-gathering rows the previous chunk
        may still be writing.  Exceptions from a background write-back
        surface here (and completed entries are reaped eagerly)."""
        if not self._pending:
            return
        rows = None if rows is None else np.asarray(rows)
        keep = []
        try:
            for prows, fut in self._pending:
                if (rows is None or fut.done()
                        or np.intersect1d(prows, rows).size):
                    fut.result()
                else:
                    keep.append((prows, fut))
        finally:
            # a failed write-back must not stay queued (it would re-raise
            # from every later fence, including close())
            self._pending = keep

    def _invalidate(self, rows: np.ndarray) -> None:
        """Drop read-ahead entries overlapping freshly-written rows."""
        if self._cache:
            self._cache = {
                key: (crows, staged)
                for key, (crows, staged) in self._cache.items()
                if not np.intersect1d(crows, rows).size}

    def prefetch(self, rows, *, sharding=None) -> None:
        """Read-ahead staging of ``rows`` for a later :meth:`gather` with
        the same arguments (``device_put`` dispatches asynchronously, so
        the copy rides under the current chunk's compute — the state
        bank's analog of the data bank's double-buffering).

        Safe by the hazard rule: rows that intersect an in-flight
        ``scatter_async`` are NOT staged (the values on host are stale
        until the write lands) — the later gather fences and restages
        them fresh; a subsequent scatter to prefetched rows invalidates
        the staged entry.  Until this shipped, state prefetch was a
        documented no-op while the data bank double-buffered — the
        asymmetry tests/test_store.py now pins the other way."""
        if self.stateless:
            return
        rows = np.asarray(rows)
        key = (rows.tobytes(), sharding)
        if key in self._cache:
            return
        for prows, fut in self._pending:
            if not fut.done() and np.intersect1d(prows, rows).size:
                return
        self._cache[key] = (rows.copy(), self._stage(rows, sharding))

    def copy(self) -> "HostStateStore":
        self.fence()
        return HostStateStore(jax.tree.map(np.copy, self.bank), n=self._n)

"""Cohort schedules: WHO participates WHEN — and, for buffered-async
rounds, HOW STALE each report is.

``FedSim.run_scanned(cohorts=...)`` accepts either

* ``None`` — in-graph uniform sampling (:func:`~repro.fl.simulate.
  sample_cohort` each round, the PR 4 behavior);
* a plain host int array ``[rounds, S]`` — the raw-array path, kept
  bit-for-bit: each row is sorted unique client ids, a row of all -1 is
  an empty round (skipped via ``lax.cond``);
* a :class:`CohortSchedule` — an object that BUILDS such an array
  (seeded generators, registered availability traces, or the buffered-
  async event process), so the scanned engine consumes one host array
  regardless of how the participation story was expressed.

Every path funnels through :func:`resolve` into a :class:`SchedulePlan`;
the shape / dead-row validation that used to live inline in
``run_scanned`` lives here (:func:`validate_cohorts`) so the per-round
driver, the scanned driver and the paged driver's ``plan_chunk`` all
enforce ONE contract.  The sortedness requirement is load-bearing, not
cosmetic: ``sharded.bucket_cohort``'s in-graph rank-within-shard
bucketing (``arange(S) - searchsorted(d, d)``) silently MIS-BUCKETS
unsorted rows — collisions overwrite bucket slots and participants are
dropped — so unsorted explicit schedules are rejected at this host
boundary (in-graph paths cannot repair them).  A cohort is a set: sort
each row (``np.sort``) before passing it in.

Buffered-async rounds (:class:`BufferedSchedule`)
-------------------------------------------------
FedBuff-style semantics, resolved ENTIRELY host-side into two arrays the
scanned engine consumes: ``concurrency`` clients train at any moment;
each dispatch completes after ``delay`` rounds and its report enters a
FIFO server buffer; when the buffer holds ``goal`` reports the round
FLUSHES them as one cohort row (staleness = flush round − dispatch
round) and replacement clients dispatch next round.  Rounds that flush
nothing are all--1 rows (the engine skips them; in-flight clients are
untouched by construction).  ``build`` returns ``(cohorts, staleness)``
and :func:`resolve` derives the params-ring ``window`` = max staleness
+ 1.  With ``delay=0`` and ``concurrency == goal`` every round flushes a
fresh cohort with zero staleness — the configuration under which the
async engine must reproduce the synchronous one bitwise (vmap engine) /
to fp32 mixing tolerance (mesh engine); see tests/test_async.py.

``weight_pow`` is the engine-level staleness damping applied to EVERY
algorithm's aggregation weights: ``w_i = (1 + tau_i) ** -weight_pow``
(exactly 1.0 at ``tau == 0``, any power).  Curvature damping of the
preconditioned mix is separate — a ``ServerMixer.damping`` hook, see
``repro.core.algorithms._stale_gram_scale``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "SchedulePlan", "CohortSchedule", "ArraySchedule", "SampledSchedule",
    "BufferedSchedule", "validate_cohorts", "validate_staleness",
    "resolve", "register_trace", "trace", "TRACES",
]


# --------------------------------------------------------- validation ----

def validate_cohorts(cohorts, rounds: int, n: int) -> np.ndarray:
    """Validate a ``[rounds, S]`` cohort array (host-side) and return it
    as int32.  Moved out of ``run_scanned`` so every consumer of a
    schedule — scanned, paged, per-round — enforces the same contract.
    """
    cohorts = np.asarray(cohorts, np.int32)
    if cohorts.ndim != 2 or cohorts.shape[0] != rounds:
        raise ValueError(f"cohorts must be [rounds={rounds}, S]; "
                         f"got {cohorts.shape}")
    live = cohorts[cohorts[:, 0] >= 0]
    dead = cohorts[cohorts[:, 0] < 0]
    if live.size and (np.any(np.diff(live, axis=1) <= 0)
                      or live.min() < 0 or live.max() >= n):
        raise ValueError(
            f"cohort rows must be sorted unique ids in [0, {n}) (or all "
            "-1 for an empty round). Sortedness is load-bearing: "
            "sharded.bucket_cohort's in-graph bucketing silently "
            "mis-buckets unsorted rows, so unsorted explicit schedules "
            "are rejected here at the host boundary — a cohort is a set; "
            "np.sort each row.")
    if dead.size and not np.all(dead == -1):
        raise ValueError("an empty cohort row must be ALL -1 — a "
                         "row mixing -1 with real ids is ambiguous "
                         "(it would be silently skipped, not "
                         "partially trained)")
    return cohorts


def validate_staleness(staleness, cohorts: np.ndarray) -> np.ndarray:
    """Validate per-report staleness aligned with ``cohorts``: int32,
    same shape, and ``0 <= tau <= t`` on live rows — a report cannot
    predate its own dispatch or round 0, and the engine's params ring
    only holds snapshots of rounds that already ran."""
    staleness = np.asarray(staleness, np.int32)
    if staleness.shape != cohorts.shape:
        raise ValueError(f"staleness must match cohorts shape "
                         f"{cohorts.shape}; got {staleness.shape}")
    t = np.arange(cohorts.shape[0], dtype=np.int64)[:, None]
    live = cohorts[:, :1] >= 0
    if np.any(staleness < 0) or np.any((staleness > t) & live):
        raise ValueError("staleness must satisfy 0 <= tau <= t on every "
                         "live row: a report cannot be older than the "
                         "run itself (the params ring only holds rounds "
                         "that already executed)")
    return staleness


# ------------------------------------------------------------- plan ------

@dataclass(frozen=True)
class SchedulePlan:
    """A resolved, validated schedule — what ``run_scanned`` actually
    consumes.  ``staleness is None`` means SYNCHRONOUS (today's engine,
    raw-array path bit-for-bit); otherwise the buffered-async engine
    runs with a params ring of ``window`` snapshots and aggregation
    weights damped by ``(1 + tau) ** -weight_pow``."""
    cohorts: np.ndarray | None    # int32 [rounds, S]; None => in-graph draw
    staleness: np.ndarray | None  # int32 [rounds, S]; None => synchronous
    s: int
    scheduled: bool
    window: int = 0               # params-ring length; 0 => synchronous
    weight_pow: float = 0.0

    @property
    def is_async(self) -> bool:
        return self.staleness is not None


def resolve(spec, *, rounds: int, n: int,
            sample_clients: int = 0) -> SchedulePlan:
    """Resolve ``run_scanned``'s ``cohorts=`` argument — ``None``, a raw
    host array, or any :class:`CohortSchedule` — into a validated
    :class:`SchedulePlan`.  The raw-array path produces exactly the plan
    an :class:`ArraySchedule` wrapping the same array would (bit-for-bit
    contract, tested)."""
    if spec is None:
        s = sample_clients if 0 < sample_clients < n else n
        return SchedulePlan(cohorts=None, staleness=None, s=s,
                            scheduled=False)
    if isinstance(spec, CohortSchedule):
        built = spec.build(n, rounds)
        cohorts, stale = built if isinstance(built, tuple) else (built, None)
    else:
        cohorts, stale = spec, None
    cohorts = validate_cohorts(cohorts, rounds, n)
    s = int(cohorts.shape[1])
    if stale is None:
        return SchedulePlan(cohorts=cohorts, staleness=None, s=s,
                            scheduled=True)
    stale = validate_staleness(stale, cohorts)
    live = cohorts[:, 0] >= 0
    window = int(stale[live].max(initial=0)) + 1 if live.any() else 1
    return SchedulePlan(
        cohorts=cohorts, staleness=stale, s=s, scheduled=True,
        window=window,
        weight_pow=float(getattr(spec, "weight_pow", 0.0) or 0.0))


# --------------------------------------------------------- schedules -----

class CohortSchedule:
    """Protocol for cohort generators.  ``build(n, rounds)`` returns a
    host ``[rounds, S]`` int array (rows sorted unique, all -1 = empty
    round) — or a ``(cohorts, staleness)`` pair for buffered-async
    schedules.  :func:`resolve` validates whatever comes back, so a
    schedule never needs to re-implement the contract checks.  A
    ``weight_pow`` attribute (default 0.0) requests engine-level
    staleness weight damping."""

    weight_pow: float = 0.0

    def build(self, n: int, rounds: int):
        raise NotImplementedError


@dataclass(frozen=True)
class ArraySchedule(CohortSchedule):
    """A pre-built cohort array behind the protocol.  Resolving this is
    identical to passing the raw array straight to ``run_scanned``."""
    cohorts: object

    def build(self, n: int, rounds: int):
        return np.asarray(self.cohorts, np.int32)


@dataclass(frozen=True)
class SampledSchedule(CohortSchedule):
    """Seeded host-side uniform sampler: ``s`` unique clients per round
    from a ``np.random.default_rng(seed)`` stream — reproducible cohorts
    without the caller materializing the array by hand.  (Distinct from
    ``sample_clients=``'s IN-GRAPH draw: that one is keyed by the run's
    jax rng and stays the scanned engine's default.)"""
    s: int
    seed: int = 0

    def build(self, n: int, rounds: int):
        if not 0 < self.s <= n:
            raise ValueError(f"SampledSchedule needs 0 < s <= n; "
                             f"got s={self.s}, n={n}")
        rng = np.random.default_rng(self.seed)
        return np.stack([
            np.sort(rng.choice(n, size=self.s, replace=False))
            for _ in range(rounds)]).astype(np.int32)


# availability traces: name -> fn(n, rounds, s, seed, **kw) -> cohorts
TRACES: dict = {}


def register_trace(name: str):
    """Register an availability-trace generator under ``name`` (used via
    :func:`trace`).  The fn signature is
    ``fn(n, rounds, s, seed, **kw) -> [rounds, S] host int array``."""
    def deco(fn):
        if name in TRACES:
            raise ValueError(f"trace {name!r} already registered")
        TRACES[name] = fn
        return fn
    return deco


@dataclass(frozen=True)
class TraceSchedule(CohortSchedule):
    name: str
    s: int
    seed: int = 0
    kwargs: tuple = ()   # sorted (key, value) pairs — keeps the dataclass hashable

    def build(self, n: int, rounds: int):
        return TRACES[self.name](n, rounds, self.s, self.seed,
                                 **dict(self.kwargs))


def trace(name: str, s: int, *, seed: int = 0, **kw) -> TraceSchedule:
    """A registered availability trace as a :class:`CohortSchedule`:
    ``trace("diurnal", s=8, seed=3, period=24)``."""
    if name not in TRACES:
        raise ValueError(f"unknown trace {name!r}; registered: "
                         f"{sorted(TRACES)}")
    return TraceSchedule(name=name, s=s, seed=seed,
                         kwargs=tuple(sorted(kw.items())))


@register_trace("diurnal")
def _diurnal(n, rounds, s, seed, *, period: int = 24, duty: float = 0.5):
    """Diurnal availability: client ``c`` is online at round ``t`` when
    its phase-shifted day cycle ``sin(2pi (t / period + c / n))`` is in
    the top ``duty`` fraction of the cycle.  Cohorts draw uniformly from
    the online pool; when fewer than ``s`` clients are online the round
    is a quorum loss (all -1, skipped by the engine)."""
    rng = np.random.default_rng(seed)
    rows = np.full((rounds, s), -1, np.int32)
    phase = np.arange(n) / n
    thresh = np.sin(np.pi * (0.5 - duty))   # top `duty` of a sine cycle
    for t in range(rounds):
        online = np.flatnonzero(
            np.sin(2 * np.pi * (t / period + phase)) >= thresh)
        if online.size >= s:
            rows[t] = np.sort(rng.choice(online, size=s, replace=False))
    return rows


@register_trace("dropout_midround")
def _dropout_midround(n, rounds, s, seed, *, drop_prob: float = 0.15):
    """Mid-round dropout: a cohort is drawn every round, but with
    probability ``drop_prob`` it loses quorum before reporting and the
    whole round aborts (all -1).  Fixed-width cohort rows cannot express
    a PARTIAL cohort — modeling per-client dropout inside a round needs
    the buffered-async engine (the dropped client simply never reports);
    this trace covers the all-or-nothing failure mode the sync engine
    can express."""
    rng = np.random.default_rng(seed)
    rows = np.full((rounds, s), -1, np.int32)
    for t in range(rounds):
        if rng.random() >= drop_prob:
            rows[t] = np.sort(rng.choice(n, size=s, replace=False))
    return rows


# ----------------------------------------------------- buffered async ----

@dataclass(frozen=True)
class BufferedSchedule(CohortSchedule):
    """FedBuff-style buffered-async arrival process, resolved host-side.

    ``concurrency`` clients are in flight at any time; a dispatch at
    round ``t0`` completes after ``delay`` rounds (an int, or an
    inclusive ``(lo, hi)`` range sampled per dispatch) and its report
    joins a FIFO buffer; a round with ``goal`` buffered reports flushes
    them as ONE cohort row with per-report staleness ``t - t0``, frees
    those clients, and dispatches replacements the next round.  A client
    is busy from dispatch until flush, so a flush row never repeats an
    id.  Rounds that flush nothing are all--1 rows.

    ``build`` returns ``(cohorts, staleness)``; :func:`resolve` sizes
    the engine's params ring at ``max(staleness) + 1``.  With
    ``delay=0, concurrency=goal`` this degenerates to one fresh
    zero-staleness cohort per round — the sync-equivalence configuration.
    """
    goal: int
    concurrency: int
    delay: object = 0       # int, or inclusive (lo, hi) tuple
    seed: int = 0
    weight_pow: float = 0.0

    def build(self, n: int, rounds: int):
        if self.goal < 1:
            raise ValueError(f"goal must be >= 1; got {self.goal}")
        if self.concurrency < self.goal:
            raise ValueError(
                f"concurrency ({self.concurrency}) < goal ({self.goal}): "
                "the buffer can never reach the flush size")
        if self.concurrency > n:
            raise ValueError(f"concurrency ({self.concurrency}) exceeds "
                             f"the population n={n}")
        lo, hi = ((int(self.delay), int(self.delay))
                  if np.isscalar(self.delay) else
                  (int(self.delay[0]), int(self.delay[1])))
        if lo < 0 or hi < lo:
            raise ValueError(f"delay must be >= 0 (int or (lo, hi) with "
                             f"lo <= hi); got {self.delay}")
        rng = np.random.default_rng(self.seed)
        rows = np.full((rounds, self.goal), -1, np.int32)
        taus = np.zeros((rounds, self.goal), np.int32)
        free = np.ones(n, bool)
        inflight: list = []   # (report_t, seq, client, dispatch_t)
        buffer: list = []     # (client, dispatch_t), FIFO
        pending, seq = self.concurrency, 0
        for t in range(rounds):
            # dispatch replacements for whatever flushed last round
            k = min(pending, int(free.sum()))
            if k:
                chosen = rng.choice(np.flatnonzero(free), size=k,
                                    replace=False)
                for c in chosen:
                    d = int(rng.integers(lo, hi + 1)) if hi > lo else lo
                    inflight.append((t + d, seq, int(c), t))
                    seq += 1
                free[chosen] = False
                pending -= k
            # arrivals: completed reports enter the buffer FIFO
            done = sorted(e for e in inflight if e[0] <= t)
            if done:
                inflight = [e for e in inflight if e[0] > t]
                buffer.extend((c, t0) for (_, _, c, t0) in done)
            # at most one goal-sized flush per round
            if len(buffer) >= self.goal:
                batch, buffer = buffer[:self.goal], buffer[self.goal:]
                ids = np.fromiter((c for c, _ in batch), np.int32)
                age = np.fromiter((t - t0 for _, t0 in batch), np.int32)
                order = np.argsort(ids)
                rows[t], taus[t] = ids[order], age[order]
                free[ids] = True
                pending += self.goal
        return rows, taus

"""Cohort schedules: WHO participates WHEN — and, for buffered-async
rounds, HOW STALE each report is.

``FedSim.run_scanned(cohorts=...)`` accepts either

* ``None`` — in-graph uniform sampling (:func:`~repro.fl.simulate.
  sample_cohort` each round, the PR 4 behavior);
* a plain host int array ``[rounds, S]`` — the raw-array path, kept
  bit-for-bit: each row is sorted unique client ids, a row of all -1 is
  an empty round (skipped via ``lax.cond``);
* a :class:`CohortSchedule` — an object that BUILDS such an array
  (seeded generators, registered availability traces, or the buffered-
  async event process), so the scanned engine consumes one host array
  regardless of how the participation story was expressed.

Every path funnels through :func:`resolve` into a :class:`SchedulePlan`;
the shape / dead-row validation that used to live inline in
``run_scanned`` lives here (:func:`validate_cohorts`) so the per-round
driver, the scanned driver and the paged driver's ``plan_chunk`` all
enforce ONE contract.  The sortedness requirement is load-bearing, not
cosmetic: ``sharded.bucket_cohort``'s in-graph rank-within-shard
bucketing (``arange(S) - searchsorted(d, d)``) silently MIS-BUCKETS
unsorted rows — collisions overwrite bucket slots and participants are
dropped — so unsorted explicit schedules are rejected at this host
boundary (in-graph paths cannot repair them).  A cohort is a set: sort
each row (``np.sort``) before passing it in.

Buffered-async rounds (:class:`BufferedSchedule`)
-------------------------------------------------
FedBuff-style semantics, resolved ENTIRELY host-side into two arrays the
scanned engine consumes: ``concurrency`` clients train at any moment;
each dispatch completes after ``delay`` rounds and its report enters a
FIFO server buffer; when the buffer holds ``goal`` reports the round
FLUSHES them as one cohort row (staleness = flush round − dispatch
round) and replacement clients dispatch next round.  Rounds that flush
nothing are all--1 rows (the engine skips them; in-flight clients are
untouched by construction).  ``build`` returns ``(cohorts, staleness)``
and :func:`resolve` derives the params-ring ``window`` = max staleness
+ 1.  With ``delay=0`` and ``concurrency == goal`` every round flushes a
fresh cohort with zero staleness — the configuration under which the
async engine must reproduce the synchronous one bitwise (vmap engine) /
to fp32 mixing tolerance (mesh engine); see tests/test_async.py.

``weight_pow`` is the engine-level staleness damping applied to EVERY
algorithm's aggregation weights: ``w_i = (1 + tau_i) ** -weight_pow``
(exactly 1.0 at ``tau == 0``, any power).  Curvature damping of the
preconditioned mix is separate — a ``ServerMixer.damping`` hook, see
``repro.core.algorithms._stale_gram_scale``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "SchedulePlan", "BuiltSchedule", "CohortSchedule", "ArraySchedule",
    "SampledSchedule", "BufferedSchedule", "buffered_events",
    "validate_cohorts", "validate_staleness", "validate_faults",
    "resolve", "register_trace", "trace", "TRACES",
]


# --------------------------------------------------------- validation ----

def validate_cohorts(cohorts, rounds: int, n: int) -> np.ndarray:
    """Validate a ``[rounds, S]`` cohort array (host-side) and return it
    as int32.  Moved out of ``run_scanned`` so every consumer of a
    schedule — scanned, paged, per-round — enforces the same contract.
    """
    cohorts = np.asarray(cohorts, np.int32)
    if cohorts.ndim != 2 or cohorts.shape[0] != rounds:
        raise ValueError(f"cohorts must be [rounds={rounds}, S]; "
                         f"got {cohorts.shape}")
    live = cohorts[cohorts[:, 0] >= 0]
    dead = cohorts[cohorts[:, 0] < 0]
    if live.size and (np.any(np.diff(live, axis=1) <= 0)
                      or live.min() < 0 or live.max() >= n):
        raise ValueError(
            f"cohort rows must be sorted unique ids in [0, {n}) (or all "
            "-1 for an empty round). Sortedness is load-bearing: "
            "sharded.bucket_cohort's in-graph bucketing silently "
            "mis-buckets unsorted rows, so unsorted explicit schedules "
            "are rejected here at the host boundary — a cohort is a set; "
            "np.sort each row.")
    if dead.size and not np.all(dead == -1):
        raise ValueError("an empty cohort row must be ALL -1 — a "
                         "row mixing -1 with real ids is ambiguous "
                         "(it would be silently skipped, not "
                         "partially trained)")
    return cohorts


def validate_staleness(staleness, cohorts: np.ndarray) -> np.ndarray:
    """Validate per-report staleness aligned with ``cohorts``: int32,
    same shape, and ``0 <= tau <= t`` on live rows — a report cannot
    predate its own dispatch or round 0, and the engine's params ring
    only holds snapshots of rounds that already ran."""
    staleness = np.asarray(staleness, np.int32)
    if staleness.shape != cohorts.shape:
        raise ValueError(f"staleness must match cohorts shape "
                         f"{cohorts.shape}; got {staleness.shape}")
    t = np.arange(cohorts.shape[0], dtype=np.int64)[:, None]
    live = cohorts[:, :1] >= 0
    if np.any(staleness < 0) or np.any((staleness > t) & live):
        raise ValueError("staleness must satisfy 0 <= tau <= t on every "
                         "live row: a report cannot be older than the "
                         "run itself (the params ring only holds rounds "
                         "that already executed)")
    return staleness


def validate_faults(faults, cohorts: np.ndarray) -> np.ndarray:
    """Validate a per-report fault-mark array aligned with ``cohorts``:
    int8, same shape, codes in ``{0..3}`` (see ``repro.fl.faults``), and
    no mark on a dead (all--1) row — a fault belongs to a report, and a
    dead row has none."""
    faults = np.asarray(faults, np.int8)
    if faults.shape != cohorts.shape:
        raise ValueError(f"faults must match cohorts shape "
                         f"{cohorts.shape}; got {faults.shape}")
    if faults.min(initial=0) < 0 or faults.max(initial=0) > 3:
        raise ValueError("fault marks must be codes in {0..3} "
                         "(OK/CRASH/NAN/EXPLODE — repro.fl.faults)")
    dead = cohorts[:, 0] < 0
    if np.any(faults[dead]):
        raise ValueError("a dead (all--1) cohort row cannot carry fault "
                         "marks — there is no report to poison")
    return faults


# ------------------------------------------------------------- plan ------

@dataclass(frozen=True)
class SchedulePlan:
    """A resolved, validated schedule — what ``run_scanned`` actually
    consumes.  ``staleness is None`` means SYNCHRONOUS (today's engine,
    raw-array path bit-for-bit); otherwise the buffered-async engine
    runs with a params ring of ``window`` snapshots and aggregation
    weights damped by ``(1 + tau) ** -weight_pow``.

    ``faults`` (optional int8 [rounds, S], codes from ``repro.fl.
    faults``) marks each report's injected fault; its presence routes
    the scanned engines through the QUARANTINE round body (an all-zero
    mask still compiles the quarantined graph — that is the fault
    engine's zero-fault configuration, contract-equal to the plain
    engine).  ``n_failed``/``n_retried`` are the event process's
    host-side per-round counters (timeout deaths / re-dispatches of
    previously-dead clients) — the engine surfaces them through the
    metrics path next to the in-graph ``n_rejected``."""
    cohorts: np.ndarray | None    # int32 [rounds, S]; None => in-graph draw
    staleness: np.ndarray | None  # int32 [rounds, S]; None => synchronous
    s: int
    scheduled: bool
    window: int = 0               # params-ring length; 0 => synchronous
    weight_pow: float = 0.0
    faults: np.ndarray | None = None      # int8 [rounds, S]
    n_failed: np.ndarray | None = None    # int32 [rounds]
    n_retried: np.ndarray | None = None   # int32 [rounds]
    norm_clip: float = float("inf")       # quarantine update-norm bound

    @property
    def is_async(self) -> bool:
        return self.staleness is not None

    @property
    def has_faults(self) -> bool:
        return self.faults is not None


@dataclass(frozen=True)
class BuiltSchedule:
    """The rich return type of a fault-aware ``CohortSchedule.build`` —
    everything :func:`resolve` needs beyond the classic ``(cohorts,
    staleness)`` pair.  Plain schedules keep returning arrays/tuples;
    :func:`resolve` accepts either."""
    cohorts: np.ndarray
    staleness: np.ndarray | None = None
    faults: np.ndarray | None = None      # int8 [rounds, S]
    n_failed: np.ndarray | None = None    # int32 [rounds]
    n_retried: np.ndarray | None = None   # int32 [rounds]


def resolve(spec, *, rounds: int, n: int,
            sample_clients: int = 0) -> SchedulePlan:
    """Resolve ``run_scanned``'s ``cohorts=`` argument — ``None``, a raw
    host array, or any :class:`CohortSchedule` — into a validated
    :class:`SchedulePlan`.  The raw-array path produces exactly the plan
    an :class:`ArraySchedule` wrapping the same array would (bit-for-bit
    contract, tested)."""
    if spec is None:
        s = sample_clients if 0 < sample_clients < n else n
        return SchedulePlan(cohorts=None, staleness=None, s=s,
                            scheduled=False)
    faults = n_failed = n_retried = None
    if isinstance(spec, CohortSchedule):
        built = spec.build(n, rounds)
        if isinstance(built, BuiltSchedule):
            cohorts, stale = built.cohorts, built.staleness
            faults = built.faults
            n_failed, n_retried = built.n_failed, built.n_retried
        elif isinstance(built, tuple):
            cohorts, stale = built
        else:
            cohorts, stale = built, None
    else:
        cohorts, stale = spec, None
    cohorts = validate_cohorts(cohorts, rounds, n)
    s = int(cohorts.shape[1])
    if faults is not None:
        faults = validate_faults(faults, cohorts)
    if n_failed is not None:
        n_failed = np.asarray(n_failed, np.int32).reshape(rounds)
    if n_retried is not None:
        n_retried = np.asarray(n_retried, np.int32).reshape(rounds)
    clip = float(getattr(spec, "norm_clip", float("inf")))
    wpow = float(getattr(spec, "weight_pow", 0.0) or 0.0)
    if stale is None:
        return SchedulePlan(cohorts=cohorts, staleness=None, s=s,
                            scheduled=True, faults=faults,
                            n_failed=n_failed, n_retried=n_retried,
                            norm_clip=clip)
    stale = validate_staleness(stale, cohorts)
    live = cohorts[:, 0] >= 0
    window = int(stale[live].max(initial=0)) + 1 if live.any() else 1
    return SchedulePlan(
        cohorts=cohorts, staleness=stale, s=s, scheduled=True,
        window=window, weight_pow=wpow, faults=faults,
        n_failed=n_failed, n_retried=n_retried, norm_clip=clip)


# --------------------------------------------------------- schedules -----

class CohortSchedule:
    """Protocol for cohort generators.  ``build(n, rounds)`` returns a
    host ``[rounds, S]`` int array (rows sorted unique, all -1 = empty
    round) — or a ``(cohorts, staleness)`` pair for buffered-async
    schedules.  :func:`resolve` validates whatever comes back, so a
    schedule never needs to re-implement the contract checks.  A
    ``weight_pow`` attribute (default 0.0) requests engine-level
    staleness weight damping."""

    weight_pow: float = 0.0

    def build(self, n: int, rounds: int):
        raise NotImplementedError


@dataclass(frozen=True)
class ArraySchedule(CohortSchedule):
    """A pre-built cohort array behind the protocol.  Resolving this is
    identical to passing the raw array straight to ``run_scanned``."""
    cohorts: object

    def build(self, n: int, rounds: int):
        return np.asarray(self.cohorts, np.int32)


@dataclass(frozen=True)
class SampledSchedule(CohortSchedule):
    """Seeded host-side uniform sampler: ``s`` unique clients per round
    from a ``np.random.default_rng(seed)`` stream — reproducible cohorts
    without the caller materializing the array by hand.  (Distinct from
    ``sample_clients=``'s IN-GRAPH draw: that one is keyed by the run's
    jax rng and stays the scanned engine's default.)"""
    s: int
    seed: int = 0

    def build(self, n: int, rounds: int):
        if not 0 < self.s <= n:
            raise ValueError(f"SampledSchedule needs 0 < s <= n; "
                             f"got s={self.s}, n={n}")
        rng = np.random.default_rng(self.seed)
        return np.stack([
            np.sort(rng.choice(n, size=self.s, replace=False))
            for _ in range(rounds)]).astype(np.int32)


# availability traces: name -> fn(n, rounds, s, seed, **kw) -> cohorts
TRACES: dict = {}


def register_trace(name: str):
    """Register an availability-trace generator under ``name`` (used via
    :func:`trace`).  The fn signature is
    ``fn(n, rounds, s, seed, **kw) -> [rounds, S] host int array``."""
    def deco(fn):
        if name in TRACES:
            raise ValueError(f"trace {name!r} already registered")
        TRACES[name] = fn
        return fn
    return deco


@dataclass(frozen=True)
class TraceSchedule(CohortSchedule):
    name: str
    s: int
    seed: int = 0
    kwargs: tuple = ()   # sorted (key, value) pairs — keeps the dataclass hashable

    def build(self, n: int, rounds: int):
        return TRACES[self.name](n, rounds, self.s, self.seed,
                                 **dict(self.kwargs))


def trace(name: str, s: int, *, seed: int = 0, **kw) -> TraceSchedule:
    """A registered availability trace as a :class:`CohortSchedule`:
    ``trace("diurnal", s=8, seed=3, period=24)``."""
    if name not in TRACES:
        raise ValueError(f"unknown trace {name!r}; registered: "
                         f"{sorted(TRACES)}")
    return TraceSchedule(name=name, s=s, seed=seed,
                         kwargs=tuple(sorted(kw.items())))


@register_trace("diurnal")
def _diurnal(n, rounds, s, seed, *, period: int = 24, duty: float = 0.5):
    """Diurnal availability: client ``c`` is online at round ``t`` when
    its phase-shifted day cycle ``sin(2pi (t / period + c / n))`` is in
    the top ``duty`` fraction of the cycle.  Cohorts draw uniformly from
    the online pool; when fewer than ``s`` clients are online the round
    is a quorum loss (all -1, skipped by the engine)."""
    rng = np.random.default_rng(seed)
    rows = np.full((rounds, s), -1, np.int32)
    phase = np.arange(n) / n
    thresh = np.sin(np.pi * (0.5 - duty))   # top `duty` of a sine cycle
    for t in range(rounds):
        online = np.flatnonzero(
            np.sin(2 * np.pi * (t / period + phase)) >= thresh)
        if online.size >= s:
            rows[t] = np.sort(rng.choice(online, size=s, replace=False))
    return rows


@register_trace("dropout_midround")
def _dropout_midround(n, rounds, s, seed, *, drop_prob: float = 0.15):
    """Mid-round dropout: a cohort is drawn every round, but with
    probability ``drop_prob`` it loses quorum before reporting and the
    whole round aborts (all -1).  Fixed-width cohort rows cannot express
    a PARTIAL cohort — modeling per-client dropout inside a round needs
    the buffered-async engine (the dropped client simply never reports);
    this trace covers the all-or-nothing failure mode the sync engine
    can express."""
    rng = np.random.default_rng(seed)
    rows = np.full((rounds, s), -1, np.int32)
    for t in range(rounds):
        if rng.random() >= drop_prob:
            rows[t] = np.sort(rng.choice(n, size=s, replace=False))
    return rows


# ----------------------------------------------------- buffered async ----

# report time of a dispatch that will NEVER report (an injected crash)
NEVER = np.iinfo(np.int64).max


def buffered_events(n: int, rounds: int, *, goal: int, concurrency: int,
                    lo: int, hi: int, rng, timeout: int = 0,
                    max_retries: int = 0,
                    fault_sampler=None) -> BuiltSchedule:
    """THE buffered-async event process — one implementation serving
    both :class:`BufferedSchedule` (no faults) and ``repro.fl.faults.
    FaultModel`` (fault hooks), so a zero-fault fault model replays the
    plain schedule's rng stream exactly.

    ``fault_sampler(client, t) -> (crashed, extra_delay, fault_code)``
    is consulted once per dispatch (from its OWN rng stream — the
    schedule's delay/choice draws here are untouched by its presence):
    a crashed dispatch never reports (report time :data:`NEVER`), an
    ``extra_delay`` stretches the completion (straggler), and a nonzero
    ``fault_code`` marks the eventual flushed report for in-graph
    corruption + quarantine.

    ``timeout`` (0 = disabled) bounds how long a dispatch may stay in
    flight: at the start of round ``t`` every in-flight entry with
    ``t - dispatch_t > timeout`` is declared DEAD — its concurrency
    slot is freed and the client becomes eligible for re-dispatch
    (bounded by ``max_retries`` deaths per client; past the bound the
    client is abandoned).  Without a timeout a dispatch that never
    reports leaks its slot forever — the failure mode this fixes.

    Conservation is asserted at every round (the host boundary):
    ``dispatched == flushed + busy + dead`` where busy counts in-flight
    entries plus buffered-but-unflushed reports (a client is busy from
    dispatch until flush or death).
    """
    rows = np.full((rounds, goal), -1, np.int32)
    taus = np.zeros((rounds, goal), np.int32)
    marks = np.zeros((rounds, goal), np.int8)
    n_failed = np.zeros(rounds, np.int32)
    n_retried = np.zeros(rounds, np.int32)
    free = np.ones(n, bool)
    deaths = np.zeros(n, np.int32)      # timeout deaths per client
    retry_due = np.zeros(n, bool)       # last dispatch died → next is a retry
    inflight: list = []   # (report_t, seq, client, dispatch_t, fault_code)
    buffer: list = []     # (client, dispatch_t, fault_code), FIFO
    pending, seq = concurrency, 0
    dispatched = flushed = dead = 0
    for t in range(rounds):
        # ---- timeouts: in-flight entries past the deadline are dead ----
        if timeout:
            late = [e for e in inflight if t - e[3] > timeout]
            if late:
                inflight = [e for e in inflight if t - e[3] <= timeout]
                for (_, _, c, _, _) in late:
                    deaths[c] += 1
                    dead += 1
                    pending += 1          # the concurrency slot is freed
                    if deaths[c] <= max_retries:
                        free[c] = True    # eligible for re-dispatch
                        retry_due[c] = True
                    # else: retry budget exhausted — abandoned for good
                n_failed[t] += len(late)
        # ---- dispatch replacements for flushed/dead slots --------------
        k = min(pending, int(free.sum()))
        if k:
            chosen = rng.choice(np.flatnonzero(free), size=k,
                                replace=False)
            for c in chosen:
                d = int(rng.integers(lo, hi + 1)) if hi > lo else lo
                crashed, extra, code = (
                    fault_sampler(int(c), t) if fault_sampler is not None
                    else (False, 0, 0))
                report = NEVER if crashed else t + d + int(extra)
                inflight.append((report, seq, int(c), t, int(code)))
                seq += 1
                if retry_due[c]:
                    retry_due[c] = False
                    n_retried[t] += 1
            free[chosen] = False
            pending -= k
            dispatched += k
        # ---- arrivals: completed reports enter the buffer FIFO ---------
        done = sorted(e for e in inflight if e[0] <= t)
        if done:
            inflight = [e for e in inflight if e[0] > t]
            buffer.extend((c, t0, code) for (_, _, c, t0, code) in done)
        # ---- at most one goal-sized flush per round --------------------
        if len(buffer) >= goal:
            batch, buffer = buffer[:goal], buffer[goal:]
            ids = np.fromiter((c for c, _, _ in batch), np.int32)
            age = np.fromiter((t - t0 for _, t0, _ in batch), np.int32)
            mk = np.fromiter((m for _, _, m in batch), np.int8)
            order = np.argsort(ids)
            rows[t], taus[t], marks[t] = ids[order], age[order], mk[order]
            free[ids] = True
            pending += goal
            flushed += goal
        # ---- conservation invariant (host boundary) --------------------
        busy = len(inflight) + len(buffer)
        if dispatched != flushed + busy + dead:
            raise AssertionError(
                f"event-process conservation violated at round {t}: "
                f"dispatched={dispatched} != flushed={flushed} + "
                f"busy={busy} + dead={dead}")
    return BuiltSchedule(
        cohorts=rows, staleness=taus,
        faults=marks if fault_sampler is not None else None,
        n_failed=n_failed, n_retried=n_retried)


@dataclass(frozen=True)
class BufferedSchedule(CohortSchedule):
    """FedBuff-style buffered-async arrival process, resolved host-side.

    ``concurrency`` clients are in flight at any time; a dispatch at
    round ``t0`` completes after ``delay`` rounds (an int, or an
    inclusive ``(lo, hi)`` range sampled per dispatch) and its report
    joins a FIFO buffer; a round with ``goal`` buffered reports flushes
    them as ONE cohort row with per-report staleness ``t - t0``, frees
    those clients, and dispatches replacements the next round.  A client
    is busy from dispatch until flush, so a flush row never repeats an
    id.  Rounds that flush nothing are all--1 rows.

    ``timeout`` (0 = disabled, the historical behavior) declares any
    dispatch still unreported after ``timeout`` rounds DEAD: its
    concurrency slot is freed and the client re-enters the dispatch
    pool, up to ``max_retries`` deaths per client (then it is abandoned
    — a permanently-lost device).  The event process counts per-round
    ``n_failed`` (deaths) and ``n_retried`` (re-dispatches of
    previously-dead clients) and enforces the conservation invariant
    ``dispatched == flushed + busy + dead`` every round; see
    :func:`buffered_events`.

    ``build`` returns ``(cohorts, staleness)`` when ``timeout == 0``
    (the legacy contract, bit-identical arrays) and a
    :class:`BuiltSchedule` carrying the counters otherwise;
    :func:`resolve` sizes the engine's params ring at
    ``max(staleness) + 1`` either way.  With ``delay=0,
    concurrency=goal`` this degenerates to one fresh zero-staleness
    cohort per round — the sync-equivalence configuration.
    """
    goal: int
    concurrency: int
    delay: object = 0       # int, or inclusive (lo, hi) tuple
    seed: int = 0
    weight_pow: float = 0.0
    timeout: int = 0        # rounds in flight before a dispatch is dead
    max_retries: int = 0    # re-dispatch budget per client after deaths

    def _validate(self, n: int) -> tuple[int, int]:
        if self.goal < 1:
            raise ValueError(f"goal must be >= 1; got {self.goal}")
        if self.concurrency < self.goal:
            raise ValueError(
                f"concurrency ({self.concurrency}) < goal ({self.goal}): "
                "the buffer can never reach the flush size")
        if self.concurrency > n:
            raise ValueError(f"concurrency ({self.concurrency}) exceeds "
                             f"the population n={n}")
        lo, hi = ((int(self.delay), int(self.delay))
                  if np.isscalar(self.delay) else
                  (int(self.delay[0]), int(self.delay[1])))
        if lo < 0 or hi < lo:
            raise ValueError(f"delay must be >= 0 (int or (lo, hi) with "
                             f"lo <= hi); got {self.delay}")
        if self.timeout < 0 or self.max_retries < 0:
            raise ValueError(f"timeout/max_retries must be >= 0; got "
                             f"{self.timeout}/{self.max_retries}")
        return lo, hi

    def build(self, n: int, rounds: int):
        lo, hi = self._validate(n)
        built = buffered_events(
            n, rounds, goal=self.goal, concurrency=self.concurrency,
            lo=lo, hi=hi, rng=np.random.default_rng(self.seed),
            timeout=self.timeout, max_retries=self.max_retries)
        if self.timeout == 0:
            # legacy return contract (and zero extra rng draws above):
            # timeout-free builds stay bit-identical to the PR 8 arrays
            return built.cohorts, built.staleness
        return built

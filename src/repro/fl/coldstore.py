"""The DISK rung of the ClientStore residency ladder: mmap cold tiers.

``repro.fl.store`` moved per-client rows from device to host numpy; this
module moves them one tier further out, onto disk, behind the SAME
``gather``/``scatter``/``prefetch`` protocol — at N = 10⁶ clients even
the host-numpy cold bank (state rows + per-client index tables) outgrows
RAM, while a chunk of rounds still touches only ``cap = chunk · S`` hot
rows.  Two classes, each subclassing its host-tier twin so every
contract in ``repro.fl.store`` holds verbatim one tier further out:

* :class:`MmapStateStore` — client-state rows in one ``np.memmap`` file
  per flattened leaf.  ``gather`` reads only the requested rows' pages
  (through a reusable pinned host staging buffer on accelerator
  backends), ``scatter``/``scatter_async`` dirty only the written rows'
  pages, and an all-zero init state (``broadcast`` of zeros — SCAFFOLD
  control variates, momenta) creates SPARSE files: 10⁶ clients of cold
  state cost ~nothing on disk until rows are actually written.
* :class:`MmapPagedBank` — the data-bank twin: ``x``/``y``/``idx``/
  ``sizes`` are read-only memmaps over a
  :class:`repro.data.streaming.StreamingFederatedDataset`'s files.  The
  staging code path is the HOST bank's (memmaps are ndarray subclasses),
  so a staged chunk is bytewise what the host-paged tier stages — the
  mmap ≡ host-paged ≡ resident equivalence is by construction, not by
  tolerance.  Optional ``boundaries`` turns on bucketing-by-shard-size:
  ragged FEMNIST-style shards stop padding every staged chunk to the
  global max shard length M (see :meth:`MmapPagedBank._stage`).

Lifecycle: cold files are TEMPORARY by default (``tempfile.mkdtemp``)
and owned by the store/bank that created them — a ``weakref.finalize``
removes the directory on garbage collection and at interpreter exit, and
both classes are context managers whose ``close()`` tears the files down
eagerly, so an exception mid-``run_scanned`` cannot leak ``.mmap`` files
past the owning ``with`` block (tests/test_coldstore.py pins this).
Deleting files whose maps are still open is safe on POSIX (the pages
live until unmapped).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import weakref
from dataclasses import dataclass

import jax
import numpy as np

from repro.data.federated import DeviceDataBank, HostPagedBank
from repro.fl.store import HostStateStore, PyTree, _put

__all__ = ["MmapStateStore", "MmapPagedBank"]

#: rows per block when materializing a cold bank (bounds the writer's
#: transient RSS — one block, never the [N, ...] bank)
BLOCK_ROWS = 1 << 14


def _leaf_path(directory: str, i: int) -> str:
    return os.path.join(directory, f"state_leaf{i}.mmap")


class MmapStateStore(HostStateStore):
    """Disk-backed client-state bank: ``HostStateStore`` over memmap
    leaves.

    Same semantics tier-for-tier (it IS a ``HostStateStore`` whose
    ``bank`` leaves are ``np.memmap``): chunk-boundary ``gather`` stages
    hot rows to device, ``scatter``/``scatter_async`` write updated rows
    back in place (dirtying only those rows' pages), ``prefetch`` is
    read-ahead with the in-flight hazard rule, stateless stores hold no
    leaves and page zero bytes — from disk or anywhere else.

    Staging reads go through a PINNED reusable host buffer per (leaf,
    row-count) on accelerator backends (``np.take(leaf, rows, out=buf)``
    collects the cold pages into one contiguous pinned region, then one
    ``device_put`` DMAs it); on the CPU backend the buffer is skipped —
    ``jax.device_put`` may alias host memory there, and a reused aliased
    buffer would corrupt the staged view.  ``_stage`` blocks until the
    H2D copies complete before the buffer can be reused.
    """

    def __init__(self, bank: PyTree, n: int | None = None, *,
                 directory: str | None = None, _owned: bool = False):
        # skip HostStateStore.__init__: its ascontiguousarray
        # normalization would pull every cold leaf into RAM
        self.bank = bank
        leaves = jax.tree.leaves(self.bank)
        self._n = int(leaves[0].shape[0]) if leaves else int(n or 0)
        self._init_runtime()
        self._pin = {} if jax.default_backend() != "cpu" else None
        self.directory = directory
        self._finalizer = (
            weakref.finalize(self, shutil.rmtree, directory,
                             ignore_errors=True)
            if _owned and directory is not None else None)

    @classmethod
    def broadcast(cls, one_client: PyTree, n: int, *,
                  directory: str | None = None) -> "MmapStateStore":
        """Build the ``[N, ...]`` COLD bank from one client's init state.

        One memmap file per flattened leaf under ``directory`` (a fresh
        temp dir when omitted; either way the store owns and finalizes
        the files).  An all-zero init leaf writes NOTHING — ``mode="w+"``
        ftruncates a sparse file of zeros — so zero-init state (the
        common case: control variates, momenta) costs no disk blocks and
        no write pass over N; nonzero init is written in ``BLOCK_ROWS``
        blocks to bound the writer's dirty-page footprint.  A stateless
        tree creates no files and owns no directory."""
        leaves, treedef = jax.tree.flatten(one_client)
        if not leaves:
            return cls(jax.tree.unflatten(treedef, []), n=n)
        owned = True
        if directory is None:
            directory = tempfile.mkdtemp(prefix="repro-coldstate-")
        else:
            os.makedirs(directory, exist_ok=True)
        bank = []
        for i, leaf in enumerate(leaves):
            row = np.ascontiguousarray(np.asarray(leaf))
            mm = np.memmap(_leaf_path(directory, i), dtype=row.dtype,
                           mode="w+", shape=(n, *row.shape))
            if row.size and np.any(row):
                for lo in range(0, n, BLOCK_ROWS):
                    mm[lo:lo + BLOCK_ROWS] = row
                mm.flush()
            bank.append(mm)
        return cls(jax.tree.unflatten(treedef, bank), n=n,
                   directory=directory, _owned=owned)

    def _stage(self, rows: np.ndarray, sharding) -> PyTree:
        if self._pin is None:
            return super()._stage(rows, sharding)
        leaves, treedef = jax.tree.flatten(self.bank)
        staged = []
        for i, leaf in enumerate(leaves):
            key = (i, len(rows))
            buf = self._pin.get(key)
            if buf is None:
                buf = self._pin[key] = np.empty(
                    (len(rows), *leaf.shape[1:]), leaf.dtype)
            np.take(leaf, rows, axis=0, out=buf)
            staged.append(_put(buf, sharding))
        staged = jax.tree.unflatten(treedef, staged)
        # the H2D copies must finish before the next stage reuses a buffer
        jax.block_until_ready(staged)
        return staged

    def disk_bytes(self) -> int:
        """Logical cold bytes on disk (sparse holes count as data —
        this is the RESIDENT-equivalent size, what the tier keeps off
        host and device)."""
        return self.host_bytes()

    def copy(self) -> "MmapStateStore":
        """Deep copy onto a NEW set of cold files (same tier — branching
        a 10⁶-client bank must not materialize it in RAM)."""
        self.fence()
        leaves, treedef = jax.tree.flatten(self.bank)
        if not leaves:
            return MmapStateStore(jax.tree.unflatten(treedef, []),
                                  n=self._n)
        directory = tempfile.mkdtemp(prefix="repro-coldstate-")
        out = []
        for i, leaf in enumerate(leaves):
            mm = np.memmap(_leaf_path(directory, i), dtype=leaf.dtype,
                           mode="w+", shape=leaf.shape)
            for lo in range(0, leaf.shape[0], BLOCK_ROWS):
                mm[lo:lo + BLOCK_ROWS] = leaf[lo:lo + BLOCK_ROWS]
            mm.flush()
            out.append(mm)
        return MmapStateStore(jax.tree.unflatten(treedef, out), n=self._n,
                              directory=directory, _owned=True)

    def close(self) -> None:
        """Drain pending writes, then delete the store's files (idempotent;
        also runs via ``weakref.finalize`` at gc/interpreter exit)."""
        self.fence()
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        self._cache.clear()
        if self._finalizer is not None:
            self._finalizer()

    def __enter__(self) -> "MmapStateStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


@dataclass
class MmapPagedBank(HostPagedBank):
    """Disk-backed federated data bank: ``HostPagedBank`` over memmaps.

    Built by :meth:`repro.data.streaming.StreamingFederatedDataset.
    mmap_bank` (or the :meth:`repro.data.federated.FederatedDataset.
    mmap_bank` convenience): ``x``/``y``/``idx``/``sizes`` are read-only
    ``np.memmap`` views over the dataset's on-disk files, and staging is
    the inherited host-tier code path — ``idx[rows]`` faults in only the
    touched index pages, the ``x[take]`` fancy-gather reads only the
    union's sample pages, and the staged ``DeviceDataBank`` is bytewise
    the host-paged tier's.  ``state_store`` pairs the matching state
    tier so ``FedSim.init`` keeps the whole cold side on disk.

    ``boundaries`` (sorted ints, last ≥ the global max shard length M)
    turns on bucketing-by-shard-size: a staged chunk's ``[U, M]`` index
    rows are TRIMMED to the smallest boundary covering the union's max
    TRUE shard size, so a chunk of small FEMNIST-style shards stops
    staging (and paying H2D for) the global-max padding.  Trimming is
    value-invariant — cyclic-pad positions at or past a client's true
    size are never sampled (``batch > 0`` draws below ``sizes``;
    ``batch == 0`` slices ``[:min_size]``) — but it changes the staged
    M, which keys one compiled chunk program per bucket.  It is
    therefore OFF by default: the bitwise mmap ≡ resident contract pins
    the staged M to the resident bank's.

    ``directory`` non-None means the bank OWNS that directory (it was
    materialized for this bank): ``close()``/gc/interpreter-exit remove
    it, including any paired state stores placed under it.  A bank
    opened over a persistent dataset passes ``directory=None`` and
    ``close()`` is a cache drop.
    """
    boundaries: tuple | None = None
    directory: str | None = None

    def __post_init__(self):
        super().__post_init__()
        if self.boundaries is not None:
            bs = tuple(int(b) for b in self.boundaries)
            if not bs or list(bs) != sorted(set(bs)):
                raise ValueError("boundaries must be sorted unique ints; "
                                 f"got {self.boundaries!r}")
            m = int(self.idx.shape[1])
            if bs[-1] < m:
                raise ValueError(f"last bucket boundary {bs[-1]} does not "
                                 f"cover the max shard length M={m}")
            self.boundaries = bs
        self._finalizer = (
            weakref.finalize(self, shutil.rmtree, self.directory,
                             ignore_errors=True)
            if self.directory is not None else None)

    def _stage(self, rows, sharding) -> DeviceDataBank:
        if self.boundaries is None:
            return super()._stage(rows, sharding)
        rows = np.asarray(rows)
        sizes = np.asarray(self.sizes[rows])
        need = int(sizes.max(initial=1))
        if self.spec.batch == 0:
            need = max(need, self.spec.min_size)
        m = next(b for b in self.boundaries if b >= need)
        take = np.asarray(self.idx[rows])[:, :m]
        put = ((lambda a: jax.device_put(a, sharding))
               if sharding is not None else jax.numpy.asarray)
        return DeviceDataBank(x=put(self.x[take]), y=put(self.y[take]),
                              sizes=put(sizes), spec=self.spec)

    def state_store(self, one_client: PyTree, n: int) -> MmapStateStore:
        """The matching STATE tier (``FedSim.init`` calls this): a
        :class:`MmapStateStore` whose files live under this bank's
        directory when the bank owns one — one ``close()`` tears down
        the whole cold tier — else in their own temp dir (finalized
        independently).  Stateless trees create no files at all."""
        if not jax.tree.leaves(one_client):
            return MmapStateStore.broadcast(one_client, n)
        directory = (tempfile.mkdtemp(prefix="state-", dir=self.directory)
                     if self.directory is not None else None)
        return MmapStateStore.broadcast(one_client, n, directory=directory)

    def close(self) -> None:
        """Drop staged caches and delete owned files (idempotent; also
        runs via ``weakref.finalize`` at gc/interpreter exit)."""
        self._cache.clear()
        if self._finalizer is not None:
            self._finalizer()

    def __enter__(self) -> "MmapPagedBank":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""Client data partitioning (paper Sec 4.2 / Appendix C.2).

Dirichlet heterogeneity follows Vogels et al. 2021: for each class, sample a
distribution over clients ~ Dir(α) and scatter that class's samples
accordingly.  α = 0.1 → strongly heterogeneous, α = 1.0 → mild.
"""
from __future__ import annotations

import numpy as np


def even_partition(n_samples: int, n_clients: int, rng: np.random.Generator):
    """Homogeneous split (Test 1 setup): shuffle, equal shards."""
    idx = rng.permutation(n_samples)
    per = n_samples // n_clients
    return [idx[i * per:(i + 1) * per] for i in range(n_clients)]


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        rng: np.random.Generator, min_per_client: int = 2):
    """Per-class Dirichlet scatter. Returns a list of index arrays (ragged —
    clients hold different sample counts, as in the paper's Fig. 4)."""
    n_classes = int(labels.max()) + 1
    shards: list[list[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idx, cuts)):
            shards[cid].extend(part.tolist())
    # guarantee a floor so every client can form a batch
    pool = [i for s in shards for i in s]
    out = []
    for s in shards:
        if len(s) < min_per_client:
            need = min_per_client - len(s)
            s = s + list(rng.choice(pool, size=need, replace=False))
        arr = np.asarray(s)
        rng.shuffle(arr)
        out.append(arr)
    return out


def client_label_histogram(labels: np.ndarray, shards) -> np.ndarray:
    """[n_clients, n_classes] counts — the paper's Fig. 4 visualization."""
    n_classes = int(labels.max()) + 1
    return np.stack([np.bincount(labels[s], minlength=n_classes)
                     for s in shards])

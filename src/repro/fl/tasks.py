"""Task adapters binding models to the algorithm interface.

A *task* exposes exactly what the registry's LocalUpdate solvers
(``repro.core.api``) consume:
    loss_grad(params, batch) -> (loss, grads)
    grams(params, batch)     -> FOOF gram tree       (any solver composed
                                with a preconditioned mixer — foof, or the
                                sgd-family's lazy ``grams`` wire field)
    hessian(params, batch)   -> [d, d]               (flat convex only)

Tasks optionally carry a RESIDENT federated data bank (``data``, a
:class:`repro.data.federated.DeviceDataBank`): ``sample_batches(rng,
participants)`` then draws per-round client batches entirely in-graph —
the data path ``FedSim.run_scanned`` scans over, so synthetic/FEMNIST-class
workloads never leave the device between evals.  ``with_data`` attaches a
bank to an existing task.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.simple import (CNNModel, LogisticModel, MLPModel,
                                 ce_loss_and_grams)


class _DataBankMixin:
    """``sample_batches`` for tasks that carry a resident data bank."""

    def with_data(self, bank):
        """A copy of this task with the resident data bank attached."""
        return dataclasses.replace(self, data=bank)

    def sample_batches(self, rng, participants):
        """In-graph [S, K, B, ...] batches for the cohort ``participants``
        (scan-safe: pure jax.random draws from the resident bank)."""
        if self.data is None:
            raise ValueError(
                f"{type(self).__name__} has no resident data bank; build "
                "one with FederatedDataset.device_bank(...) and attach it "
                "via task.with_data(bank) to use the scanned driver")
        return self.data.sample(rng, participants)


@dataclass(frozen=True)
class ConvexTask(_DataBankMixin):
    """Test 1: logistic regression with analytic grad/Hessian, flat θ ∈ R^d."""
    model: LogisticModel
    data: Any = None                  # optional resident DeviceDataBank

    def init(self, rng):
        return self.model.init(rng)

    def loss_grad(self, theta, batch):
        return self.model.loss(theta, batch), self.model.grad(theta, batch)

    def hessian(self, theta, batch):
        return self.model.hessian(theta, batch)

    def grams(self, theta, batch):
        # full-Hessian task: "gram" IS the Hessian (used by foof-path tests)
        return self.model.hessian(theta, batch)[None]   # [1, d, d] one block

    def metric(self, theta, batch):
        return self.model.accuracy(theta, batch)


@dataclass(frozen=True)
class DNNTask(_DataBankMixin):
    """Test 2: MLP / CNN classification with FOOF grams."""
    model: Any   # MLPModel | CNNModel
    data: Any = None                  # optional resident DeviceDataBank

    def init(self, rng):
        return self.model.init(rng)

    def loss_grad(self, params, batch):
        def lf(p):
            loss, _ = ce_loss_and_grams(self.model, p, batch)
            return loss
        return jax.value_and_grad(lf)(params)

    def grams(self, params, batch):
        _, grams = ce_loss_and_grams(self.model, params, batch, collect=True)
        return grams

    def hessian(self, params, batch):
        raise NotImplementedError("full Hessian only for the convex task")

    def metric(self, params, batch):
        logits, _ = self.model.apply(params, batch["x"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))

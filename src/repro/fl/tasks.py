"""Task adapters binding models to the algorithm interface.

A *task* exposes exactly what algorithms consume:
    loss_grad(params, batch) -> (loss, grads)
    grams(params, batch)     -> FOOF gram tree       (SOPM/foof methods)
    hessian(params, batch)   -> [d, d]               (flat convex only)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.simple import (CNNModel, LogisticModel, MLPModel,
                                 ce_loss_and_grams)


@dataclass(frozen=True)
class ConvexTask:
    """Test 1: logistic regression with analytic grad/Hessian, flat θ ∈ R^d."""
    model: LogisticModel

    def init(self, rng):
        return self.model.init(rng)

    def loss_grad(self, theta, batch):
        return self.model.loss(theta, batch), self.model.grad(theta, batch)

    def hessian(self, theta, batch):
        return self.model.hessian(theta, batch)

    def grams(self, theta, batch):
        # full-Hessian task: "gram" IS the Hessian (used by foof-path tests)
        return self.model.hessian(theta, batch)[None]   # [1, d, d] one block

    def metric(self, theta, batch):
        return self.model.accuracy(theta, batch)


@dataclass(frozen=True)
class DNNTask:
    """Test 2: MLP / CNN classification with FOOF grams."""
    model: Any   # MLPModel | CNNModel

    def init(self, rng):
        return self.model.init(rng)

    def loss_grad(self, params, batch):
        def lf(p):
            loss, _ = ce_loss_and_grams(self.model, p, batch)
            return loss
        return jax.value_and_grad(lf)(params)

    def grams(self, params, batch):
        _, grams = ce_loss_and_grams(self.model, params, batch, collect=True)
        return grams

    def hessian(self, params, batch):
        raise NotImplementedError("full Hessian only for the convex task")

    def metric(self, params, batch):
        logits, _ = self.model.apply(params, batch["x"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))

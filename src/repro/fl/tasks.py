"""Task adapters binding models to the algorithm interface.

A *task* exposes exactly what the registry's LocalUpdate solvers
(``repro.core.api``) consume:
    loss_grad(params, batch) -> (loss, grads)
    grams(params, batch)     -> FOOF gram tree       (any solver composed
                                with a preconditioned mixer — foof, or the
                                sgd-family's lazy ``grams`` wire field)
    hessian(params, batch)   -> [d, d]               (flat convex only)

Tasks optionally carry a federated data store (``data``, any
:class:`repro.fl.store.ClientStore` data bank).  With the RESIDENT
:class:`repro.data.federated.DeviceDataBank`, ``sample_batches(rng,
participants)`` draws per-round client batches entirely in-graph — the
data path ``FedSim.run_scanned`` scans over, so synthetic/FEMNIST-class
workloads never leave the device between evals.  With the PAGED
:class:`repro.data.federated.HostPagedBank`, the task holds only the
host-side store; the engine stages hot cohort rows per chunk and samples
from the staged views (``sample_batches`` on the paged store itself is a
contract error — there is nothing resident to draw from).  ``with_data``
attaches either store to an existing task.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.simple import (CNNModel, LogisticModel, MLPModel,
                                 ce_loss_and_grams)


class _DataBankMixin:
    """``with_data``/``sample_batches`` for tasks that carry a data store."""

    def with_data(self, bank):
        """A copy of this task with a data store attached (resident
        ``DeviceDataBank`` or paged ``HostPagedBank``)."""
        return dataclasses.replace(self, data=bank)

    def sample_batches(self, rng, participants):
        """In-graph [S, K, B, ...] batches for the cohort ``participants``
        (scan-safe: pure jax.random draws from a RESIDENT bank — the
        engine samples paged data from its staged chunk views instead)."""
        if self.data is None:
            raise ValueError(
                f"{type(self).__name__} has no data bank; build one with "
                "FederatedDataset.device_bank(...) (or .paged_bank) and "
                "attach it via task.with_data(bank) to use the scanned "
                "driver")
        if not getattr(self.data, "is_resident", True):
            raise ValueError(
                "sample_batches draws from a RESIDENT bank; this task "
                "holds a paged store — the engine samples from its staged "
                "chunk views (bank.gather(rows).sample(...))")
        return self.data.sample(rng, participants)


@dataclass(frozen=True)
class ConvexTask(_DataBankMixin):
    """Test 1: logistic regression with analytic grad/Hessian, flat θ ∈ R^d."""
    model: LogisticModel
    data: Any = None                  # optional ClientStore data bank

    def init(self, rng):
        return self.model.init(rng)

    def loss_grad(self, theta, batch):
        return self.model.loss(theta, batch), self.model.grad(theta, batch)

    def hessian(self, theta, batch):
        return self.model.hessian(theta, batch)

    def grams(self, theta, batch):
        # full-Hessian task: "gram" IS the Hessian (used by foof-path tests)
        return self.model.hessian(theta, batch)[None]   # [1, d, d] one block

    def metric(self, theta, batch):
        return self.model.accuracy(theta, batch)


@dataclass(frozen=True)
class DNNTask(_DataBankMixin):
    """Test 2: MLP / CNN classification with FOOF grams."""
    model: Any   # MLPModel | CNNModel
    data: Any = None                  # optional ClientStore data bank

    def init(self, rng):
        return self.model.init(rng)

    def loss_grad(self, params, batch):
        def lf(p):
            loss, _ = ce_loss_and_grams(self.model, p, batch)
            return loss
        return jax.value_and_grad(lf)(params)

    def grams(self, params, batch):
        _, grams = ce_loss_and_grams(self.model, params, batch, collect=True)
        return grams

    def hessian(self, params, batch):
        raise NotImplementedError("full Hessian only for the convex task")

    def metric(self, params, batch):
        logits, _ = self.model.apply(params, batch["x"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))

"""Mesh-sharded client banks for the simulation engine.

The vmap engine (``repro.fl.simulate``) keeps the full stacked client-state
bank ``[N, ...]`` on ONE device — its per-round memory wall.  This module
places the bank (and the per-client batch bank) on a 1-D ``("clients",)``
mesh axis and runs the gather/compute/scatter round as shard_map over the
client shards, so per-device bank memory is N / n_shards and every future
async/streaming cohort PR can build on the same seam.

Contract (oracle: the vmap engine, bitwise-tolerant in fp32 mixing):

* **bucketing** — participants are pre-bucketed per shard HOST-side
  (:func:`bucket_participants`): client ``c`` lives on shard
  ``c // shard_n`` at local row ``c % shard_n``.  Buckets are padded to a
  capacity that is a static function of the cohort size S only
  (``min(S, shard_n)``), so the jit cache keys once per cohort size, not
  per random cohort.  Padding slots carry weight 0, a clipped position,
  and the out-of-range local id ``shard_n``.
* **gather** — each shard ``jnp.take``s its local participants' states
  (and batch rows) from its bank shard; padded slots (sentinel id
  ``shard_n``) clamp to the shard's LAST row and compute throwaway work
  that cannot poison aggregation (weight 0) or state (scatter drop).
* **compute** — vmap over the ≤ cap local participants per shard; client
  rngs are ``split(rng, S)`` indexed by participant position, identical
  to the vmap engine's per-participant keys.
* **aggregate** — server fns run replicated per shard on the LOCAL
  message bucket with ``Participation(weights, n_total, axes=("clients",))``:
  weighted means become per-shard partial reductions + one cross-shard
  psum (one per block-size group through the packed
  ``mix_preconditioned`` bank — the GramBank's row axis stays sharded
  with the participants; no per-leaf walks).
* **scatter** — shard-local ``.at[idx].set(..., mode="drop")``: padded
  slots write nowhere, non-participants (on any shard) are bit-untouched.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import api as API
from repro.core.algorithms import Participation
from repro.distributed.axes import CLIENTS_AXIS, make_client_mesh, shard_map
from repro.fl import faults as FLT
from repro.fl.simulate import round_metrics
# staged_host_rows is the scatter-overlap hook for mesh staging: the
# write-behind drain (repro.fl.store.HostStateStore.scatter_async)
# assembles a staged chunk's updated rows back on host SHARD-BY-SHARD —
# each addressable shard D2H-copies its own staging_sharding slice, so
# the background thread never dispatches a compiled slice/gather while
# the main thread is enqueueing the next chunk's programs.  It lives in
# repro.fl.store (the dependency points store → here otherwise) and is
# re-exported from this module as part of the mesh-staging surface.
from repro.fl.store import staged_host_rows

PyTree = Any

__all__ = ["CLIENTS_AXIS", "make_client_mesh", "bucket_participants",
           "bucket_cohort", "shard_clients", "replicate", "staging_sharding",
           "staged_host_rows", "make_sharded_round",
           "make_sharded_round_async", "make_sharded_round_q",
           "make_sharded_round_async_q", "bank_shard_rows"]


def _n_shards(mesh: jax.sharding.Mesh) -> int:
    if CLIENTS_AXIS not in mesh.axis_names:
        raise ValueError(f"sharded engine needs a {CLIENTS_AXIS!r} mesh "
                         f"axis; got {mesh.axis_names}")
    return mesh.shape[CLIENTS_AXIS]


def bucket_participants(idx: np.ndarray, weights: np.ndarray, n_clients: int,
                        n_shards: int):
    """Host-side bucketing of a participant cohort onto client shards.

    Returns ``(local, pos, w)``, each ``[n_shards, cap]`` with
    ``cap = min(S, shard_n)`` (static per cohort size):

    * ``local`` — participant local row in the shard's bank slice; padding
      is ``shard_n``, one past the end, so gathers clamp and scatters drop.
    * ``pos`` — position in the cohort's participant order (indexes the
      round's ``split(rng, S)`` keys and pre-gathered [S] batch banks);
      padding clamps to 0.
    * ``w`` — per-participant aggregation weights; padding is 0, so padded
      slots vanish from every weighted reduction.
    """
    shard_n = n_clients // n_shards
    idx = np.asarray(idx)
    weights = np.asarray(weights, np.float32)
    s = int(idx.shape[0])
    cap = min(s, shard_n)
    local = np.full((n_shards, cap), shard_n, np.int32)
    pos = np.zeros((n_shards, cap), np.int32)
    w = np.zeros((n_shards, cap), np.float32)
    # vectorized bucketing (no per-participant Python loop — this runs
    # host-side every round): group by owner shard, cohort order preserved
    # within each shard by the stable sort; slot = rank within the group
    d, r = np.divmod(idx.astype(np.int64), shard_n)
    order = np.argsort(d, kind="stable")
    ds = d[order]
    slot = np.arange(s, dtype=np.int64) - np.searchsorted(ds, ds)
    local[ds, slot] = r[order]
    pos[ds, slot] = order
    w[ds, slot] = weights[order]
    return local, pos, w


def bucket_cohort(idx: jax.Array, weights: jax.Array, n_clients: int,
                  n_shards: int, *extras: jax.Array):
    """In-graph counterpart of :func:`bucket_participants` — traceable
    inside the scanned round body (``FedSim.run_scanned``).

    Requires ``idx`` SORTED ascending (what ``sample_cohort`` produces).
    THE REQUIREMENT IS SILENT IN-GRAPH: the rank-within-shard slot
    assignment (``arange(S) - searchsorted(d, d)``) is only a bijection
    when equal shard owners are contiguous — an unsorted cohort collides
    slots, overwriting participants (mis-bucketing, not an error).
    Traced code cannot validate this, so the host boundary does:
    ``repro.fl.schedule.validate_cohorts`` rejects unsorted explicit
    schedules before any cohort reaches this function (regression-tested
    in tests/test_async.py).  For sorted cohorts the output is
    bit-identical to the host bucketing (both group by owner shard
    preserving cohort order).  The cap ``min(S, shard_n)`` is a static
    function of S, so one program serves every cohort of a chunk — and
    because the buckets are rebuilt per round from whatever row the
    schedule supplies, OVERLAPPING/streaming cohorts (the buffered-async
    engine: the same client id appearing in different rounds' flushes)
    bucket exactly like disjoint ones.

    ``extras``: additional per-participant ``[S]`` arrays (e.g. the
    async engine's staleness) bucketed alongside, each returned as
    ``[n_shards, cap]`` with 0 at padding slots (padding already carries
    weight 0, so a zero extra cannot contribute anywhere).
    """
    shard_n = n_clients // n_shards
    s = idx.shape[0]
    cap = min(s, shard_n)
    d = idx // shard_n
    r = (idx % shard_n).astype(jnp.int32)
    # rank within the owner-shard group: position minus first occurrence
    slot = jnp.arange(s) - jnp.searchsorted(d, d)
    local = jnp.full((n_shards, cap), shard_n, jnp.int32).at[d, slot].set(r)
    pos = jnp.zeros((n_shards, cap), jnp.int32).at[d, slot].set(
        jnp.arange(s, dtype=jnp.int32))
    w = jnp.zeros((n_shards, cap), jnp.float32).at[d, slot].set(
        weights.astype(jnp.float32))
    bucketed_extras = tuple(
        jnp.zeros((n_shards, cap), e.dtype).at[d, slot].set(e)
        for e in extras)
    return (local, pos, w, *bucketed_extras)


def shard_clients(mesh: jax.sharding.Mesh, clients: PyTree) -> PyTree:
    """Place a stacked ``[N, ...]`` client bank on the clients axis —
    per-device bank memory becomes N / n_shards rows."""
    sh = NamedSharding(mesh, P(CLIENTS_AXIS))
    return jax.tree.map(lambda x: jax.device_put(x, sh), clients)


def replicate(mesh: jax.sharding.Mesh, tree: PyTree) -> PyTree:
    """Replicate server-side state (params, server) over the mesh."""
    sh = NamedSharding(mesh, P())
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


def staging_sharding(mesh: jax.sharding.Mesh) -> NamedSharding:
    """Placement for PAGED staging onto the mesh: hot client rows split
    over the clients axis, so each shard receives only its slice of the
    staged bank (shard-local paging — host→device traffic and per-device
    staged memory are both ``cap / n_shards`` rows).  The paged driver
    rounds staging capacities up to a multiple of ``n_shards`` so the
    split is always even."""
    return NamedSharding(mesh, P(CLIENTS_AXIS))


def bank_shard_rows(clients: PyTree) -> list[tuple[int, ...]]:
    """Leading-axis extents of each addressable shard of the first bank
    leaf — the per-device client-bank memory footprint (tests/bench)."""
    leaves = jax.tree.leaves(clients)
    if not leaves:
        return []
    return [tuple(s.data.shape) for s in leaves[0].addressable_shards]


def make_sharded_round(task, algo, hp, n_clients: int,
                       mesh: jax.sharding.Mesh):
    """Build the sharded gather/compute/scatter round body.

    Returns ``round_fn(params, server, clients, batches, rng, local,
    pos, w, *, s, bucketed)`` — jit it with ``static_argnames=("s",
    "bucketed")``.  ``batches`` leaves lead with N (client-ordered bank,
    sharded like the client bank and gathered shard-locally) when
    ``bucketed=False``, or with ``n_shards·cap`` (pre-bucketed
    participant rows, see :func:`bucket_participants`) when
    ``bucketed=True``.
    """
    nd = _n_shards(mesh)
    if n_clients % nd:
        raise ValueError(f"n_clients={n_clients} must divide over the "
                         f"{nd}-way {CLIENTS_AXIS!r} axis")

    def round_fn(params, server, clients, batches, rng, local, pos, w, *,
                 s: int, bucketed: bool):
        def shard_fn(params, server, lclients, lbatches, li, lpos, lw, rng):
            li, lpos, lw = li[0], lpos[0], lw[0]        # [1, cap] → [cap]
            # ---- gather: this shard's participants only ---------------
            gathered = jax.tree.map(
                lambda x: jnp.take(x, li, axis=0, mode="clip"), lclients)
            gbatches = lbatches if bucketed else jax.tree.map(
                lambda x: jnp.take(x, li, axis=0, mode="clip"), lbatches)
            # same per-participant keys as the vmap oracle: split over the
            # FULL cohort (replicated compute), index by cohort position
            crngs = jnp.take(jax.random.split(rng, s), lpos, axis=0)

            # ---- compute: vmap over the local bucket ------------------
            def client_fn(cstate, cb, cr):
                return algo.client(task, hp, params, cstate, server, cb, cr)

            msgs, updated = jax.vmap(client_fn)(gathered, gbatches, crngs)

            # ---- aggregate: partial reductions + one psum per group ---
            part = Participation(weights=lw, n_total=n_clients,
                                 axes=(CLIENTS_AXIS,))
            new_params, new_server = algo.server(task, hp, params, server,
                                                 msgs, part)

            # ---- scatter: shard-local writes; padding slots drop ------
            new_clients = jax.tree.map(
                lambda b, u: b.at[li].set(u, mode="drop"), lclients, updated)
            # metrics go through the SAME fp32 wmean as the vmap engine
            # (part.axes turns the mean into partial sums + one psum)
            metrics = round_metrics(msgs, part)
            return new_params, new_server, new_clients, metrics

        shd = P(CLIENTS_AXIS)
        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(), P(), shd, shd, shd, shd, shd, P()),
            out_specs=(P(), P(), shd, P()),
            axis_names={CLIENTS_AXIS}, check=False)(
                params, server, clients, batches, local, pos, w, rng)

    return round_fn


def make_sharded_round_async(task, algo, hp, n_clients: int,
                             mesh: jax.sharding.Mesh):
    """Buffered-async twin of :func:`make_sharded_round`.

    Returns ``round_fn(params, server, clients, batches, pstack, rng,
    local, pos, w, tau, *, s)`` — always pre-bucketed (``batches`` and
    ``pstack`` lead with ``n_shards·cap`` rows in shard order; the
    caller gathers each participant's dispatch-time params from its ring
    OUTSIDE the manual region and buckets them like batches).  Each
    shard's clients train against their own stale params row; the mix
    sees ``Participation(staleness=ltau)`` so the declared mixer damping
    hook runs per-shard with the usual cross-shard psums.  Padding slots
    carry weight 0 and staleness 0 — throwaway compute, no contribution.
    """
    nd = _n_shards(mesh)
    if n_clients % nd:
        raise ValueError(f"n_clients={n_clients} must divide over the "
                         f"{nd}-way {CLIENTS_AXIS!r} axis")

    def round_fn(params, server, clients, batches, pstack, rng, local, pos,
                 w, tau, *, s: int):
        def shard_fn(params, server, lclients, lbatches, lpstack, li, lpos,
                     lw, ltau, rng):
            li, lpos = li[0], lpos[0]                   # [1, cap] → [cap]
            lw, ltau = lw[0], ltau[0]
            gathered = jax.tree.map(
                lambda x: jnp.take(x, li, axis=0, mode="clip"), lclients)
            crngs = jnp.take(jax.random.split(rng, s), lpos, axis=0)

            # compute: per-participant dispatch-time params are a MAPPED
            # vmap axis here (the sync round closes over broadcast params)
            def client_fn(cparams, cstate, cb, cr):
                return algo.client(task, hp, cparams, cstate, server, cb,
                                   cr)

            msgs, updated = jax.vmap(client_fn)(lpstack, gathered,
                                                lbatches, crngs)
            part = Participation(weights=lw, n_total=n_clients,
                                 axes=(CLIENTS_AXIS,), staleness=ltau)
            new_params, new_server = algo.server(task, hp, params, server,
                                                 msgs, part)
            new_clients = jax.tree.map(
                lambda b, u: b.at[li].set(u, mode="drop"), lclients, updated)
            return (new_params, new_server, new_clients,
                    round_metrics(msgs, part))

        shd = P(CLIENTS_AXIS)
        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(), P(), shd, shd, shd, shd, shd, shd, shd, P()),
            out_specs=(P(), P(), shd, P()),
            axis_names={CLIENTS_AXIS}, check=False)(
                params, server, clients, batches, pstack, local, pos, w,
                tau, rng)

    return round_fn


def _quarantine_local(algo, task, hp, n_clients, params, server, msgs,
                      lw, lcodes, clip, ltau):
    """Shard-local half of the in-graph quarantine (see
    ``FedSim._aggregate_q`` for the replicated-engine twin and the full
    contract).  Runs inside a shard_map region: inject faults into this
    shard's local message bucket, decode, validate EVERY decoded leaf
    (all-finite AND wire-norm ≤ ``clip``), sanitize rejected/crashed
    slots to zero (0·NaN is NaN — zero weights alone cannot neutralize a
    poisoned leaf inside the weighted reductions), and mix with the
    effective weights.  ``alive`` and ``n_rejected`` are psum'd so every
    shard takes the same carry-forward branch.  Padding slots carry
    weight 0 and code 0; their finite throwaway messages stay valid and
    are not counted (counting requires ``lw > 0``).
    """
    msgs = FLT.inject(msgs, lcodes)
    dec = API.decode_msgs(algo, msgs, params)
    valid = FLT.validity(dec, clip)
    keep = valid & (lcodes != FLT.FAULT_CRASH)
    dec = FLT.sanitize(dec, keep)
    lw_eff = jnp.where(keep, lw, jnp.float32(0.0))
    part = Participation(weights=lw_eff, n_total=n_clients,
                         axes=(CLIENTS_AXIS,), staleness=ltau)
    cand_p, cand_sv = API.mix_decoded(algo, task, hp, params, server, dec,
                                      part)
    alive = jax.lax.psum(jnp.sum(lw_eff), CLIENTS_AXIS) > 0
    n_rej = jax.lax.psum(jnp.sum((~valid) & (lw > 0)),
                         CLIENTS_AXIS).astype(jnp.int32)
    new_p = jax.tree.map(lambda a, b: jnp.where(alive, a, b), cand_p, params)
    new_sv = jax.tree.map(lambda a, b: jnp.where(alive, a, b), cand_sv,
                          server)
    metrics = round_metrics(dec, part)
    metrics["alive"] = alive
    metrics["n_rejected"] = n_rej
    return new_p, new_sv, keep, metrics


def make_sharded_round_q(task, algo, hp, n_clients: int,
                         mesh: jax.sharding.Mesh):
    """Quarantining twin of :func:`make_sharded_round` — the fault-
    tolerant sync round body.

    Returns ``round_fn(params, server, clients, batches, rng, local,
    pos, w, codes, *, s, clip)`` — always pre-bucketed (``codes`` is the
    ``[n_shards, cap]`` bucketed per-slot fault-code row; padding slots
    carry code 0).  Differences from the plain body: client messages are
    run through the fault injector, decoded ONCE, validated, sanitized,
    and mixed via ``API.mix_decoded``; rejected or crashed clients keep
    their pre-round local state bit-untouched (the keep-masked restore
    below), and an all-rejected round degrades to a params-carrying
    no-op via the psum'd ``alive`` select.  With an all-zero code row
    every select collapses to its identity branch, so the zero-fault run
    matches the plain sharded body to fp32 mixing tolerance.
    """
    nd = _n_shards(mesh)
    if n_clients % nd:
        raise ValueError(f"n_clients={n_clients} must divide over the "
                         f"{nd}-way {CLIENTS_AXIS!r} axis")

    def round_fn(params, server, clients, batches, rng, local, pos, w,
                 codes, *, s: int, clip: float):
        def shard_fn(params, server, lclients, lbatches, li, lpos, lw,
                     lcodes, rng):
            li, lpos = li[0], lpos[0]                   # [1, cap] → [cap]
            lw, lcodes = lw[0], lcodes[0]
            gathered = jax.tree.map(
                lambda x: jnp.take(x, li, axis=0, mode="clip"), lclients)
            crngs = jnp.take(jax.random.split(rng, s), lpos, axis=0)

            def client_fn(cstate, cb, cr):
                return algo.client(task, hp, params, cstate, server, cb, cr)

            msgs, updated = jax.vmap(client_fn)(gathered, lbatches, crngs)
            new_params, new_server, keep, metrics = _quarantine_local(
                algo, task, hp, n_clients, params, server, msgs, lw,
                lcodes, clip, None)
            # rejected clients keep their pre-round state bit-untouched
            cap = li.shape[0]
            restored = jax.tree.map(
                lambda u, g: jnp.where(
                    keep.reshape((cap,) + (1,) * (u.ndim - 1)), u, g),
                updated, gathered)
            new_clients = jax.tree.map(
                lambda b, u: b.at[li].set(u, mode="drop"), lclients,
                restored)
            return new_params, new_server, new_clients, metrics

        shd = P(CLIENTS_AXIS)
        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(), P(), shd, shd, shd, shd, shd, shd, P()),
            out_specs=(P(), P(), shd, P()),
            axis_names={CLIENTS_AXIS}, check=False)(
                params, server, clients, batches, local, pos, w, codes, rng)

    return round_fn


def make_sharded_round_async_q(task, algo, hp, n_clients: int,
                               mesh: jax.sharding.Mesh):
    """Quarantining twin of :func:`make_sharded_round_async` — the
    fault-tolerant buffered-async round body.

    Returns ``round_fn(params, server, clients, batches, pstack, rng,
    local, pos, w, tau, codes, *, s, clip)`` — always pre-bucketed, with
    ``tau`` AND ``codes`` bucketed alongside the weights (padding slots:
    staleness 0, code 0, weight 0).  Quarantine semantics match
    :func:`make_sharded_round_q`; staleness flows into the mix through
    ``Participation`` exactly as in the plain async body.
    """
    nd = _n_shards(mesh)
    if n_clients % nd:
        raise ValueError(f"n_clients={n_clients} must divide over the "
                         f"{nd}-way {CLIENTS_AXIS!r} axis")

    def round_fn(params, server, clients, batches, pstack, rng, local, pos,
                 w, tau, codes, *, s: int, clip: float):
        def shard_fn(params, server, lclients, lbatches, lpstack, li, lpos,
                     lw, ltau, lcodes, rng):
            li, lpos = li[0], lpos[0]                   # [1, cap] → [cap]
            lw, ltau, lcodes = lw[0], ltau[0], lcodes[0]
            gathered = jax.tree.map(
                lambda x: jnp.take(x, li, axis=0, mode="clip"), lclients)
            crngs = jnp.take(jax.random.split(rng, s), lpos, axis=0)

            def client_fn(cparams, cstate, cb, cr):
                return algo.client(task, hp, cparams, cstate, server, cb,
                                   cr)

            msgs, updated = jax.vmap(client_fn)(lpstack, gathered,
                                                lbatches, crngs)
            new_params, new_server, keep, metrics = _quarantine_local(
                algo, task, hp, n_clients, params, server, msgs, lw,
                lcodes, clip, ltau)
            cap = li.shape[0]
            restored = jax.tree.map(
                lambda u, g: jnp.where(
                    keep.reshape((cap,) + (1,) * (u.ndim - 1)), u, g),
                updated, gathered)
            new_clients = jax.tree.map(
                lambda b, u: b.at[li].set(u, mode="drop"), lclients,
                restored)
            return new_params, new_server, new_clients, metrics

        shd = P(CLIENTS_AXIS)
        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(), P(), shd, shd, shd, shd, shd, shd, shd, shd,
                      P()),
            out_specs=(P(), P(), shd, P()),
            axis_names={CLIENTS_AXIS}, check=False)(
                params, server, clients, batches, pstack, local, pos, w,
                tau, codes, rng)

    return round_fn

"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD forward for train/prefill and a constant-memory recurrent step
for decode.  The heavy lifting is matmuls (TPU-friendly); the cross-chunk
recurrence is a short ``lax.scan`` over S/chunk steps.

FedPM applicability (DESIGN.md §Arch-applicability): in_proj / out_proj are
linear layers → FOOF preconditioned; A_log, dt_bias, D, conv and norm params
are non-matrix → simple mixing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import block_gram, no_gram


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k]."""
    t = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    out = xc[..., :, None] - xc[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(x, dt, a_log, b, c, chunk: int):
    """SSD chunked algorithm.

    x: [B, S, H, P]; dt: [B, S, H] (post-softplus); a_log: [H];
    b, c: [B, S, N] (single group).  Returns y: [B, S, H, P] and the final
    state [B, H, P, N].
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        # zero-pad: dt = 0 ⇒ decay exp(0·a) = 1 and no input contribution,
        # so the final state is exact; padded y rows are sliced off.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    s_pad = s + pad
    nc = s_pad // q
    a = -jnp.exp(a_log.astype(jnp.float32))                     # [H]
    dta = dt.astype(jnp.float32) * a[None, None, :]             # [B,S,H]

    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    dtac = dta.reshape(bsz, nc, q, h)
    bc = b.reshape(bsz, nc, q, n)
    cc = c.reshape(bsz, nc, q, n)

    # --- intra-chunk (diagonal blocks): quadratic attention-like form
    l = jnp.exp(_segsum(dtac.transpose(0, 1, 3, 2)))            # [B,nc,H,q,q]
    cb = jnp.einsum("bzqn,bzkn->bzqk", cc, bc,
                    preferred_element_type=jnp.float32)         # [B,nc,q,q]
    m = cb[:, :, None] * l                                      # [B,nc,H,q,q]
    y_diag = jnp.einsum("bzhqk,bzkh,bzkhp->bzqhp", m, dtc,
                        xc.astype(jnp.float32))

    # --- chunk states: decayed sum of inputs within each chunk
    dta_cum = jnp.cumsum(dtac, axis=2)                          # [B,nc,q,H]
    decay_to_end = jnp.exp(dta_cum[:, :, -1:, :] - dta_cum)     # [B,nc,q,H]
    states = jnp.einsum("bzqn,bzqh,bzqh,bzqhp->bzhpn",
                        bc, dtc, decay_to_end, xc.astype(jnp.float32))

    # --- inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(dta_cum[:, :, -1, :])                 # [B,nc,H]

    def step(carry, inp):
        st, dec = inp                                           # [B,H,P,N], [B,H]
        new = carry * dec[..., None, None] + st
        return new, carry                                       # emit state *before* chunk

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)          # [B,nc,H,P,N]

    # --- contribution of carried-in state to each position
    state_decay = jnp.exp(dta_cum)                              # [B,nc,q,H]
    y_off = jnp.einsum("bzqn,bzhpn,bzqh->bzqhp", cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(bsz, s_pad, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssd_decode_step(state, x, dt, a_log, b, c):
    """One recurrent step.  state: [B,H,P,N]; x: [B,H,P]; dt: [B,H];
    b, c: [B,N].  Returns (y [B,H,P], new_state)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    da = jnp.exp(dt.astype(jnp.float32) * a[None, :])           # [B,H]
    dbx = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(jnp.float32), b,
                     x.astype(jnp.float32))
    new = state * da[..., None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", new, c)
    return y.astype(x.dtype), new


# ------------------------------------------------------------ mamba block ----

def init_mamba(cfg: ModelConfig, rng) -> dict:
    d, din, n, hh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    convdim = din + 2 * n
    zxbcdt = 2 * din + 2 * n + hh
    k1, k2, k3 = jax.random.split(rng, 3)
    dt = jnp.dtype(cfg.dtype)
    return {
        "in_proj": (jax.random.normal(k1, (d, zxbcdt)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(k2, (cfg.conv_kernel, convdim)) *
                   cfg.conv_kernel ** -0.5).astype(dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, hh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((hh,), jnp.float32),
        "d_skip": jnp.ones((hh,), jnp.float32),
        "gate_norm": jnp.ones((din,), jnp.float32),
        "out_proj": (jax.random.normal(k3, (din, d)) * din ** -0.5).astype(dt),
    }


def _causal_conv(x, w):
    """Depthwise causal conv. x: [B,S,C]; w: [K,C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, shape=x.shape)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
    return out


def _split_zxbcdt(cfg: ModelConfig, zxbcdt):
    din, n, hh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:2 * din + 2 * n]
    dt = zxbcdt[..., 2 * din + 2 * n:]
    return z, xbc, dt


def _rmsnorm_gated(x, z, scale):
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (xf * scale).astype(x.dtype)


def mamba_forward(cfg: ModelConfig, p: dict, x: jax.Array, *, collect=False):
    """x: [B, S, D] (already normed). Returns (out, grams, final_states)."""
    bsz, s, d = x.shape
    din, n, hh, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc_raw, dt = _split_zxbcdt(cfg, zxbcdt)
    xbc = jax.nn.silu(_causal_conv(xbc_raw, p["conv_w"]))
    xs, b, c = xbc[..., :din], xbc[..., din:din + n], xbc[..., din + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(bsz, s, hh, ph)
    y, final = ssd_scan(xh, dt, p["a_log"], b, c, cfg.ssm_chunk)
    y = y + xh * p["d_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, din)
    y = _rmsnorm_gated(y, z, p["gate_norm"])
    out = y @ p["out_proj"]
    grams = {k: no_gram() for k in p}
    if collect:
        grams["in_proj"] = block_gram(x.reshape(-1, d), cfg.foof_block)
        grams["out_proj"] = block_gram(y.reshape(-1, din), cfg.foof_block)
    # conv tail state for decode continuity: last (K-1) *pre-conv* inputs
    conv_state = jnp.pad(xbc_raw, ((0, 0), (cfg.conv_kernel - 1, 0), (0, 0)))[:, -(cfg.conv_kernel - 1):, :]
    return out, grams, (final, conv_state)


def mamba_decode(cfg: ModelConfig, p: dict, x: jax.Array, ssm_state, conv_state):
    """x: [B, 1, D]; ssm_state: [B,H,P,N]; conv_state: [B,K-1,convdim]."""
    bsz = x.shape[0]
    din, n, hh, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc_new, dt = _split_zxbcdt(cfg, zxbcdt)
    window = jnp.concatenate([conv_state, xbc_new], axis=1)      # [B,K,convdim]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"])[:, None, :]
    xbc = jax.nn.silu(conv_out)
    xs, b, c = xbc[..., :din], xbc[..., din:din + n], xbc[..., din + n:]
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    xh = xs[:, 0].reshape(bsz, hh, ph)
    y, new_state = ssd_decode_step(ssm_state, xh, dtv, p["a_log"], b[:, 0], c[:, 0])
    y = y + xh * p["d_skip"].astype(xh.dtype)[None, :, None]
    y = y.reshape(bsz, 1, din)
    y = _rmsnorm_gated(y, z, p["gate_norm"])
    out = y @ p["out_proj"]
    new_conv = window[:, 1:, :]
    return out, new_state, new_conv

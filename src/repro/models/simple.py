"""The paper's own evaluation models.

Test 1 (Sec 4.1): L2-regularized logistic regression — strongly convex, with
analytic gradient and full Hessian (enables FedNL/FedNS/LocalNewton/FedPM
with exact preconditioners and the superlinear-rate check of Theorem 1).

Test 2 (Sec 4.2): non-convex DNNs — an MLP and a "simple CNN" (2 conv +
3 fc, as in Li/He/Song 2021).  Every linear/conv layer is expressed as a
matmul over (bias-augmented) inputs, so the FOOF statistic A = (1/T)·XᵀX is
exact for all parameters including biases (input augmented with a 1-column;
the paper treats biases separately — augmenting is the equivalent
formulation of y = Wx + b as y = [W b][x;1]).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models.layers import block_gram, no_gram


# ------------------------------------------------------- Test 1: convex ----

@dataclass(frozen=True)
class LogisticModel:
    """f_i(θ) = (1/M) Σ_j log(1 + exp(-y_j x_jᵀθ)) + (λ/2)‖θ‖²."""
    d: int
    lam: float = 1e-3

    def init(self, rng) -> jax.Array:
        return jnp.zeros((self.d,), jnp.float32)

    def loss(self, theta, batch) -> jax.Array:
        x, y = batch["x"], batch["y"]                    # y ∈ {-1, +1}
        z = y * (x @ theta)
        return jnp.mean(jax.nn.softplus(-z)) + 0.5 * self.lam * jnp.sum(theta ** 2)

    def grad(self, theta, batch) -> jax.Array:
        x, y = batch["x"], batch["y"]
        z = y * (x @ theta)
        s = jax.nn.sigmoid(-z)                           # σ(-z)
        return -(x.T @ (y * s)) / x.shape[0] + self.lam * theta

    def hessian(self, theta, batch) -> jax.Array:
        x, y = batch["x"], batch["y"]
        z = y * (x @ theta)
        w = jax.nn.sigmoid(z) * jax.nn.sigmoid(-z)       # σ(z)σ(-z)
        return (x.T * w) @ x / x.shape[0] + self.lam * jnp.eye(self.d)

    def accuracy(self, theta, batch) -> jax.Array:
        pred = jnp.sign(batch["x"] @ theta)
        return jnp.mean((pred == batch["y"]).astype(jnp.float32))


# ------------------------------------------------- Test 2: DNN building ----

def _augment(x2d: jax.Array) -> jax.Array:
    ones = jnp.ones((*x2d.shape[:-1], 1), x2d.dtype)
    return jnp.concatenate([x2d, ones], axis=-1)


def _dense(x, w, collect: bool, foof_block: int):
    """x: [..., din]; w: [din+1, dout] (bias row folded in)."""
    xa = _augment(x)
    y = xa @ w
    gram = block_gram(xa.reshape(-1, xa.shape[-1]), foof_block) if collect \
        else no_gram()
    return y, gram


def _conv(x, w, kh, kw, collect: bool, foof_block: int):
    """x: [B,H,W,C]; w: [kh*kw*C+1, O] over bias-augmented im2col patches."""
    b, h, ww, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x.transpose(0, 3, 1, 2), (kh, kw), (1, 1), "SAME")       # [B, C*kh*kw, H, W]
    patches = patches.transpose(0, 2, 3, 1)                       # [B,H,W,C*kh*kw]
    pa = _augment(patches)
    y = pa @ w
    gram = block_gram(pa.reshape(-1, pa.shape[-1]), foof_block) if collect \
        else no_gram()
    return y, gram


@dataclass(frozen=True)
class MLPModel:
    """Flatten → hidden dense layers (ReLU) → classifier head."""
    in_dim: int
    hidden: Sequence[int]
    num_classes: int
    foof_block: int = 1024

    def init(self, rng) -> dict:
        dims = [self.in_dim, *self.hidden, self.num_classes]
        params = {}
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
            rng, k = jax.random.split(rng)
            w = jax.random.normal(k, (a + 1, b)) * (2.0 / a) ** 0.5
            w = w.at[-1].set(0.0)                        # zero bias row
            params[f"fc{i}"] = {"w": w.astype(jnp.float32)}
        return params

    def apply(self, params, x, collect: bool = False):
        x = x.reshape(x.shape[0], -1)
        grams = {}
        n = len(params)
        for i in range(n):
            x, g = _dense(x, params[f"fc{i}"]["w"], collect, self.foof_block)
            grams[f"fc{i}"] = {"w": g}
            if i < n - 1:
                x = jax.nn.relu(x)
        return x, grams


@dataclass(frozen=True)
class CNNModel:
    """The paper's 'simple CNN': conv(5×5,6) → pool → conv(5×5,16) → pool →
    fc(120) → fc(84) → fc(classes)."""
    in_hw: int = 32
    in_ch: int = 3
    num_classes: int = 10
    foof_block: int = 1024

    def init(self, rng) -> dict:
        ks = jax.random.split(rng, 5)

        def w(k, a, b):
            ww = jax.random.normal(k, (a + 1, b)) * (2.0 / a) ** 0.5
            return ww.at[-1].set(0.0).astype(jnp.float32)

        hw4 = self.in_hw // 4
        return {
            "conv0": {"w": w(ks[0], 5 * 5 * self.in_ch, 6)},
            "conv1": {"w": w(ks[1], 5 * 5 * 6, 16)},
            "fc0": {"w": w(ks[2], hw4 * hw4 * 16, 120)},
            "fc1": {"w": w(ks[3], 120, 84)},
            "fc2": {"w": w(ks[4], 84, self.num_classes)},
        }

    def apply(self, params, x, collect: bool = False):
        grams = {}
        x, g = _conv(x, params["conv0"]["w"], 5, 5, collect, self.foof_block)
        grams["conv0"] = {"w": g}
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        x, g = _conv(x, params["conv1"]["w"], 5, 5, collect, self.foof_block)
        grams["conv1"] = {"w": g}
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        x = x.reshape(x.shape[0], -1)
        for name in ("fc0", "fc1", "fc2"):
            x, g = _dense(x, params[name]["w"], collect, self.foof_block)
            grams[name] = {"w": g}
            if name != "fc2":
                x = jax.nn.relu(x)
        return x, grams


def ce_loss_and_grams(model, params, batch, *, collect: bool = False,
                      weight_decay: float = 0.0):
    """Softmax CE (labels int) + optional L2; returns (loss, grams)."""
    logits, grams = model.apply(params, batch["x"], collect)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1).mean()
    if weight_decay:
        l2 = sum(jnp.sum(w ** 2) for w in jax.tree.leaves(params))
        nll = nll + 0.5 * weight_decay * l2
    return nll, grams


def accuracy(model, params, batch) -> jax.Array:
    logits, _ = model.apply(params, batch["x"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))

"""Architecture configuration.

One frozen dataclass describes every assigned architecture (dense GQA,
sliding-window, MoE, MLA, SSM, hybrid, audio/VLM decoder) plus the paper's
own small models.  ``reduced()`` yields the CPU smoke-test variant required
by the spec (≤2 layers, d_model ≤ 512, ≤4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 → d_model // num_heads
    source: str = ""             # citation (hf:/arXiv:)

    # --- attention variant ---
    attention: str = "full"      # full | sliding_pattern | mla | none
    sliding_window: int = 4096
    local_per_global: int = 0    # gemma3: 5 local layers per 1 global
    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- FFN variant ---
    num_experts: int = 0         # 0 → dense FFN
    experts_per_tok: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_kernel: int = 4
    attn_every: int = 0          # zamba2: shared attn block period

    # --- misc ---
    norm: str = "rmsnorm"        # rmsnorm | layernorm | nonparametric
    use_bias: bool = False
    rope_theta: float = 10000.0
    mrope_sections: tuple = ()   # qwen2-vl M-RoPE (t, h, w) section split
    num_codebooks: int = 0       # musicgen parallel codebook heads
    frontend: str = "none"       # none | audio_stub | vision_stub
    frontend_tokens: int = 0     # patches/frames consumed as embeddings
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # long-context serving: cap global-attention cache to a window
    long_context_global_window: int = 0

    # --- FedPM integration ---
    foof_block: int = 1024       # within-layer block-diagonal FOOF cap
    subquadratic: bool = False   # eligible for long_500k
    # §Perf A1: dispatch MoE inside a shard_map island (fully local
    # routing per (client, expert-shard); combine = one psum over "model")
    moe_shard_map: bool = False
    # §Perf B2: FSDP placement. "contract" shards the weight's contraction
    # dim over "data" (classic, but GSPMD falls back to batch replication
    # on the MLP path — measured 3.2 PB/chip traffic on llama3-405b);
    # "cols" shards the non-contraction dim over ("model","data") so the
    # compiler's well-trodden weight-all-gather path triggers instead.
    fsdp_mode: str = "contract"  # contract | cols
    # §Perf B3: shard the residual stream's sequence dim over "model"
    # between blocks (Korthikanti-style sequence parallelism)
    seq_parallel: bool = False

    # --- scan unit structure ---
    layers_per_unit: int = 1

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_layers % max(self.layers_per_unit, 1) != 0:
            raise ValueError(f"{self.name}: num_layers {self.num_layers} not "
                             f"divisible by layers_per_unit {self.layers_per_unit}")

    @property
    def num_units(self) -> int:
        return self.num_layers // self.layers_per_unit

    @property
    def d_inner(self) -> int:   # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family (spec: ≤2 layers, d≤512, ≤4 experts)."""
        lpu = self.layers_per_unit
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = min(self.num_kv_heads, heads) if self.num_kv_heads else 0
        d = min(self.d_model, 128)
        changes = dict(
            num_layers=2 * lpu if self.attn_every == 0 else 2 * lpu,
            d_model=d,
            num_heads=heads,
            num_kv_heads=max(kv, 1) if heads else 0,
            head_dim=d // heads if heads else 0,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            kv_lora_rank=min(self.kv_lora_rank, 32),
            q_lora_rank=min(self.q_lora_rank, 48),
            qk_rope_dim=min(self.qk_rope_dim, 16),
            qk_nope_dim=min(self.qk_nope_dim, 16),
            v_head_dim=min(self.v_head_dim, 24),
            num_experts=min(self.num_experts, 4),
            experts_per_tok=min(self.experts_per_tok, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=min(self.ssm_head_dim, 32) if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=32,
            sliding_window=64,
            frontend_tokens=min(self.frontend_tokens, 8),
            # keep Σ sections == head_dim/2 for the reduced head size
            mrope_sections=(
                ((d // heads) // 2 - 2 * ((d // heads) // 8),
                 (d // heads) // 8, (d // heads) // 8)
                if self.mrope_sections else ()),
            foof_block=128,
            dtype="float32",
            name=self.name + "-smoke",
        )
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}

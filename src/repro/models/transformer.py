"""One composable decoder stack covering all 10 assigned architectures.

Layers are grouped into repeat *units* (cfg.layers_per_unit) whose params are
stacked on a leading axis and driven by ``lax.scan`` — llama3-405b lowers
with a 126×-smaller HLO than an unrolled stack.  Unit internals:

  dense   : lpu × (norm → GQA attn → norm → MLP)          (gemma3: lpu = 6,
            inner layers 0..4 sliding-window, layer 5 global)
  moe     : norm → attn/MLA → norm → MoE (+ shared experts)
  ssm     : norm → mamba2 (SSD)
  hybrid  : shared-attention block (weights shared across units, zamba2)
            followed by lpu mamba2 layers

Forward optionally collects FOOF grams (mirroring the param tree) and/or the
KV/SSM cache (for prefill).  ``decode_step`` consumes one token against the
cache.  ``param_specs``/``cache_specs`` give PartitionSpecs for the
production meshes (DESIGN.md §3/§5).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig, InputShape

CLIENT_AXES_SPEC = ("pod", "data")  # batch-sharded axes present in the mesh


# =========================================================== init / specs ====

def _init_unit(cfg: ModelConfig, rng) -> dict:
    """Params for one repeat unit (inner layers stacked on axis 0)."""
    lpu = cfg.layers_per_unit
    inner = []
    for i in range(lpu):
        rng, k1, k2 = jax.random.split(rng, 3)
        if cfg.family in ("ssm",) or (cfg.family == "hybrid"):
            p = {"norm": L.init_norm(cfg, cfg.d_model),
                 "mamba": S.init_mamba(cfg, k1)}
        elif cfg.attention == "mla":
            p = {"norm1": L.init_norm(cfg, cfg.d_model),
                 "attn": L.init_mla(cfg, k1),
                 "norm2": L.init_norm(cfg, cfg.d_model),
                 "moe": L.init_moe(cfg, k2) if cfg.num_experts else L.init_mlp(cfg, k2)}
        else:
            ffn = L.init_moe(cfg, k2) if cfg.num_experts else L.init_mlp(cfg, k2)
            p = {"norm1": L.init_norm(cfg, cfg.d_model),
                 "attn": L.init_attn(cfg, k1),
                 "norm2": L.init_norm(cfg, cfg.d_model),
                 "ffn": ffn}
        inner.append(p)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *inner)


def init_params(cfg: ModelConfig, rng) -> dict:
    rng, ke, kh, ks = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.dtype)
    units = []
    for _ in range(cfg.num_units):
        rng, ku = jax.random.split(rng)
        units.append(_init_unit(cfg, ku))
    blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *units)
    out_vocab = cfg.vocab_size * max(cfg.num_codebooks, 1)
    params = {
        "embed": {"w": (jax.random.normal(ke, (cfg.vocab_size, cfg.d_model))
                        * cfg.d_model ** -0.5).astype(dt)},
        "blocks": blocks,
        "final_norm": L.init_norm(cfg, cfg.d_model),
        "head": {"w": (jax.random.normal(kh, (cfg.d_model, out_vocab))
                       * cfg.d_model ** -0.5).astype(dt)},
    }
    if cfg.family == "hybrid":
        params["shared_attn"] = {"norm": L.init_norm(cfg, cfg.d_model),
                                 "attn": L.init_attn(cfg, ks)}
    return params


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# Archs whose params must additionally shard over "data" (DESIGN.md §3/§5).
FSDP_ARCHS = {"command-r-35b", "deepseek-v2-236b", "llama3-405b", "qwen2-vl-72b"}


def _axprod(axis_sizes: dict, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, (tuple, list)) else [axes]):
        n *= axis_sizes.get(a, 1)
    return n


def param_specs(cfg: ModelConfig, axis_sizes: dict, *, fsdp: bool | None = None):
    """PartitionSpec tree mirroring ``init_params`` output."""
    if fsdp is None:
        fsdp = cfg.name in FSDP_ARCHS
    msz = axis_sizes.get("model", 1)
    dsz = axis_sizes.get("data", 1)

    cols_mode = fsdp and cfg.fsdp_mode == "cols"

    def ok(dim, size):
        return dim % size == 0 and size > 1

    def m(dim):   # shard over "model" if divisible
        return "model" if ok(dim, msz) else None

    def d(dim):   # shard over "data" (fsdp-contract) if enabled & divisible
        return "data" if (fsdp and not cols_mode and ok(dim, dsz)) else None

    def md(dim):  # §Perf B2 "cols": shard over ("model","data") together
        if cols_mode and ok(dim, msz * dsz):
            return ("model", "data")
        return m(dim)

    _VECTOR = {"scale", "bias", "a_log", "dt_bias", "d_skip", "gate_norm",
               "q_norm", "kv_norm", "conv_w"}
    _BASE_NDIM = {k: (2 if k == "conv_w" else 1) for k in _VECTOR}

    def base_spec(name, shp):
        if name in _VECTOR:
            return (None,) * len(shp)
        if len(shp) == 3:                        # moe experts [E, ., .]
            if name == "wi":
                return (m(shp[0]), d(shp[1]), None)
            return (m(shp[0]), None, d(shp[2]))  # wo
        a, b = shp
        table = {
            "wqkv": (d(a), md(b)), "wo": (md(a), d(b)),
            "wi": (d(a), md(b)),
            "wq_a": (d(a), md(b) if cols_mode else None),
            "wq_b": (None, md(b)),
            "wkv_a": (d(a), md(b) if cols_mode else None),
            "wkv_b": (None, md(b)),
            "router": (None, None),
            "shared_wi": (d(a), md(b)), "shared_wo": (md(a), d(b)),
            "in_proj": (d(a), md(b)), "out_proj": (md(a), d(b)),
        }
        return table.get(name, (None, None))

    params = abstract_params(cfg)

    def spec_for(path, leaf) -> P:
        keys = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        name = keys[-1]
        if keys[0] == "embed":
            return P(m(cfg.vocab_size), d(cfg.d_model))
        if keys[0] == "head":
            return P(d(cfg.d_model), m(leaf.shape[-1]))
        shp = leaf.shape
        nbase = 3 if ("moe" in keys and name in ("wi", "wo")) \
            else _BASE_NDIM.get(name, 2)
        # leading scan/stack axes are unsharded
        base_shape = shp[len(shp) - nbase:]
        lead = (None,) * (len(shp) - nbase)
        return P(*lead, *base_spec(name, base_shape))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def batch_spec(cfg: ModelConfig, axis_sizes: dict, batch_size: int):
    """Input batch sharding: batch over the client axes that divide it."""
    axes = [a for a in CLIENT_AXES_SPEC if axis_sizes.get(a, 1) > 1]
    n = _axprod(axis_sizes, axes)
    baxes = tuple(axes) if axes and batch_size % n == 0 else None
    return baxes


# ============================================================== forward ======

def _positions_for(cfg: ModelConfig, batch: dict, bsz: int, s: int):
    if cfg.mrope_sections:
        if "positions" in batch:
            return batch["positions"]
        pos = jnp.arange(s, dtype=jnp.int32)[None, None, :]
        return jnp.broadcast_to(pos, (bsz, 3, s))
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (bsz, s))


def _embed_inputs(cfg: ModelConfig, params: dict, batch: dict):
    """Returns (x [B,S,D], token_counts_for_embed_gram or None)."""
    if cfg.frontend == "audio_stub":
        return batch["embeds"].astype(jnp.dtype(cfg.dtype)), None
    if cfg.frontend == "vision_stub":
        tok = batch["tokens"]
        text = jnp.take(params["embed"]["w"], tok, axis=0)
        patches = batch["patches"].astype(text.dtype)
        return jnp.concatenate([patches, text], axis=1), tok
    tok = batch["tokens"]
    return jnp.take(params["embed"]["w"], tok, axis=0), tok


def _seq_parallel_spec(cfg: ModelConfig, bsz: int, s: int):
    """P(batch_axes, "model", None) when the ambient mesh supports it."""
    try:
        from repro.distributed.axes import ambient_mesh
        mesh = ambient_mesh()
        names = tuple(getattr(mesh, "axis_names", ()) or ())
        if "model" not in names or int(mesh.shape["model"]) <= 1:
            return None
        if s % int(mesh.shape["model"]) != 0:
            return None
        ca = tuple(a for a in ("pod", "data") if a in names)
        n = 1
        for a in ca:
            n *= int(mesh.shape[a])
        baxes = ca if ca and bsz % n == 0 else None
        return P(baxes, "model", None)
    except Exception:
        return None


def _gemma3_window(cfg: ModelConfig, inner_idx: int) -> int:
    """5 local (sliding) : 1 global layer pattern."""
    if cfg.local_per_global <= 0:
        return 0
    return cfg.sliding_window if (inner_idx % (cfg.local_per_global + 1)
                                  != cfg.local_per_global) else 0


def _unit_forward(cfg: ModelConfig, up: dict, shared: dict | None, x,
                  positions, *, collect: bool, want_cache: bool):
    """One repeat unit. Returns (x, grams_unit, cache_unit, aux)."""
    lpu = cfg.layers_per_unit
    grams_inner, cache_unit, aux = [], {}, {}
    shared_grams = None
    if cfg.family == "hybrid" and shared is not None:
        h = L.apply_norm(cfg, shared["norm"], x)
        o, g_attn, (k, v) = L.attn_forward(cfg, shared["attn"], h, positions,
                                           window=0, collect=collect)
        x = x + o
        shared_grams = {"norm": {kk: L.no_gram() for kk in shared["norm"]},
                        "attn": g_attn}
        if want_cache:
            cache_unit["shared"] = {"k": k, "v": v}
    for i in range(lpu):
        p_i = jax.tree.map(lambda a: a[i], up)
        if cfg.family in ("ssm", "hybrid"):
            h = L.apply_norm(cfg, p_i["norm"], x)
            o, g, (st, conv) = S.mamba_forward(cfg, p_i["mamba"], h, collect=collect)
            x = x + o
            gi = {"norm": {k: L.no_gram() for k in p_i["norm"]}, "mamba": g}
            if want_cache:
                cache_unit[f"layer{i}"] = {"ssm": st, "conv": conv}
        else:
            h = L.apply_norm(cfg, p_i["norm1"], x)
            if cfg.attention == "mla":
                o, g_attn, (ckv, krope) = L.mla_forward(cfg, p_i["attn"], h,
                                                        positions, collect=collect)
                if want_cache:
                    cache_unit[f"layer{i}"] = {"ckv": ckv, "krope": krope}
            else:
                win = (_gemma3_window(cfg, i) if cfg.local_per_global
                       else (cfg.sliding_window if cfg.attention == "sliding" else 0))
                o, g_attn, (k, v) = L.attn_forward(cfg, p_i["attn"], h, positions,
                                                   window=win, collect=collect)
                if want_cache:
                    if win > 0:
                        k, v = k[:, :, -min(win, k.shape[2]):], v[:, :, -min(win, v.shape[2]):]
                    cache_unit[f"layer{i}"] = {"k": k, "v": v}
            x = x + o
            h2 = L.apply_norm(cfg, p_i["norm2"], x)
            key = "moe" if "moe" in p_i else "ffn"
            if cfg.num_experts:
                o2, g_ffn, aux_moe = L.moe_forward(cfg, p_i[key], h2, collect=collect)
                aux = aux_moe
            else:
                o2, g_ffn = L.mlp_forward(cfg, p_i[key], h2, collect=collect)
            x = x + o2
            gi = {"norm1": {k: L.no_gram() for k in p_i["norm1"]},
                  "attn": g_attn,
                  "norm2": {k: L.no_gram() for k in p_i["norm2"]},
                  key: g_ffn}
        grams_inner.append(gi)
    grams_unit = jax.tree.map(lambda *xs: jnp.stack(xs), *grams_inner)
    return x, grams_unit, cache_unit, aux, shared_grams


def forward(cfg: ModelConfig, params: dict, batch: dict, *,
            collect_foof: bool = False, want_cache: bool = False,
            remat: bool = True):
    """Full forward. Returns (logits_input_x [B,S,D], grams, cache, aux).

    The head matmul is NOT applied here — the loss is chunked over sequence
    (see ``chunked_ce_loss``) to avoid materializing [B,S,V] logits.
    ``remat`` checkpoints each repeat unit so backward recomputes
    activations instead of saving them (126-layer archs).
    """
    x, tok = _embed_inputs(cfg, params, batch)
    bsz, s, _ = x.shape
    positions = _positions_for(cfg, batch, bsz, s)
    shared = params.get("shared_attn")

    seq_spec = _seq_parallel_spec(cfg, bsz, s) if cfg.seq_parallel and \
        not want_cache else None

    def body(carry, up):
        x = carry
        x, g, cache, aux, g_sh = _unit_forward(
            cfg, up, shared, x, positions,
            collect=collect_foof, want_cache=want_cache)
        if seq_spec is not None:
            # §Perf B3: between blocks the residual stream lives
            # seq-sharded over "model" — norms/adds/converts run S/|model|
            # per chip; the next block's matmul all-gathers it back.
            x = jax.lax.with_sharding_constraint(x, seq_spec)
        return x, (g, cache, g_sh)

    if remat:
        body = jax.checkpoint(body)
    x, (grams_units, cache_units, grams_shared) = jax.lax.scan(
        body, x, params["blocks"])
    x = L.apply_norm(cfg, params["final_norm"], x)

    grams = {
        "embed": {"w": _embed_gram(cfg, tok) if collect_foof else L.no_gram()},
        "blocks": grams_units,
        "final_norm": {k: L.no_gram() for k in params["final_norm"]},
        "head": {"w": L.block_gram(x.reshape(-1, cfg.d_model), cfg.foof_block)
                 if collect_foof else L.no_gram()},
    }
    if cfg.family == "hybrid":
        # shared-attn grams: mean over unit applications (stacked by scan)
        grams["shared_attn"] = jax.tree.map(lambda a: jnp.mean(a, axis=0),
                                            grams_shared)
    return x, grams, cache_units, {}


def _embed_gram(cfg: ModelConfig, tok):
    """Exact diagonal FOOF for the embedding: one-hot input covariance =
    token frequency diagonal (DESIGN.md §4.2)."""
    if tok is None:
        return L.no_gram()
    counts = jnp.zeros((cfg.vocab_size,), jnp.float32).at[tok.reshape(-1)].add(1.0)
    return counts / jnp.float32(tok.size)


def chunked_ce_loss(cfg: ModelConfig, head_w, x, labels, loss_mask=None,
                    chunk: int = 512):
    """Cross-entropy over [B,S] without materializing [B,S,V] logits."""
    bsz, s, d = x.shape
    nq = max(cfg.num_codebooks, 1)
    c = min(chunk, s)
    nchunk = s // c
    xc = x.reshape(bsz, nchunk, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(bsz, nchunk, c, *labels.shape[2:]).transpose(1, 0, 2, *range(3, labels.ndim + 1))
    if loss_mask is None:
        loss_mask = jnp.ones((bsz, s), jnp.float32)
    mc = loss_mask.reshape(bsz, nchunk, c).transpose(1, 0, 2)

    def body(carry, inp):
        xb, lb, mb = inp
        logits = (xb @ head_w).astype(jnp.float32)
        if nq > 1:
            logits = logits.reshape(*logits.shape[:-1], nq, cfg.vocab_size)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if nq > 1:
            nll = jnp.mean(nll, axis=-1)
        return (carry[0] + jnp.sum(nll * mb), carry[1] + jnp.sum(mb)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *,
            collect_foof: bool = False):
    x, grams, _, aux = forward(cfg, params, batch, collect_foof=collect_foof)
    loss = chunked_ce_loss(cfg, params["head"]["w"], x, batch["labels"],
                           batch.get("loss_mask"))
    return loss, {"grams": grams, **aux}


# ================================================================ decode =====

def init_cache(cfg: ModelConfig, bsz: int, max_seq: int, dtype=None):
    """Abstract-friendly cache init (works under eval_shape)."""
    dt = jnp.dtype(dtype or cfg.dtype)
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    units = []
    for _ in range(cfg.num_units):
        cu = {}
        if cfg.family == "hybrid":
            slen = max_seq
            if cfg.long_context_global_window and \
                    max_seq > cfg.long_context_global_window:
                slen = cfg.long_context_global_window
            cu["shared"] = {"k": jnp.zeros((bsz, kvh, slen, hd), dt),
                            "v": jnp.zeros((bsz, kvh, slen, hd), dt)}
        for i in range(cfg.layers_per_unit):
            if cfg.family in ("ssm", "hybrid"):
                cu[f"layer{i}"] = {
                    "ssm": jnp.zeros((bsz, cfg.ssm_heads, cfg.ssm_head_dim,
                                      cfg.ssm_state), jnp.float32),
                    "conv": jnp.zeros((bsz, cfg.conv_kernel - 1,
                                       cfg.d_inner + 2 * cfg.ssm_state), dt)}
            elif cfg.attention == "mla":
                cu[f"layer{i}"] = {
                    "ckv": jnp.zeros((bsz, max_seq, cfg.kv_lora_rank), dt),
                    "krope": jnp.zeros((bsz, max_seq, cfg.qk_rope_dim), dt)}
            else:
                win = (_gemma3_window(cfg, i) if cfg.local_per_global
                       else (cfg.sliding_window if cfg.attention == "sliding" else 0))
                slen = min(win, max_seq) if win > 0 else max_seq
                if win == 0 and cfg.long_context_global_window and \
                        max_seq > cfg.long_context_global_window:
                    slen = cfg.long_context_global_window
                cu[f"layer{i}"] = {"k": jnp.zeros((bsz, kvh, slen, hd), dt),
                                   "v": jnp.zeros((bsz, kvh, slen, hd), dt)}
        units.append(cu)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *units)


def abstract_cache(cfg: ModelConfig, bsz: int, max_seq: int):
    return jax.eval_shape(partial(init_cache, cfg, bsz, max_seq))


def cache_specs(cfg: ModelConfig, axis_sizes: dict, bsz: int, max_seq: int):
    """B → client axes (if divisible), cache seq → "model" (DESIGN §5)."""
    cache = abstract_cache(cfg, bsz, max_seq)
    msz = axis_sizes.get("model", 1)
    baxes = batch_spec(cfg, axis_sizes, bsz)

    def spec(leaf):
        shp = leaf.shape  # leading dim = n_units
        if len(shp) == 5 and shp[2] in (cfg.num_kv_heads,):        # [U,B,KV,S,hd]
            sax = "model" if shp[3] % msz == 0 and msz > 1 else None
            return P(None, baxes, None, sax, None)
        if len(shp) == 4 and shp[-1] == cfg.kv_lora_rank:          # ckv [U,B,S,r]
            sax = "model" if shp[2] % msz == 0 and msz > 1 else None
            return P(None, baxes, sax, None)
        if len(shp) == 4 and shp[-1] == cfg.qk_rope_dim:           # krope
            sax = "model" if shp[2] % msz == 0 and msz > 1 else None
            return P(None, baxes, sax, None)
        if len(shp) == 5:                                          # ssm [U,B,H,P,N]
            hax = "model" if shp[2] % msz == 0 and msz > 1 else None
            return P(None, baxes, hax, None, None)
        if len(shp) == 4:                                          # conv [U,B,K-1,C]
            cax = "model" if shp[3] % msz == 0 and msz > 1 else None
            return P(None, baxes, None, cax)
        return P(*([None] * len(shp)))

    return jax.tree.map(spec, cache)


def decode_step(cfg: ModelConfig, params: dict, cache, batch: dict, pos):
    """One-token decode. batch['tokens']: [B,1] (or embeds [B,1,D]).
    pos: scalar int32 = index of the new token. Returns (logits, cache)."""
    if cfg.frontend == "audio_stub":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = jnp.take(params["embed"]["w"], batch["tokens"], axis=0)
    shared = params.get("shared_attn")

    def body(carry, inp):
        x = carry
        up, cu = inp
        if cfg.family == "hybrid" and shared is not None:
            h = L.apply_norm(cfg, shared["norm"], x)
            csz = cu["shared"]["k"].shape[2]
            ring_win = csz if (cfg.long_context_global_window and
                               csz == cfg.long_context_global_window) else 0
            o, kc, vc = L.attn_decode(cfg, shared["attn"], h, pos,
                                      cu["shared"]["k"], cu["shared"]["v"],
                                      window=ring_win)
            x = x + o
            cu = dict(cu, shared={"k": kc, "v": vc})
        for i in range(cfg.layers_per_unit):
            p_i = jax.tree.map(lambda a: a[i], up)
            ci = cu[f"layer{i}"]
            has_ffn = cfg.family not in ("ssm", "hybrid")
            if cfg.family in ("ssm", "hybrid"):
                h = L.apply_norm(cfg, p_i["norm"], x)
                o, st, conv = S.mamba_decode(cfg, p_i["mamba"], h,
                                             ci["ssm"], ci["conv"])
                x = x + o
                ci = {"ssm": st, "conv": conv}
            elif cfg.attention == "mla":
                h = L.apply_norm(cfg, p_i["norm1"], x)
                o, ckv, krope = L.mla_decode(cfg, p_i["attn"], h, pos,
                                             ci["ckv"], ci["krope"])
                x = x + o
                ci = {"ckv": ckv, "krope": krope}
            else:
                h = L.apply_norm(cfg, p_i["norm1"], x)
                win = (_gemma3_window(cfg, i) if cfg.local_per_global
                       else (cfg.sliding_window if cfg.attention == "sliding" else 0))
                # global layers capped to a window in long-context mode also
                # run as ring buffers (cache shorter than max positions)
                eff_win = win if win > 0 else (
                    ci["k"].shape[2] if cfg.long_context_global_window and
                    ci["k"].shape[2] == cfg.long_context_global_window else 0)
                o, kc, vc = L.attn_decode(cfg, p_i["attn"], h, pos,
                                          ci["k"], ci["v"], window=eff_win)
                x = x + o
                ci = {"k": kc, "v": vc}
            cu = dict(cu)
            cu[f"layer{i}"] = ci
            if has_ffn:
                h2 = L.apply_norm(cfg, p_i["norm2"], x)
                key = "moe" if "moe" in p_i else "ffn"
                if cfg.num_experts:
                    o2, _, _ = L.moe_forward(cfg, p_i[key], h2)
                else:
                    o2, _ = L.mlp_forward(cfg, p_i[key], h2)
                x = x + o2
        return x, cu

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = (x @ params["head"]["w"]).astype(jnp.float32)
    return logits, new_cache


def pos_upper(cfg: ModelConfig) -> int:
    return 1 << 30


def prefill(cfg: ModelConfig, params: dict, batch: dict):
    """Full-sequence prefill: returns (last-position hidden, cache)."""
    x, _, cache, _ = forward(cfg, params, batch, want_cache=True)
    return x[:, -1:, :], cache


# ============================================================ accounting =====

def count_params(params) -> int:
    return sum(int(math.prod(p.shape)) for p in jax.tree.leaves(params))


def active_params(cfg: ModelConfig) -> int:
    """Per-token active parameter count (MoE: top-k fraction of experts)."""
    total = 0
    params = abstract_params(cfg)

    def add(path, leaf):
        nonlocal total
        n = int(math.prod(leaf.shape))
        keys = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        if "blocks" in keys and cfg.num_experts and keys[-1] in ("wi", "wo") \
                and leaf.ndim >= 3:
            n = n * cfg.experts_per_tok // cfg.num_experts
        total += n

    jax.tree_util.tree_map_with_path(add, params)
    return total


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (decode/prefill fwd)."""
    n = active_params(cfg)
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens

"""Composable model layers (pure functions, params as dict pytrees).

Every linear layer can optionally emit its FOOF statistic — the uncentered
input covariance A = (1/T)·XᵀX, block-diagonal within the layer
(DESIGN.md §4.2).  Gram leaves mirror param keys; params without a gram get
a size-0 placeholder so trees stay congruent through ``lax.scan``.

All attention is chunked/online-softmax (no S×S materialization), GQA
grouping is explicit, and decode paths operate on seq-sharded KV caches.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

NO_GRAM_SHAPE = (0,)


def no_gram(dtype=jnp.float32):
    return jnp.zeros(NO_GRAM_SHAPE, dtype)


def is_gram(x) -> bool:
    return x.ndim >= 2 and x.shape[-1] == x.shape[-2] and x.size > 0


def _model_axis_size() -> int:
    """Size of the ambient mesh's "model" axis (1 when tracing meshless)."""
    try:
        from repro.distributed.axes import ambient_mesh
        mesh = ambient_mesh()
        if mesh is not None and "model" in getattr(mesh, "axis_names", ()):
            return int(mesh.shape["model"])
    except Exception:
        pass
    return 1


def choose_block(d: int, cap: int, prefer_multiple: int = 1) -> int:
    """Largest divisor of d that is ≤ cap.  When ``prefer_multiple`` > 1,
    prefer a block size whose block-count nb = d/bs is a multiple of it —
    so the gram stack [nb, bs, bs] shards evenly over the model axis
    (§Perf iteration C2: replicated grams were 26 GB/chip on llama3-405b)."""
    if d <= cap:
        return d
    best = 1
    for b in range(cap, 0, -1):
        if d % b == 0:
            if best == 1:
                best = b
            if prefer_multiple > 1 and (d // b) % prefer_multiple == 0:
                return b
            if prefer_multiple <= 1:
                return b
    return best


def block_gram(x2d: jax.Array, block_cap: int) -> jax.Array:
    """A = (1/T) XᵀX as block-diagonal fp32 blocks: [nb, bs, bs], sharded
    over the model axis when nb divides it."""
    t, d = x2d.shape
    msz = _model_axis_size()
    bs = choose_block(d, block_cap, prefer_multiple=msz)
    nb = d // bs
    xb = x2d.reshape(t, nb, bs)
    a = jnp.einsum("tnb,tnc->nbc", xb, xb, preferred_element_type=jnp.float32)
    a = a / jnp.float32(t)
    if msz > 1 and nb % msz == 0:
        # Two-step constraint (§Perf C3): pin the einsum output REPLICATED so
        # GSPMD computes per-data-shard partial grams + all-reduce (0.9 GB on
        # olmo) instead of all-gathering every token over "data" to produce a
        # model-sharded output directly (measured 154 GB/chip of all-gather);
        # the replicated→sharded reshard afterwards is a free local slice.
        a = jax.lax.with_sharding_constraint(
            a, jax.sharding.PartitionSpec(None, None, None))
        a = jax.lax.with_sharding_constraint(
            a, jax.sharding.PartitionSpec("model", None, None))
    return a


# ---------------------------------------------------------------- norms ----

def init_norm(cfg: ModelConfig, d: int):
    if cfg.norm == "nonparametric":
        return {}
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        xf = xf * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        if cfg.norm == "layernorm":
            xf = xf * p["scale"] + p["bias"]
        # nonparametric (olmo): no affine
    return xf.astype(x.dtype)


# ----------------------------------------------------------------- rope ----

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: tuple = ()) -> jax.Array:
    """x: [..., S, hd]; positions: [B, S] (or [B, 3, S] for M-RoPE)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    if mrope_sections:
        # qwen2-vl M-RoPE: frequency bands are split across (t, h, w)
        # position streams.  positions: [B, 3, S].
        assert positions.ndim == 3
        sec = jnp.asarray(
            sum(([i] * s for i, s in enumerate(mrope_sections)), []), jnp.int32)
        # pos_per_freq[b, s, f] = positions[b, sec[f], s]
        pos = jnp.swapaxes(positions, 1, 2).astype(jnp.float32)  # [B, S, 3]
        pos = pos[..., sec]                              # [B, S, hd/2]
        ang = pos * freqs[None, None, :]                 # [B, S, hd/2]
        ang = ang[:, None, :, :] if x.ndim == 4 else ang  # broadcast heads
    else:
        assert positions.ndim == 2
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
        ang = ang[:, None, :, :] if x.ndim == 4 else ang
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------ chunked attention ----

def _mask_bias(qpos, kpos, window: int):
    """[Sq, Sk] additive bias: 0 where attendable, -inf otherwise."""
    ok = kpos[None, :] <= qpos[:, None]
    if window > 0:
        ok &= kpos[None, :] > qpos[:, None] - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def banded_attention(q, k, v, *, window: int, q_chunk: int = 512,
                     scale: float | None = None):
    """Sliding-window attention touching only the O(window) KV band per
    q-chunk (§Perf D1): a dynamic slice of k/v of span q_chunk+pad replaces
    the full-sequence KV scan — O(S·W) instead of O(S²) work/traffic.
    Exact (the mask uses true positions; edge clamping handled)."""
    b, h, sq, hd = q.shape
    hdv = v.shape[-1]
    kv = k.shape[1]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qc = min(q_chunk, sq)
    nq = sq // qc
    pad = min(-(-window // qc) * qc, sq - qc)     # window rounded up to qc
    span = qc + pad
    qg = (q.reshape(b, kv, g, sq, hd) * scale).reshape(
        b, kv, g, nq, qc, hd).transpose(3, 0, 1, 2, 4, 5)

    def q_block(qi, qblk):
        start = jnp.clip(qi * qc - pad, 0, sq - span)
        kblk = jax.lax.dynamic_slice(k, (0, 0, start, 0), (b, kv, span, hd))
        vblk = jax.lax.dynamic_slice(v, (0, 0, start, 0), (b, kv, span, hdv))
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qblk, kblk,
                       preferred_element_type=jnp.float32)
        qpos = qi * qc + jnp.arange(qc)
        kpos = start + jnp.arange(span)
        ok = (kpos[None, :] <= qpos[:, None]) & \
             (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(ok[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkgqc,bkcd->bkgqd", p.astype(vblk.dtype), vblk,
                          preferred_element_type=jnp.float32)

    outs = jax.lax.map(jax.checkpoint(lambda a: q_block(*a)),
                       (jnp.arange(nq), qg))
    return outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, h, sq, hdv).astype(
        v.dtype)


def chunked_attention(q, k, v, *, window: int = 0, q_chunk: int = 512,
                      kv_chunk: int = 1024, scale: float | None = None):
    """Flash-style causal attention in pure jnp (online softmax).

    q: [B, H, Sq, hd]; k, v: [B, KV, Sk, hd]; returns [B, H, Sq, hd].
    Sq == Sk (self-attention over the same segment).
    """
    b, h, sq, hd = q.shape
    if window > 0 and window + q_chunk < sq:
        # the band is narrower than the sequence → O(S·W) path
        return banded_attention(q, k, v, window=window, q_chunk=q_chunk,
                                scale=scale)
    hdv = v.shape[-1]               # MLA: v_head_dim ≠ qk head_dim
    kv = k.shape[1]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, k.shape[2])
    nq, nk = sq // qc, k.shape[2] // kc
    qg = q.reshape(b, kv, g, sq, hd) * scale
    qg = qg.reshape(b, kv, g, nq, qc, hd).transpose(3, 0, 1, 2, 4, 5)  # [nq,...]
    kb = k.reshape(b, kv, nk, kc, hd).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, kv, nk, kc, hdv).transpose(2, 0, 1, 3, 4)

    def q_block(qi, qblk):
        m0 = jnp.full((b, kv, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kv, g, qc, hdv), jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kblk, vblk = inp
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qblk, kblk,
                           preferred_element_type=jnp.float32)
            qpos = qi * qc + jnp.arange(qc)
            kpos = ki * kc + jnp.arange(kc)
            s = s + _mask_bias(qpos, kpos, window)[None, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # rows with everything masked keep m = -inf; guard the exp
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc)

        (m, l, acc), _ = jax.lax.scan(
            lambda c, i: (kv_step(c, i), None), (m0, l0, a0),
            (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out

    # remat each q-block: backward recomputes the per-chunk softmax instead
    # of saving O(S²) probability residuals (§Perf iteration C1 — cut the
    # olmo-1b train_4k per-chip peak from 17.4 GB).
    outs = jax.lax.map(jax.checkpoint(lambda args: q_block(*args)),
                       (jnp.arange(nq), qg))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, h, sq, hdv)
    return out.astype(v.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0):
    """Single-token attention against a (possibly seq-sharded) cache.

    q: [B, H, 1, hd]; caches: [B, KV, S, hd]; cache_len: scalar — number of
    valid cache positions (new token is at index cache_len - 1).
    """
    b, h, _, hd = q.shape
    kv, s = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, 1, hd) * (1.0 / math.sqrt(hd))
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    kpos = jnp.arange(s)
    ok = kpos < cache_len
    if window > 0:
        ok &= kpos >= cache_len - window
    scores = jnp.where(ok[None, None, None, None, :], scores, -jnp.inf)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bksd->bkgqd", (p / l).astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, 1, hd).astype(v_cache.dtype)


# ------------------------------------------------------------ GQA block ----

def init_attn(cfg: ModelConfig, rng) -> dict:
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2 = jax.random.split(rng)
    dt = jnp.dtype(cfg.dtype)
    std = d ** -0.5
    return {
        "wqkv": (jax.random.normal(k1, (d, (h + 2 * kvh) * hd)) * std).astype(dt),
        "wo": (jax.random.normal(k2, (h * hd, d)) * (h * hd) ** -0.5).astype(dt),
    }


def attn_forward(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
                 *, window: int = 0, collect: bool = False):
    """x: [B, S, D] (already normed). Returns (out [B,S,D], grams, kv)."""
    b, s, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    qkv = x @ p["wqkv"]
    q, k, v = jnp.split(qkv, [h * hd, (h + kvh) * hd], axis=-1)
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, kvh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, kvh, hd).transpose(0, 2, 1, 3)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    o = chunked_attention(q, k, v, window=window)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    out = o @ p["wo"]
    grams = {
        "wqkv": block_gram(x.reshape(-1, d), cfg.foof_block) if collect else no_gram(),
        "wo": block_gram(o.reshape(-1, h * hd), cfg.foof_block) if collect else no_gram(),
    }
    return out, grams, (k, v)


def attn_decode(cfg: ModelConfig, p: dict, x: jax.Array, pos, kcache, vcache,
                *, window: int = 0):
    """x: [B, 1, D]; caches [B, KV, S, hd]; pos: scalar index of new token."""
    b, _, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    qkv = x @ p["wqkv"]
    q, k, v = jnp.split(qkv, [h * hd, (h + kvh) * hd], axis=-1)
    q = q.reshape(b, 1, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, 1, kvh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, 1, kvh, hd).transpose(0, 2, 1, 3)
    posb = jnp.full((b, 1), pos, jnp.int32)
    if cfg.mrope_sections:
        posb = jnp.broadcast_to(posb[:, None, :], (b, 3, 1))
    q = apply_rope(q, posb, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, posb, cfg.rope_theta, cfg.mrope_sections)
    slot = pos if window <= 0 else pos % kcache.shape[2]
    kcache = jax.lax.dynamic_update_slice(kcache, k.transpose(0, 1, 2, 3).reshape(b, kvh, 1, hd),
                                          (0, 0, slot, 0))
    vcache = jax.lax.dynamic_update_slice(vcache, v.reshape(b, kvh, 1, hd),
                                          (0, 0, slot, 0))
    cache_len = jnp.minimum(pos + 1, kcache.shape[2])
    # ring-buffer windows: all stored entries are valid once wrapped
    o = decode_attention(q, kcache, vcache, cache_len,
                         window=0 if window <= 0 else kcache.shape[2] + 1)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * hd)
    return o @ p["wo"], kcache, vcache


# ------------------------------------------------------------- MLA block ----

def init_mla(cfg: ModelConfig, rng) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dr, dn, dv = cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    ks = jax.random.split(rng, 5)
    dt = jnp.dtype(cfg.dtype)

    def w(k, i, o):
        return (jax.random.normal(k, (i, o)) * i ** -0.5).astype(dt)

    return {
        "wq_a": w(ks[0], d, r_q),
        "wq_b": w(ks[1], r_q, h * (dn + dr)),
        "wkv_a": w(ks[2], d, r_kv + dr),
        "wkv_b": w(ks[3], r_kv, h * (dn + dv)),
        "wo": w(ks[4], h * dv, d),
        "q_norm": jnp.ones((r_q,), jnp.float32),
        "kv_norm": jnp.ones((r_kv,), jnp.float32),
    }


def _rms(x, scale):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (xf * scale).astype(x.dtype)


def mla_forward(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
                *, collect: bool = False):
    """DeepSeek-V2 Multi-head Latent Attention (training/prefill path)."""
    b, s, d = x.shape
    h = cfg.num_heads
    r_kv = cfg.kv_lora_rank
    dr, dn, dv = cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    cq = _rms(x @ p["wq_a"], p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(b, s, h, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckv_full = x @ p["wkv_a"]
    ckv, k_rope = ckv_full[..., :r_kv], ckv_full[..., r_kv:]
    ckv = _rms(ckv, p["kv_norm"])
    kvb = (ckv @ p["wkv_b"]).reshape(b, s, h, dn + dv).transpose(0, 2, 1, 3)
    k_nope, v = kvb[..., :dn], kvb[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)  # [B,1,S,dr]
    k_rope_h = jnp.broadcast_to(k_rope, (b, h, s, dr))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    o = chunked_attention(q_full, k_full, v, scale=1.0 / math.sqrt(dn + dr))
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dv)
    out = o @ p["wo"]
    grams = {
        "wq_a": block_gram(x.reshape(-1, d), cfg.foof_block) if collect else no_gram(),
        "wq_b": block_gram(cq.reshape(-1, cq.shape[-1]), cfg.foof_block) if collect else no_gram(),
        "wkv_a": no_gram(),   # same input covariance as wq_a — shared (DESIGN §4)
        "wkv_b": block_gram(ckv.reshape(-1, r_kv), cfg.foof_block) if collect else no_gram(),
        "wo": block_gram(o.reshape(-1, h * dv), cfg.foof_block) if collect else no_gram(),
        "q_norm": no_gram(), "kv_norm": no_gram(),
    }
    return out, grams, (ckv, k_rope[:, 0])


def mla_decode(cfg: ModelConfig, p: dict, x: jax.Array, pos, ckv_cache, krope_cache):
    """Absorbed MLA decode: attention runs in the latent space, so the cache
    is only [B, S, r_kv] + [B, S, dr] (DESIGN.md §5 decode sharding)."""
    b, _, d = x.shape
    h = cfg.num_heads
    r_kv = cfg.kv_lora_rank
    dr, dn, dv = cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    cq = _rms(x @ p["wq_a"], p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(b, 1, h, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    posb = jnp.full((b, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope, posb, cfg.rope_theta)

    ckv_full = x @ p["wkv_a"]
    ckv_new = _rms(ckv_full[..., :r_kv], p["kv_norm"])          # [B,1,r]
    krope_new = apply_rope(ckv_full[:, None, :, r_kv:], posb, cfg.rope_theta)[:, 0]
    ckv_cache = jax.lax.dynamic_update_slice(ckv_cache, ckv_new, (0, pos, 0))
    krope_cache = jax.lax.dynamic_update_slice(krope_cache, krope_new, (0, pos, 0))

    wkv_b = p["wkv_b"].reshape(r_kv, h, dn + dv)
    wk, wv = wkv_b[..., :dn], wkv_b[..., dn:]                    # [r,h,dn], [r,h,dv]
    q_lat = jnp.einsum("bhqd,rhd->bhqr", q_nope, wk)             # absorb W_k
    s_lat = jnp.einsum("bhqr,bsr->bhqs", q_lat, ckv_cache,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bhqd,bsd->bhqs", q_rope, krope_cache,
                        preferred_element_type=jnp.float32)
    scores = (s_lat + s_rope) / math.sqrt(dn + dr)
    valid = jnp.arange(ckv_cache.shape[1]) < (pos + 1)
    scores = jnp.where(valid[None, None, None, :], scores, -jnp.inf)
    pattn = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bhqr", pattn.astype(ckv_cache.dtype), ckv_cache)
    o = jnp.einsum("bhqr,rhd->bhqd", o_lat, wv)                  # absorb W_v
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * dv)
    return o @ p["wo"], ckv_cache, krope_cache


# -------------------------------------------------------------- MLP/MoE ----

def init_mlp(cfg: ModelConfig, rng, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2 = jax.random.split(rng)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wi": (jax.random.normal(k1, (d, 2 * f)) * d ** -0.5).astype(dt),
        "wo": (jax.random.normal(k2, (f, d)) * f ** -0.5).astype(dt),
    }


def mlp_forward(cfg: ModelConfig, p: dict, x: jax.Array, *, collect=False):
    b, s, d = x.shape
    gate_up = x @ p["wi"]
    gate, up = jnp.split(gate_up, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    out = h @ p["wo"]
    grams = {
        "wi": block_gram(x.reshape(-1, d), cfg.foof_block) if collect else no_gram(),
        "wo": block_gram(h.reshape(-1, h.shape[-1]), cfg.foof_block) if collect else no_gram(),
    }
    return out, grams


def init_moe(cfg: ModelConfig, rng) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (e, d, 2 * f)) * d ** -0.5).astype(dt),
        "wo": (jax.random.normal(ks[2], (e, f, d)) * f ** -0.5).astype(dt),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        k1, k2 = jax.random.split(ks[3])
        p["shared_wi"] = (jax.random.normal(k1, (d, 2 * fs)) * d ** -0.5).astype(dt)
        p["shared_wo"] = (jax.random.normal(k2, (fs, d)) * fs ** -0.5).astype(dt)
    return p


def _moe_mesh_info():
    """(client_axes, sizes dict) of the ambient mesh, or None."""
    try:
        from repro.distributed.axes import ambient_mesh
        mesh = ambient_mesh()
        names = tuple(getattr(mesh, "axis_names", ()) or ())
        if "model" not in names or int(mesh.shape["model"]) <= 1:
            return None
        client = tuple(a for a in ("pod", "data") if a in names)
        sizes = {a: int(mesh.shape[a]) for a in names}
        return client, sizes
    except Exception:
        return None


def _gram_plain(x2d: jax.Array, block_cap: int) -> jax.Array:
    """block_gram without sharding constraints (shard_map-island safe)."""
    t, d = x2d.shape
    bs = choose_block(d, block_cap)
    xb = x2d.reshape(t, d // bs, bs)
    a = jnp.einsum("tnb,tnc->nbc", xb, xb, preferred_element_type=jnp.float32)
    return a / jnp.float32(t)


def _moe_forward_shardmap(cfg: ModelConfig, p: dict, x: jax.Array, info,
                          *, collect=False):
    """§Perf A1: locality-aware MoE.  Every (client, expert-shard) chip holds
    its cohort's tokens (x is model-replicated) AND its expert shard's
    weights, so dispatch needs no communication; the k-expert combine is one
    psum over "model".  Capacity is per (cohort × expert) — an FL-natural
    semantics (each client cohort budgets its own expert traffic)."""
    from jax.sharding import PartitionSpec as P

    client_axes, sizes = info
    b, s, d = x.shape
    e, kk, f = cfg.num_experts, cfg.experts_per_tok, cfg.d_ff
    msz = sizes["model"]
    e_local = e // msz
    nclients = 1
    for a in client_axes:
        nclients *= sizes[a]
    shard_batch = client_axes and b % nclients == 0
    baxes = client_axes if shard_batch else None
    t_local = (b // nclients if shard_batch else b) * s
    cap = max(int(math.ceil(cfg.capacity_factor * t_local * kk / e)), 1)
    manual = set(client_axes) | {"model"} if shard_batch else {"model"}

    def island(x_l, router, wi, wo):
        bl = x_l.shape[0]
        xt = x_l.reshape(bl * s, d)
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, kk)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
        e_off = jax.lax.axis_index("model") * e_local
        buf, slot, keep, st, fg = _dispatch_local(
            xt, gate_vals, gate_idx, e, e_off, e_local, cap)
        gu = jnp.einsum("ecd,edf->ecf", buf, wi)
        gate_h, up_h = jnp.split(gu, 2, axis=-1)
        hbuf = jax.nn.silu(gate_h) * up_h
        obuf = jnp.einsum("ecf,efd->ecd", hbuf, wo)
        contrib = obuf.reshape(e_local * cap, d)
        gathered = jnp.where(keep[:, None],
                             contrib[jnp.clip(slot, 0, e_local * cap - 1)],
                             0.0)
        out_t = jax.ops.segment_sum(gathered * fg[:, None].astype(x_l.dtype),
                                    st, num_segments=bl * s)
        out = jax.lax.psum(out_t, "model").reshape(bl, s, d)
        if collect:
            gram_wo = jax.lax.pmean(_gram_plain(hbuf.reshape(-1, f),
                                                cfg.foof_block),
                                    tuple(manual))
        else:
            gram_wo = no_gram()
        # every (token, choice) is kept on exactly one model shard if it
        # fit that shard's capacity → global kept-frac = psum over "model"
        kept = jax.lax.psum(jnp.sum(keep.astype(jnp.float32)), "model") \
            / jnp.float32(keep.shape[0])
        if shard_batch:
            kept = jax.lax.pmean(kept, tuple(client_axes))
        return out, gram_wo, 1.0 - kept

    from repro.distributed.axes import shard_map as _shard_map
    bspec = P(baxes, None, None)
    out, gram_wo, dropped = _shard_map(
        island, in_specs=(bspec, P(), P("model", None, None),
                          P("model", None, None)),
        out_specs=(bspec, P(), P()),
        axis_names=manual, check=False,
    )(x, p["router"], p["wi"], p["wo"])

    xt_all = x.reshape(b * s, d)
    grams = {
        "router": block_gram(xt_all, cfg.foof_block) if collect else no_gram(),
        "wi": no_gram(),
        "wo": gram_wo,
    }
    aux = {"dropped_frac": dropped}
    if cfg.num_shared_experts:
        sgu = xt_all @ p["shared_wi"]
        sg, su = jnp.split(sgu, 2, axis=-1)
        sh = jax.nn.silu(sg) * su
        out = out + (sh @ p["shared_wo"]).reshape(b, s, d)
        grams["shared_wi"] = no_gram()
        grams["shared_wo"] = (block_gram(sh, cfg.foof_block) if collect
                              else no_gram())
    return out, grams, aux


def _dispatch_local(xt, gate_vals, gate_idx, e_global, e_off, e_local, cap):
    """Sort-based capacity dispatch of local tokens to local experts.
    Returns (buf [e_local, cap, D], slot, keep, st, flat_gate)."""
    t, d = xt.shape
    k = gate_idx.shape[-1]
    flat_e = gate_idx.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)
    se, st = flat_e[order], flat_tok[order]
    first = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(t * k) - first
    local = (se >= e_off) & (se < e_off + e_local)
    keep = (rank < cap) & local
    slot = (se - e_off) * cap + rank
    slot = jnp.where(keep, slot, e_local * cap)
    buf = jnp.zeros((e_local * cap + 1, d), xt.dtype).at[slot].set(xt[st])
    flat_gate = gate_vals.reshape(-1)[order]
    return buf[:-1].reshape(e_local, cap, d), slot, keep, st, flat_gate


def moe_forward(cfg: ModelConfig, p: dict, x: jax.Array, *, collect=False):
    """Top-k routed experts, sort-based capacity dispatch (no [T,E,C] one-hot).

    Expert grams are pooled across experts (DESIGN.md: pooled-expert FOOF) —
    the input covariance is computed over all tokens rather than per expert,
    keeping the statistic O(d²) instead of O(E·d²).

    With ``cfg.moe_shard_map`` and a live mesh, dispatch runs inside a
    shard_map island (§Perf A1): activations are model-replicated and
    data-sharded, expert weights are model-sharded — so every chip can route
    its own cohort's tokens to its own expert shard with ZERO communication,
    and the combine is a single psum over "model".  GSPMD's auto
    partitioning of the scatter instead all-gathers every token over "data"
    (measured 907 s of collectives on qwen3-moe train_4k).
    """
    if cfg.moe_shard_map:
        info = _moe_mesh_info()
        if info is not None:
            return _moe_forward_shardmap(cfg, p, x, info, collect=collect)
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_tok
    f = cfg.d_ff
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                # [T,k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    cap = int(math.ceil(cfg.capacity_factor * t * k / e))
    cap = max(cap, 1)
    flat_e = gate_idx.reshape(-1)                                # [T*k]
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)
    se, st = flat_e[order], flat_tok[order]
    # rank within expert = position - first position of that expert id
    first = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(t * k) - first
    keep = rank < cap
    slot = se * cap + rank                                       # [T*k]
    slot = jnp.where(keep, slot, e * cap)                        # overflow → dropped
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(xt[st]).astype(x.dtype)
    buf = buf[:-1].reshape(e, cap, d)

    gu = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    gate_h, up_h = jnp.split(gu, 2, axis=-1)
    hbuf = jax.nn.silu(gate_h) * up_h                            # [e,cap,f]
    obuf = jnp.einsum("ecf,efd->ecd", hbuf, p["wo"])

    # gather back + weighted combine over the k choices
    flat_gate = gate_vals.reshape(-1)[order]
    contrib = obuf.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], contrib[jnp.clip(slot, 0, e * cap - 1)], 0.0)
    out_t = jax.ops.segment_sum(gathered * flat_gate[:, None].astype(x.dtype),
                                st, num_segments=t)
    out = out_t.reshape(b, s, d)

    grams = {
        "router": block_gram(xt, cfg.foof_block) if collect else no_gram(),
        "wi": no_gram(),      # pooled: shares router's input covariance
        "wo": block_gram(hbuf.reshape(-1, f), cfg.foof_block) if collect else no_gram(),
    }
    aux = {"router_probs_mean": jnp.mean(probs, axis=0),
           "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    if cfg.num_shared_experts:
        sgu = xt @ p["shared_wi"]
        sg, su = jnp.split(sgu, 2, axis=-1)
        sh = jax.nn.silu(sg) * su
        out = out + (sh @ p["shared_wo"]).reshape(b, s, d)
        grams["shared_wi"] = no_gram()
        grams["shared_wo"] = (block_gram(sh, cfg.foof_block) if collect else no_gram())
    return out, grams, aux

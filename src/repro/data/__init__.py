from repro.data.synthetic import (
    make_libsvm_like, make_clustered_classification, make_image_classification,
    make_lm_tokens, LIBSVM_SPECS,
)
from repro.data.federated import (
    FederatedDataset, DeviceDataBank, HostPagedBank, build_round_batches,
    steps_per_epoch,
)
from repro.data.streaming import (
    StreamingFederatedDataset, StreamWriter, bucket_boundaries,
)

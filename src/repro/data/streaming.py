"""On-disk federated datasets: manifest + raw-array files, opened lazily.

A :class:`StreamingFederatedDataset` is the DISK form of a partitioned
federated dataset — the same four arrays a :class:`repro.data.federated.
HostPagedBank` holds in host numpy (shared features ``x``/``y``, the
``[N, M]`` cyclic-padded per-client index table, ``[N]`` true shard
sizes), stored as raw little-endian files beside a ``manifest.json`` that
records shapes and dtypes.  Nothing is loaded at ``open`` time: each
array is an ``np.memmap`` materialized on first touch, so a 10⁶-client
dataset costs an ``open`` + four ``mmap`` calls until a chunk's rows
fault pages in.  :meth:`mmap_bank` wraps the maps in a
:class:`repro.fl.coldstore.MmapPagedBank` — the disk rung of the
ClientStore residency ladder.

Datasets are WRITTEN in blocks (:meth:`writer` → :class:`StreamWriter`)
so the producer never holds more than one block in RAM — the ingest path
for shard sources that don't fit in memory — or converted whole from an
in-memory :class:`~repro.data.federated.FederatedDataset` with
:meth:`from_dataset` (block-copied, same bound).

Bucketing-by-shard-size: ragged FEMNIST-style shards make the padded
``[N, M]`` index table wasteful to STAGE — a chunk whose union holds
only small shards still pads to the global max M.  :func:`
bucket_boundaries` builds a geometric ladder of staging widths;
passing it to :meth:`mmap_bank` lets the bank trim each staged chunk to
the smallest bucket covering the union's true max shard size (see
``MmapPagedBank._stage`` for the value-invariance argument).
"""
from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass, field
from functools import cached_property

import numpy as np

__all__ = ["StreamingFederatedDataset", "StreamWriter", "bucket_boundaries"]

FORMAT = "repro-streamfed-v1"

#: rows per block when converting an in-memory dataset (bounds writer RSS)
BLOCK_ROWS = 1 << 14

_FILES = {"x": "x.mmap", "y": "y.mmap", "idx": "idx.mmap",
          "sizes": "sizes.mmap"}


def bucket_boundaries(max_size: int, *, min_m: int = 8,
                      factor: float = 1.5) -> tuple:
    """Geometric ladder of staging widths ``(min_m, …, max_size)``.

    Each bucket is ≤ ``factor`` × the previous, so trimming to a bucket
    wastes at most ``factor − 1`` of the staged width while keeping the
    number of distinct staged shapes — and hence compiled chunk
    programs — logarithmic in ``max_size``."""
    if max_size < 1:
        raise ValueError(f"max_size must be >= 1, got {max_size}")
    out, b = [], min(min_m, max_size)
    while b < max_size:
        out.append(b)
        b = max(b + 1, int(b * factor))
    out.append(max_size)
    return tuple(out)


def _normalize(meta: dict) -> dict:
    for k in ("x_shape", "y_shape"):
        meta[k] = tuple(meta[k])
    return meta


@dataclass
class StreamingFederatedDataset:
    """A federated dataset on disk: four raw-array files + a manifest.

    ``meta`` keys: ``format``, ``n_samples``, ``n_clients``, ``m`` (max
    shard length, the index table's padded width), ``x_shape``/``x_dtype``
    (per-SAMPLE trailing shape, e.g. ``(16,)`` float32) and ``y_shape``/
    ``y_dtype``.  The array properties are lazy read-only memmaps.
    """
    directory: str
    meta: dict

    # ------------------------------------------------------------- open --

    @classmethod
    def open(cls, directory: str) -> "StreamingFederatedDataset":
        with open(os.path.join(directory, "manifest.json")) as f:
            meta = json.load(f)
        if meta.get("format") != FORMAT:
            raise ValueError(f"{directory}: not a {FORMAT} manifest "
                             f"(format={meta.get('format')!r})")
        return cls(directory=directory, meta=_normalize(meta))

    @property
    def n_clients(self) -> int:
        return int(self.meta["n_clients"])

    @property
    def n_samples(self) -> int:
        return int(self.meta["n_samples"])

    def _map(self, name: str, dtype, shape) -> np.memmap:
        return np.memmap(os.path.join(self.directory, _FILES[name]),
                         dtype=dtype, mode="r", shape=shape)

    @cached_property
    def x(self) -> np.memmap:
        return self._map("x", self.meta["x_dtype"],
                         (self.n_samples, *self.meta["x_shape"]))

    @cached_property
    def y(self) -> np.memmap:
        return self._map("y", self.meta["y_dtype"],
                         (self.n_samples, *self.meta["y_shape"]))

    @cached_property
    def idx(self) -> np.memmap:
        return self._map("idx", np.int64,
                         (self.n_clients, int(self.meta["m"])))

    @cached_property
    def sizes(self) -> np.memmap:
        return self._map("sizes", np.int32, (self.n_clients,))

    # ------------------------------------------------------------ write --

    @classmethod
    def writer(cls, directory: str, *, x_shape, x_dtype, y_shape, y_dtype,
               m: int) -> "StreamWriter":
        """Open a block-at-a-time writer (the out-of-core ingest path)."""
        return StreamWriter(directory=directory, x_shape=tuple(x_shape),
                            x_dtype=np.dtype(x_dtype),
                            y_shape=tuple(y_shape),
                            y_dtype=np.dtype(y_dtype), m=int(m))

    @classmethod
    def from_dataset(cls, ds, *, directory: str | None = None
                     ) -> "StreamingFederatedDataset":
        """Spill an in-memory :class:`repro.data.federated.
        FederatedDataset` to disk, block by block (writer RSS stays one
        block regardless of dataset size).  ``directory=None`` → a fresh
        temp dir; the files persist until the caller (or an owning
        :class:`~repro.fl.coldstore.MmapPagedBank`) removes them."""
        if directory is None:
            directory = tempfile.mkdtemp(prefix="repro-streamfed-")
        idx, sizes = ds._padded_index()
        w = cls.writer(directory, x_shape=ds.x.shape[1:], x_dtype=ds.x.dtype,
                       y_shape=ds.y.shape[1:], y_dtype=ds.y.dtype,
                       m=idx.shape[1])
        for lo in range(0, len(ds.x), BLOCK_ROWS):
            w.add_samples(ds.x[lo:lo + BLOCK_ROWS],
                          ds.y[lo:lo + BLOCK_ROWS])
        for lo in range(0, len(idx), BLOCK_ROWS):
            w.add_clients(idx[lo:lo + BLOCK_ROWS],
                          sizes[lo:lo + BLOCK_ROWS])
        return w.finalize()

    # ------------------------------------------------------------- bank --

    def bucket_boundaries(self, *, min_m: int = 8,
                          factor: float = 1.5) -> tuple:
        """Staging-width ladder for this dataset's M (see
        :func:`bucket_boundaries`)."""
        return bucket_boundaries(int(self.meta["m"]), min_m=min_m,
                                 factor=factor)

    def mmap_bank(self, steps: int, batch: int, *, boundaries=None,
                  owned: bool = False):
        """Open the disk-tier ClientStore over this dataset's files: a
        :class:`repro.fl.coldstore.MmapPagedBank` staging chunk unions
        straight from the maps.  ``owned=True`` hands the bank the
        dataset's directory to finalize (temp-dir datasets);
        ``boundaries`` turns on bucketed staging widths."""
        # lazy: repro.fl.coldstore imports this module's sibling
        # federated.py — importing it at module scope would cycle
        from repro.fl.coldstore import MmapPagedBank
        from repro.data.federated import _BankSpec
        sizes = self.sizes
        return MmapPagedBank(
            x=self.x, y=self.y, idx=self.idx, sizes=sizes,
            spec=_BankSpec(steps=steps, batch=batch,
                           min_size=int(np.asarray(sizes).min())),
            boundaries=boundaries,
            directory=self.directory if owned else None)


@dataclass
class StreamWriter:
    """Block-appending writer for :class:`StreamingFederatedDataset`.

    ``add_samples`` / ``add_clients`` append raw bytes through buffered
    file handles (never building the full arrays), ``finalize`` validates
    the index table against the sample count, writes the manifest and
    returns the opened dataset."""
    directory: str
    x_shape: tuple
    x_dtype: np.dtype
    y_shape: tuple
    y_dtype: np.dtype
    m: int
    n_samples: int = 0
    n_clients: int = 0
    _max_idx: int = field(default=-1, repr=False)
    _files: dict = field(default_factory=dict, repr=False)

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._files = {k: open(os.path.join(self.directory, v), "wb")
                       for k, v in _FILES.items()}

    def _append(self, name: str, block: np.ndarray, dtype, trailing):
        block = np.ascontiguousarray(block, dtype=dtype)
        if block.shape[1:] != tuple(trailing):
            raise ValueError(f"{name} block has trailing shape "
                             f"{block.shape[1:]}, expected {trailing}")
        self._files[name].write(block.tobytes())
        return len(block)

    def add_samples(self, x_block, y_block) -> None:
        nx = self._append("x", x_block, self.x_dtype, self.x_shape)
        ny = self._append("y", y_block, self.y_dtype, self.y_shape)
        if nx != ny:
            raise ValueError(f"x block ({nx}) and y block ({ny}) disagree")
        self.n_samples += nx

    def add_clients(self, idx_block, sizes_block) -> None:
        idx_block = np.ascontiguousarray(idx_block, dtype=np.int64)
        ni = self._append("idx", idx_block, np.int64, (self.m,))
        ns = self._append("sizes", np.asarray(sizes_block).reshape(-1),
                          np.int32, ())
        if ni != ns:
            raise ValueError(f"idx block ({ni}) and sizes block ({ns}) "
                             "disagree")
        if idx_block.size:
            self._max_idx = max(self._max_idx, int(idx_block.max()))
        self.n_clients += ni

    def finalize(self) -> StreamingFederatedDataset:
        for f in self._files.values():
            f.close()
        if self._max_idx >= self.n_samples:
            raise ValueError(f"index table references sample "
                             f"{self._max_idx} but only {self.n_samples} "
                             "samples were written")
        meta = {"format": FORMAT, "n_samples": self.n_samples,
                "n_clients": self.n_clients, "m": self.m,
                "x_shape": list(self.x_shape),
                "x_dtype": self.x_dtype.name,
                "y_shape": list(self.y_shape),
                "y_dtype": self.y_dtype.name}
        with open(os.path.join(self.directory, "manifest.json"), "w") as f:
            json.dump(meta, f, indent=1)
        return StreamingFederatedDataset(directory=self.directory,
                                         meta=_normalize(meta))

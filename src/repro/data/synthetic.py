"""Synthetic dataset generators, statistically matched to the paper's data.

The offline container has no LibSVM/CIFAR/FEMNIST; DESIGN.md §7 records the
substitution.  Shapes/sizes follow the paper exactly:
  w8a: d=300, 142 clients × 350 samples      a9a: d=123, 80 × 407
  cifar10-like: 32×32×3, 10 classes          cifar100-like: 100 classes
  femnist-like: 28×28×1, 62 classes, ragged writers
"""
from __future__ import annotations

import numpy as np

LIBSVM_SPECS = {
    # name: (d, n_clients, samples_per_client)
    "w8a": (300, 142, 350),
    "a9a": (123, 80, 407),
}


def make_libsvm_like(name: str, seed: int = 0):
    """Sparse-ish binary classification matching the LibSVM set's shape.
    Features are bernoulli-gated gaussians (LibSVM a9a/w8a are sparse
    binary); labels from a ground-truth hyperplane + 10% flip noise."""
    d, n_clients, per = LIBSVM_SPECS[name]
    rng = np.random.default_rng(seed)
    n = n_clients * per
    density = 0.15
    x = rng.normal(size=(n, d)) * (rng.random((n, d)) < density)
    x = x.astype(np.float32)
    theta_star = rng.normal(size=d).astype(np.float32) / np.sqrt(d)
    margin = x @ theta_star + 0.1 * rng.normal(size=n)
    y = np.sign(margin).astype(np.float32)
    y[y == 0] = 1.0
    flip = rng.random(n) < 0.10
    y[flip] *= -1.0
    return {"x": x, "y": y, "n_clients": n_clients, "per_client": per}


def make_clustered_classification(n: int, d: int, classes: int, seed: int = 0,
                                  spread: float = 1.0):
    """Gaussian class clusters in R^d (MLP-scale stand-in for CIFAR feats)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(classes, d)).astype(np.float32) * 2.0
    y = rng.integers(0, classes, size=n)
    x = centers[y] + spread * rng.normal(size=(n, d)).astype(np.float32)
    return {"x": x.astype(np.float32), "y": y.astype(np.int32)}


def make_image_classification(n: int, hw: int, ch: int, classes: int,
                              seed: int = 0, noise: float = 0.6):
    """Low-res images: smooth per-class templates + pixel noise (CNN-scale
    stand-in for CIFAR10/100)."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(classes, hw, hw, ch)).astype(np.float32)
    # smooth templates so conv layers have structure to find
    for _ in range(2):
        base = (base + np.roll(base, 1, 1) + np.roll(base, -1, 1)
                + np.roll(base, 1, 2) + np.roll(base, -1, 2)) / 5.0
    y = rng.integers(0, classes, size=n)
    x = base[y] + noise * rng.normal(size=(n, hw, hw, ch)).astype(np.float32)
    return {"x": x.astype(np.float32), "y": y.astype(np.int32)}


def make_lm_tokens(vocab: int, n_tokens: int, seed: int = 0,
                   zipf_a: float = 1.2) -> np.ndarray:
    """Zipf-distributed token stream with local bigram structure (so a small
    LM has something learnable)."""
    rng = np.random.default_rng(seed)
    ranks = rng.zipf(zipf_a, size=n_tokens)
    toks = np.minimum(ranks - 1, vocab - 1).astype(np.int32)
    # inject bigram structure: every even position predicts (prev*7+1) % vocab
    idx = np.arange(1, n_tokens, 2)
    toks[idx] = (toks[idx - 1] * 7 + 1) % vocab
    return toks

"""Federated dataset container + per-round batch construction.

``build_round_batches`` produces the [N, K, B, ...] pytree the simulate
engine vmaps over.  Clients hold ragged shards (Dirichlet partition); each
round every client samples K·B indices from its own shard (with replacement
when the shard is small — the uniform-K requirement of a vmapped engine,
DESIGN.md §7).

``DeviceDataBank`` (built by :meth:`FederatedDataset.device_bank`) is the
scan-compiled engine's data path: the whole federated dataset lives
RESIDENT on device as padded per-client rows, and per-round batches are
drawn in-graph by ``bank.sample(rng, participants)`` — no host round-trip
between evals.  Ragged (FEMNIST-class writer) shards are padded to the max
shard length; sampling draws indices uniformly below each client's TRUE
shard size, so padding rows are never read.

Both banks implement the :class:`repro.fl.store.ClientStore` protocol
(gather / scatter / prefetch):

* ``DeviceDataBank`` is the *resident* store — ``gather`` hands the whole
  bank to the engine, which takes cohort rows in-graph (bit-for-bit
  today's behavior, donation aliasing included);
* ``HostPagedBank`` (built by :meth:`FederatedDataset.paged_bank`) is the
  *paged* store for N ≫ cohort populations: the dataset stays in host
  memory as numpy (features shared, per-client index rows — never the
  ``[N, M, ...]`` materialization), and ``gather(rows)`` stages only the
  hot rows a chunk touches as a ``[U, M, ...]`` ``DeviceDataBank`` view.
  ``prefetch`` pre-stages the next chunk's rows while the current chunk
  computes (double-buffering over the scanned chunk boundary); data is
  read-only, so ``scatter`` is a no-op.

One rung further out, :meth:`FederatedDataset.mmap_bank` spills the
dataset to disk (``repro.data.streaming``) and opens the mmap-backed
``MmapPagedBank`` twin (``repro.fl.coldstore``) — same protocol, cold
storage on disk instead of host RAM.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.partition import dirichlet_partition, even_partition


@dataclass
class FederatedDataset:
    x: np.ndarray
    y: np.ndarray
    shards: list                      # list of index arrays, one per client
    test_x: np.ndarray | None = None
    test_y: np.ndarray | None = None

    @property
    def n_clients(self) -> int:
        return len(self.shards)

    @classmethod
    def from_arrays(cls, data: dict, n_clients: int, *, alpha: float = 0.0,
                    seed: int = 0, test_frac: float = 0.15):
        """alpha == 0 → homogeneous even split; alpha > 0 → Dirichlet(α)."""
        rng = np.random.default_rng(seed)
        x, y = data["x"], data["y"]
        n = len(x)
        perm = rng.permutation(n)
        n_test = int(n * test_frac)
        test_idx, train_idx = perm[:n_test], perm[n_test:]
        xt, yt = x[train_idx], y[train_idx]
        if alpha > 0:
            labels = yt.astype(np.int64) if yt.dtype.kind in "iu" else \
                ((yt > 0).astype(np.int64))
            shards = dirichlet_partition(labels, n_clients, alpha, rng)
        else:
            shards = even_partition(len(xt), n_clients, rng)
        return cls(x=xt, y=yt, shards=shards,
                   test_x=x[test_idx], test_y=y[test_idx])

    def test_batch(self, max_n: int = 4096) -> dict:
        return {"x": jnp.asarray(self.test_x[:max_n]),
                "y": jnp.asarray(self.test_y[:max_n])}

    def _padded_index(self):
        """[N, M] per-client sample indices, cyclic-padded to the max
        shard length M (padding rows are never sampled: ridx < size)."""
        sizes = np.array([len(s) for s in self.shards], np.int32)
        m = int(sizes.max())
        rows = [np.asarray(s)[np.arange(m) % len(s)] for s in self.shards]
        return np.stack(rows), sizes

    def device_bank(self, steps: int, batch: int) -> "DeviceDataBank":
        """Upload the whole partitioned dataset as a resident
        :class:`DeviceDataBank` — the scan-compiled engine's data path.

        ``batch == 0`` selects full-shard mode (each of ``steps`` steps
        sees the client's first ``min-shard-size`` samples, matching
        :meth:`client_full_batches`)."""
        idx, sizes = self._padded_index()
        return DeviceDataBank(
            x=jnp.asarray(self.x[idx]), y=jnp.asarray(self.y[idx]),
            sizes=jnp.asarray(sizes),
            spec=_BankSpec(steps=steps, batch=batch,
                           min_size=int(sizes.min())))

    def paged_bank(self, steps: int, batch: int) -> "HostPagedBank":
        """Build the host-paged :class:`HostPagedBank` — the out-of-core
        data path for N ≫ cohort populations.

        Unlike :meth:`device_bank`, NOTHING is uploaded and the
        ``[N, M, ...]`` per-client materialization never exists anywhere:
        host memory is the shared feature arrays plus an ``[N, M]`` index
        table, and only the rows a chunk's cohorts touch are staged to
        device (``gather``)."""
        idx, sizes = self._padded_index()
        return HostPagedBank(
            x=np.ascontiguousarray(self.x), y=np.ascontiguousarray(self.y),
            idx=idx.astype(np.int64), sizes=sizes,
            spec=_BankSpec(steps=steps, batch=batch,
                           min_size=int(sizes.min())))

    def mmap_bank(self, steps: int, batch: int, *, directory=None,
                  boundaries=None):
        """Spill the dataset to disk and open the DISK-tier ClientStore —
        a :class:`repro.fl.coldstore.MmapPagedBank` staging chunk unions
        straight from the on-disk maps (see
        :class:`repro.data.streaming.StreamingFederatedDataset`).

        ``directory=None`` writes a fresh temp dir that the returned
        bank OWNS (removed on ``close()``/gc/interpreter exit, together
        with any paired :meth:`~repro.fl.coldstore.MmapPagedBank.
        state_store` placed under it); an explicit ``directory``
        persists.  ``boundaries`` enables bucketed staging widths
        (:func:`repro.data.streaming.bucket_boundaries`)."""
        from repro.data.streaming import StreamingFederatedDataset
        owned = directory is None
        sfd = StreamingFederatedDataset.from_dataset(
            self, directory=directory)
        return sfd.mmap_bank(steps, batch, boundaries=boundaries,
                             owned=owned)

    def client_full_batches(self, k_steps: int) -> dict:
        """[N, K, M, ...] — every step sees the client's full shard (Test 1:
        full gradients/Hessians). Requires equal shard sizes."""
        sizes = {len(s) for s in self.shards}
        m = min(sizes)
        xs = np.stack([self.x[s[:m]] for s in self.shards])
        ys = np.stack([self.y[s[:m]] for s in self.shards])
        reps = (1, k_steps) + (1,) * self.x.ndim
        return {"x": jnp.asarray(np.tile(xs[:, None], reps)),
                "y": jnp.asarray(np.tile(ys[:, None],
                                         (1, k_steps) + (1,) * (self.y.ndim)))}


@dataclass(frozen=True)
class _BankSpec:
    """Static half of a DeviceDataBank (shapes the scanned program keys on)."""
    steps: int
    batch: int                        # 0 → full-shard mode
    min_size: int


@dataclass(frozen=True)
class DeviceDataBank:
    """Resident federated data bank for in-graph batch construction.

    ``x``/``y`` are ``[N, M, ...]`` padded per-client rows (cyclic pad to
    the max shard length M); ``sizes[i] <= M`` is client *i*'s true shard
    size.  Two sampling modes, fixed at construction:

    * ``batch > 0`` — each call draws ``steps·batch`` indices per
      participant, uniform WITH replacement below the client's true size
      (the scan-compatible analog of :func:`build_round_batches`; the
      without-replacement host path stays available as the seeded numpy
      oracle for ``FedSim.run``), returning ``[S, steps, batch, ...]``.
    * ``batch == 0`` — full-shard mode (Test 1): every step sees the
      client's first ``min_size`` samples, tiled over ``steps``, matching
      :meth:`FederatedDataset.client_full_batches`; the rng is unused.
    """
    x: jax.Array
    y: jax.Array
    sizes: jax.Array                  # [N] int32 true shard sizes
    spec: _BankSpec

    is_resident = True                # ClientStore: engine gathers in-graph

    @property
    def n_clients(self) -> int:
        return self.x.shape[0]

    # ------------------------------------------- ClientStore conformance --
    # The resident store's gather/scatter are identities the ENGINE
    # performs in-graph (jnp.take / .at[].set inside the round jit) —
    # that's what keeps the resident path bit-for-bit and donation-aliased.
    # ``gather(rows)`` here builds an explicit [U, ...] staged view (used
    # by tests and by HostPagedBank as its staging target shape); the
    # engine never calls it on the hot path.

    def gather(self, rows, *, sharding=None) -> "DeviceDataBank":
        rows = jnp.asarray(rows, jnp.int32)
        take = lambda bank: jnp.take(bank, rows, axis=0)
        return DeviceDataBank(x=take(self.x), y=take(self.y),
                              sizes=jnp.take(self.sizes, rows),
                              spec=self.spec)

    def scatter(self, rows, staged) -> None:
        """Data is read-only — nothing to write back."""

    def prefetch(self, rows, *, sharding=None) -> None:
        """Resident: everything is already on device."""

    def one_client_struct(self) -> dict:
        """ShapeDtypeStruct pytree of ONE client's per-round batches, as
        :meth:`sample` would draw them (comm accounting, no execution)."""
        one = jax.eval_shape(
            lambda b: b.sample(jax.random.PRNGKey(0),
                               jnp.zeros((1,), jnp.int32)), self)
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), one)

    def sample(self, rng, participants) -> dict:
        """In-graph per-round batches for the cohort ``participants`` [S]."""
        steps, batch = self.spec.steps, self.spec.batch
        participants = jnp.asarray(participants, jnp.int32)
        if batch == 0:
            m = self.spec.min_size
            take = lambda bank: jnp.take(bank, participants, axis=0)[:, :m]
            tile = lambda rows: jnp.broadcast_to(
                rows[:, None], (rows.shape[0], steps, *rows.shape[1:]))
            return {"x": tile(take(self.x)), "y": tile(take(self.y))}
        need = steps * batch
        keys = jax.random.split(rng, participants.shape[0])

        def one(key, cid):
            ridx = jax.random.randint(key, (need,), 0,
                                      jnp.take(self.sizes, cid))

            def row(bank):
                r = jnp.take(jnp.take(bank, cid, axis=0), ridx, axis=0)
                return r.reshape(steps, batch, *r.shape[1:])

            return {"x": row(self.x), "y": row(self.y)}

        return jax.vmap(one)(keys, participants)


# the bank crosses jit boundaries as an ARGUMENT (arrays traced, spec
# static) — never closure-captured into a program as baked-in constants
jax.tree_util.register_dataclass(DeviceDataBank,
                                 data_fields=["x", "y", "sizes"],
                                 meta_fields=["spec"])


@dataclass
class HostPagedBank:
    """Host-paged federated data bank: the out-of-core ClientStore for
    N ≫ cohort populations (see ``repro.fl.store``).

    Cold storage is host numpy — the SHARED feature arrays plus an
    ``[N, M]`` per-client index table; the resident bank's ``[N, M, ...]``
    materialization never exists anywhere.  :meth:`gather` stages the hot
    rows a chunk's cohorts touch as a ``[U, M, ...]``
    :class:`DeviceDataBank` whose rows are bytewise the resident bank's
    rows for those clients (``staged.x[l] == resident.x[union[l]]``), so
    the engine's in-graph ``bank.sample`` draws IDENTICAL batches for a
    cohort remapped to staged positions — the equivalence the paged
    driver's fp32 contract rests on.

    :meth:`prefetch` pre-stages the next chunk's rows (``device_put``
    dispatches asynchronously) while the current chunk computes —
    double-buffering over the scanned chunk boundary.  Data is read-only,
    so :meth:`scatter` is a no-op.  NOT a pytree: the paged bank never
    crosses a jit boundary, only its staged views do.
    """
    x: np.ndarray                     # [n_samples, ...] shared features
    y: np.ndarray
    idx: np.ndarray                   # [N, M] int64 per-client sample rows
    sizes: np.ndarray                 # [N] int32 true shard sizes
    spec: _BankSpec

    is_resident = False               # ClientStore: driver pages at chunks

    def __post_init__(self):
        self._cache = {}              # prefetch key -> staged DeviceDataBank
        #: exact device bytes of the most recent gather (bench/tests)
        self.last_staged_bytes = 0

    @property
    def n_clients(self) -> int:
        return int(self.idx.shape[0])

    def host_bytes(self) -> int:
        """Total host (cold) bytes — what paging keeps OFF the device."""
        return sum(int(a.nbytes) for a in (self.x, self.y, self.idx,
                                           self.sizes))

    # ------------------------------------------- ClientStore conformance --

    @staticmethod
    def _key(rows, sharding):
        return (np.asarray(rows).tobytes(), sharding)

    def _stage(self, rows, sharding) -> DeviceDataBank:
        rows = np.asarray(rows)
        take = self.idx[rows]                              # [U, M]
        put = ((lambda a: jax.device_put(a, sharding))
               if sharding is not None else jnp.asarray)
        return DeviceDataBank(x=put(self.x[take]), y=put(self.y[take]),
                              sizes=put(self.sizes[rows]), spec=self.spec)

    def gather(self, rows, *, sharding=None) -> DeviceDataBank:
        """Stage client ``rows`` to device (consuming a matching
        :meth:`prefetch` if one is in flight)."""
        staged = self._cache.pop(self._key(rows, sharding), None)
        if staged is None:
            staged = self._stage(rows, sharding)
        self.last_staged_bytes = sum(
            int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
            for v in (staged.x, staged.y, staged.sizes))
        return staged

    def scatter(self, rows, staged) -> None:
        """Data is read-only — nothing to write back."""

    def prefetch(self, rows, *, sharding=None) -> None:
        """Begin staging ``rows`` for a later :meth:`gather` with the same
        arguments.  ``device_put`` returns before the transfer completes,
        so the copy overlaps the current chunk's compute."""
        key = self._key(rows, sharding)
        if key not in self._cache:
            self._cache[key] = self._stage(rows, sharding)

    def state_store(self, one_client, n: int):
        """Build the matching STATE tier for this bank's residency rung
        (``FedSim.init`` calls this so data and state page together).
        Host-paged data pairs with the host-numpy
        :class:`repro.fl.store.HostStateStore`; the disk-tier subclass
        overrides this with its mmap twin."""
        from repro.fl.store import HostStateStore
        return HostStateStore.broadcast(one_client, n)

    def one_client_struct(self) -> dict:
        """ShapeDtypeStruct pytree of ONE client's per-round batches —
        shape-identical to :meth:`DeviceDataBank.one_client_struct` on
        the resident twin (comm accounting without staging anything)."""
        steps, batch = self.spec.steps, self.spec.batch
        b = batch if batch else self.spec.min_size
        sds = lambda a: jax.ShapeDtypeStruct(
            (steps, b, *a.shape[1:]), jax.dtypes.canonicalize_dtype(a.dtype))
        return {"x": sds(self.x), "y": sds(self.y)}


def build_round_batches(ds: FederatedDataset, steps: int, batch: int,
                        rng: np.random.Generator, clients=None) -> dict:
    """Stochastic [N, K, B, ...] batches; replacement iff shard < K·B.

    ``clients`` (optional int array of client ids) restricts the build to
    the sampled cohort — leaves lead with S = len(clients) and host work
    scales with S, matching the simulate engine's gathered round.
    """
    shards = (ds.shards if clients is None
              else [ds.shards[int(c)] for c in clients])
    n = len(shards)
    need = steps * batch
    xs, ys = [], []
    for s in shards:
        replace = len(s) < need
        idx = rng.choice(s, size=need, replace=replace)
        xs.append(ds.x[idx])
        ys.append(ds.y[idx])
    x = np.stack(xs).reshape(n, steps, batch, *ds.x.shape[1:])
    y = np.stack(ys).reshape(n, steps, batch, *ds.y.shape[1:])
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def steps_per_epoch(ds: FederatedDataset, batch: int) -> int:
    """Mean shard size / batch (uniform-K approximation of 'one epoch')."""
    mean_sz = float(np.mean([len(s) for s in ds.shards]))
    return max(1, int(round(mean_sz / batch)))

"""Serving launcher: batched prefill + decode for any token-input arch.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
        --batch 4 --prompt-len 96 --max-new 32

Thin CLI over the same prefill/decode_step the decode_32k / long_500k
dry-run shapes lower at production scale (see examples/serve_decode.py
for the annotated walkthrough).
"""
from __future__ import annotations

import argparse

from repro.configs import ARCH_NAMES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()
    import sys
    sys.argv = ["serve_decode", "--arch", args.arch,
                "--batch", str(args.batch),
                "--prompt-len", str(args.prompt_len),
                "--max-new", str(args.max_new)]
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                    "..", "..", "..", "examples"))
    import serve_decode
    serve_decode.main()


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count at first init).

"""Multi-pod dry-run (spec §MULTI-POD DRY-RUN + §ROOFLINE ANALYSIS).

For every (architecture × input shape × mesh) combination this lowers and
compiles the real step function against ShapeDtypeStruct stand-ins — no
allocation — then extracts memory_analysis / cost_analysis / the collective
schedule and derives the three roofline terms (TPU v5e constants).

  train_4k    → the FedPM fused-K1 round (the paper's technique, Eq. 9)
  prefill_32k → full-sequence prefill returning the KV/SSM cache
  decode_*    → serve_step: ONE token against a seq-len cache

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # full baseline matrix
  python -m repro.launch.dryrun --all --mesh multi
Results append to benchmarks/results/dryrun.jsonl (one JSON per line).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config, shape_supported
from repro.core.algorithms import HParams
from repro.distributed.roofline import V5E, roofline_from_compiled
from repro.fl import distributed as D
from repro.launch.mesh import axis_sizes, make_production_mesh
from repro.models import transformer as T
from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results", "dryrun.jsonl")


# ============================================================ input specs ===

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for the step inputs (weak-type-correct,
    shardable, no device allocation)."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        if cfg.frontend == "audio_stub":
            batch = {"embeds": _sds((b, 1, cfg.d_model), dt)}
        else:
            batch = {"tokens": _sds((b, 1), jnp.int32)}
        return {"batch": batch,
                "cache": T.abstract_cache(cfg, b, s),
                "pos": _sds((), jnp.int32)}
    # train / prefill
    if cfg.frontend == "audio_stub":
        batch = {"embeds": _sds((b, s, cfg.d_model), dt),
                 "labels": _sds((b, s, cfg.num_codebooks), jnp.int32)}
    elif cfg.frontend == "vision_stub":
        p = cfg.frontend_tokens
        batch = {"tokens": _sds((b, s - p), jnp.int32),
                 "patches": _sds((b, p, cfg.d_model), dt),
                 "positions": _sds((b, 3, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32),
                 "loss_mask": _sds((b, s), jnp.float32)}
    else:
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32)}
    return {"batch": batch}


def batch_shardings(cfg: ModelConfig, shape: InputShape, mesh, batch):
    """Shard every batch leaf's leading (client/batch) dim when divisible."""
    sizes = axis_sizes(mesh)
    baxes = T.batch_spec(cfg, sizes, shape.global_batch)

    def spec(leaf):
        return NamedSharding(mesh, P(baxes, *([None] * (len(leaf.shape) - 1))))

    return jax.tree.map(spec, batch)


# ================================================================ lowering ===

#: §Perf variants: tag → ModelConfig field overrides
VARIANTS = {
    "moe_shard_map": {"moe_shard_map": True},
    "foof_block_512": {"foof_block": 512},
    "capacity_1.0": {"capacity_factor": 1.0},
    "fsdp_cols": {"fsdp_mode": "cols"},
    "seq_parallel": {"seq_parallel": True},
}


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool,
               algo: str = "fedpm", hp: HParams | None = None,
               extra_tag: str = ""):
    """Lower + compile one (arch × shape × mesh); return result dict."""
    import dataclasses as _dc
    cfg = get_config(arch)
    for tag in extra_tag.split("+"):
        if tag in VARIANTS:
            cfg = _dc.replace(cfg, **VARIANTS[tag])
    shape = INPUT_SHAPES[shape_name]
    # Serving uses inference-appropriate layouts (§Perf, measured):
    #  - weight-gather FSDP ("cols") helps training (grad+weight traffic)
    #    but blows up prefill/decode working sets → serve with "contract";
    #  - the shard_map MoE island wins for train/prefill (many tokens per
    #    expert) but loses at decode's 1-token dispatch → GSPMD-auto there.
    if shape.kind == "decode":
        cfg = _dc.replace(cfg, moe_shard_map=False, fsdp_mode="contract")
    elif shape.kind == "prefill":
        cfg = _dc.replace(cfg, fsdp_mode="contract")
    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = axis_sizes(mesh)
    hp = hp or HParams(lr=0.3, damping=1.0, inverse_method="ns", ns_iters=12)

    params = T.abstract_params(cfg)
    pspecs = T.param_specs(cfg, sizes)
    pshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs)
    specs = input_specs(cfg, shape)
    t0 = time.time()

    from repro.distributed.axes import use_mesh
    with use_mesh(mesh):
        if shape.kind == "decode":
            cshard = jax.tree.map(
                lambda sp: NamedSharding(mesh, sp),
                T.cache_specs(cfg, sizes, shape.global_batch, shape.seq_len))
            bshard = batch_shardings(cfg, shape, mesh, specs["batch"])
            fn = D.make_decode_step(cfg)
            lowered = jax.jit(
                fn,
                in_shardings=(pshard, cshard, bshard, None),
                out_shardings=(None, cshard),
                donate_argnums=(1,),
            ).lower(params, specs["cache"], specs["batch"], specs["pos"])
        elif shape.kind == "prefill":
            bshard = batch_shardings(cfg, shape, mesh, specs["batch"])
            cshard = jax.tree.map(
                lambda sp: NamedSharding(mesh, sp),
                T.cache_specs(cfg, sizes, shape.global_batch, shape.seq_len))
            fn = D.make_prefill_step(cfg)
            lowered = jax.jit(
                fn, in_shardings=(pshard, bshard),
                out_shardings=(None, cshard),
            ).lower(params, specs["batch"])
        elif algo == "fedpm_steady":
            # §Perf C4: the between-refresh step with cached inverses
            _, steady = D.make_amortized_steps(cfg, hp)
            bshard = batch_shardings(cfg, shape, mesh, specs["batch"])
            inverses = D.abstract_inverses(cfg, specs["batch"])
            msz = sizes.get("model", 1)

            def inv_spec(leaf):
                if leaf.ndim >= 3 and leaf.shape[-3] % msz == 0 and msz > 1:
                    return NamedSharding(mesh, P(
                        *([None] * (leaf.ndim - 3)), "model", None, None))
                return NamedSharding(mesh, P())

            ishard = jax.tree.map(inv_spec, inverses)
            lowered = jax.jit(
                steady, in_shardings=(pshard, ishard, bshard),
                out_shardings=(pshard, None),
                donate_argnums=(0,),
            ).lower(params, inverses, specs["batch"])
        else:  # train: the FedPM fused-K1 round (or the FO baseline)
            step = (D.make_fused_k1_step(cfg, hp) if algo == "fedpm"
                    else D.make_fedavg_step(cfg, hp))
            bshard = batch_shardings(cfg, shape, mesh, specs["batch"])
            lowered = jax.jit(
                step, in_shardings=(pshard, bshard),
                out_shardings=(pshard, None),
                donate_argnums=(0,),
            ).lower(params, specs["batch"])
        compiled = lowered.compile()

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rep = roofline_from_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        num_devices=mesh.size, model_flops=T.model_flops(cfg, shape))
    mem = compiled.memory_analysis()
    out = rep.as_dict()
    out.update({
        "algo": algo, "tag": extra_tag,
        "compile_s": round(time.time() - t0, 1),
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0) or 0),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0) or 0),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0) or 0),
        "hbm_capacity_per_chip": 16e9,         # v5e HBM capacity reference
    })
    return out


def append_result(res: dict, path: str = RESULTS):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(res) + "\n")


def run_matrix(meshes=("single",), arches=ARCH_NAMES, shapes=None,
               algo="fedpm", path: str = RESULTS, tag: str = ""):
    shapes = shapes or list(INPUT_SHAPES)
    done, failed = 0, []
    for arch in arches:
        cfg = get_config(arch)
        for shape_name in shapes:
            if not shape_supported(cfg, shape_name):
                append_result({"arch": arch, "shape": shape_name,
                               "skipped": "quadratic-attention arch; "
                               "long_500k requires sub-quadratic (DESIGN §5)"},
                              path)
                continue
            for mesh_kind in meshes:
                try:
                    res = lower_pair(arch, shape_name,
                                     multi_pod=(mesh_kind == "multi"),
                                     algo=algo, extra_tag=tag)
                    append_result(res, path)
                    done += 1
                    print(f"OK  {arch} {shape_name} {mesh_kind} "
                          f"dom={res['dominant']} "
                          f"compile={res['compile_s']}s", flush=True)
                except Exception as e:
                    failed.append((arch, shape_name, mesh_kind))
                    append_result({"arch": arch, "shape": shape_name,
                                   "mesh": mesh_kind,
                                   "error": f"{type(e).__name__}: {e}"[:500]},
                                  path)
                    print(f"FAIL {arch} {shape_name} {mesh_kind}: "
                          f"{type(e).__name__}: {str(e)[:200]}", flush=True)
    print(f"done={done} failed={len(failed)} {failed}")
    return failed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--algo", default="fedpm",
                    choices=["fedpm", "fedavg", "fedpm_steady"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="", help="'+'-joined VARIANTS keys")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()
    if args.all:
        arches = (args.arch,) if args.arch else ARCH_NAMES
        shapes = [args.shape] if args.shape else None
        run_matrix(meshes=(args.mesh,), arches=arches, shapes=shapes,
                   algo=args.algo, path=args.out, tag=args.tag)
        return
    res = lower_pair(args.arch, args.shape, multi_pod=(args.mesh == "multi"),
                     algo=args.algo, extra_tag=args.tag)
    append_result(res, args.out)
    print(json.dumps(res, indent=1))


if __name__ == "__main__":
    main()

"""Training launcher: federated FedPM training of any assigned arch.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
        --mode local_steps --k 4 --algo fedpm

Reduced configs run on the host devices; full configs are exercised via
``repro.launch.dryrun`` (this launcher refuses full configs on CPU).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import ARCH_NAMES, get_config
from repro.core.algorithms import HParams
from repro.data import make_lm_tokens
from repro.fl import distributed as D
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ARCH_NAMES)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config — needs a TPU mesh")
    ap.add_argument("--algo", default="fedpm", choices=["fedpm", "fedavg"])
    ap.add_argument("--mode", default="fused_k1",
                    choices=["fused_k1", "local_steps", "amortized"])
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--refresh-every", type=int, default=4)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.2)
    ap.add_argument("--damping", type=float, default=1.0)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    if args.full and jax.default_backend() == "cpu":
        raise SystemExit("full configs on CPU are dry-run only "
                         "(python -m repro.launch.dryrun)")
    cfg = get_config(args.arch, reduced=not args.full)
    if cfg.frontend != "none":
        raise SystemExit("token-input archs only in this launcher")
    hp = HParams(lr=args.lr, damping=args.damping, clip=1.0)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(cfg, rng)
    print(f"arch={cfg.name} params={T.count_params(params)/1e6:.1f}M "
          f"mode={args.mode} algo={args.algo}")

    mesh = make_host_mesh()
    bs = args.batch * (args.k if args.mode == "local_steps" else 1)
    stream = make_lm_tokens(cfg.vocab_size, (args.steps + 1) * bs * args.seq)

    from repro.distributed.axes import use_mesh
    ctx = use_mesh(mesh)
    ctx.__enter__()
    if args.mode == "local_steps":
        step = jax.jit(D.make_local_steps_round(cfg, hp, mesh, args.k))
    elif args.mode == "amortized":
        refresh, steady = D.make_amortized_steps(cfg, hp)
        refresh, steady = jax.jit(refresh), jax.jit(steady)
    else:
        step = jax.jit(D.make_fused_k1_step(cfg, hp) if args.algo == "fedpm"
                       else D.make_fedavg_step(cfg, hp))

    inverses = None
    t0 = time.time()
    for t in range(args.steps):
        lo = t * bs * args.seq
        toks = jnp.asarray(stream[lo:lo + bs * args.seq]).reshape(bs, args.seq)
        batch = {"tokens": toks, "labels": toks}
        if args.mode == "amortized":
            if t % args.refresh_every == 0:
                params, inverses, m = refresh(params, batch)
            else:
                params, m = steady(params, inverses, batch)
        else:
            params, m = step(params, batch)
        if t % 10 == 0 or t == args.steps - 1:
            print(f"step {t:4d} loss={float(m['loss']):.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, params, meta={"arch": cfg.name,
                                                 "steps": args.steps})
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()

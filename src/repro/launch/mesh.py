"""Production meshes (spec §MULTI-POD DRY-RUN).

Functions, not module-level constants — importing this module never touches
jax device state.  The dry-run sets XLA_FLAGS for 512 host devices *before*
any jax import; everything else sees the real device count.
"""
from __future__ import annotations

import jax

from repro.distributed.axes import make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist (tests/examples): 1×N ("data","model")."""
    n = jax.device_count()
    return make_auto_mesh((1, n), ("data", "model"))


def axis_sizes(mesh: jax.sharding.Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))

"""Small shared utilities: pytree math, rng, timing."""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y."""
    return jax.tree.map(lambda u, v: alpha * u + v, x, y)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a: PyTree, b: PyTree):
    leaves = jax.tree.map(lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_l2norm(a: PyTree):
    return jnp.sqrt(tree_dot(a, a))


def global_norm_clip(tree: PyTree, max_norm: float | None) -> PyTree:
    if max_norm is None:
        return tree
    norm = tree_l2norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return tree_scale(tree, scale)


def tree_num_params(a: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(a))


def tree_bytes(a: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_mean_over_axis0(a: PyTree) -> PyTree:
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), a)


def split_like(rng: jax.Array, tree: PyTree) -> PyTree:
    """One rng key per leaf of ``tree``."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))


class Stopwatch:
    def __init__(self):
        self.t0 = time.perf_counter()

    def lap_us(self) -> float:
        now = time.perf_counter()
        dt = (now - self.t0) * 1e6
        self.t0 = now
        return dt


def timeit_us(fn: Callable[[], Any], iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock microseconds of fn() (blocking on jax arrays)."""
    def run():
        out = fn()
        jax.block_until_ready(out)
        return out

    for _ in range(warmup):
        run()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))

"""Generate the README's algorithm-registry table from the live registry.

Usage::

    python scripts/gen_alg_table.py

and paste the output between the ``<!-- registry-table -->`` markers in
README.md (tests/test_api.py fails if a registered algorithm is missing
from the README).  Byte columns are EXACT per-client per-round wire
volumes at the shared reference sizes — the SAME
``benchmarks.bench_comm.reference_cost`` the gated ``comm/*`` bench rows
use (Test-2 MLP 64→128→64→10 at K=2×B=64 for layer-wise methods; the
Test-1 convex model, d=123 full-batch, for flat/Hessian methods), so the
README and the bench gate can never drift apart.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.bench_comm import reference_cost           # noqa: E402
from repro.core.algorithms import ALGORITHMS               # noqa: E402


def _kb(b: int) -> str:
    return f"{b / 1024:.1f} KiB" if b < 1 << 20 else f"{b / (1 << 20):.2f} MiB"


def main() -> None:
    print("| algorithm | cat | local update | server mixer | wire fields "
          "| transform | up/client | down/client |")
    print("|---|---|---|---|---|---|---|---|")
    for name in sorted(ALGORITHMS):
        a = ALGORITHMS[name]
        c = reference_cost(name)
        wire = ", ".join(a.message_cls.WIRE)
        tr = a.wire.name if a.wire is not None else "—"
        print(f"| `{name}` | {a.category} | {a.local.name} | {a.mixer.name} "
              f"| {wire} | {tr} | {_kb(c['bytes_up_per_client'])} "
              f"| {_kb(c['bytes_down_per_client'])} |")


if __name__ == "__main__":
    main()

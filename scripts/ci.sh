#!/usr/bin/env bash
# Tier-1 CI: install dev deps (best-effort — the suite degrades gracefully
# without them, see tests/hyp_compat.py), run the ROADMAP pytest command
# under a timeout, then an interpret-mode benchmark smoke that exercises
# every Pallas kernel path (gram, NS inverse, fused invert-and-apply) and
# the packed gram-bank engine — kernel regressions fail tier-1 cheaply.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt \
    || echo "WARN: dev deps not installed (offline?); running degraded suite"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    timeout "${CI_TIMEOUT:-1800}" python -m pytest -x -q

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    timeout "${CI_BENCH_TIMEOUT:-600}" python -m benchmarks.bench_cost --smoke

#!/usr/bin/env bash
# Tier-1 CI entry point.  Stages:
#
#   1. dev deps        best-effort pip install (suite degrades gracefully
#                      without hypothesis, see tests/hyp_compat.py);
#                      skipped with --fast (local pre-commit use)
#   2. pytest          ROADMAP tier-1 command + JUnit XML for the
#                      workflow's test-report annotation (CI_JUNIT path)
#   3. bench smoke     benchmarks.run --smoke writes BENCH_pr10.json; its
#       + gate         first stage is the interpret-mode kernel smoke
#                      (every Pallas path: gram, NS inverse, fused
#                      invert-and-apply, bank), then the gate rows
#                      (exact comm-bytes wire-transform on/off ratios,
#                      packed-vs-per-leaf, scanned-vs-per-round dispatch,
#                      K-sweep, paged-vs-resident ClientStore overhead +
#                      staged-bytes, the disk-tier mmap-vs-host-paged
#                      pair, sharded-vs-vmap on a forced 8-device
#                      host mesh); benchmarks.bench_gate fails tier-1 on
#                      >25% ratio regressions vs the checked-in
#                      benchmarks/baseline_pr10.json.
#                      CI_SKIP_BENCH_GATE=1 replaces this with the bare
#                      kernel smoke (benchmarks.bench_cost --smoke).
#   4. paged scale     benchmarks.bench_paging --scale in a FRESH process
#                      (own jax runtime, so the jax.live_arrays() device
#                      watermark is exact): N = 10^5 STATEFUL scaffold
#                      clients through the paged scanned driver, exiting
#                      nonzero unless the device peak stays a small
#                      fraction of the resident-equivalent footprint —
#                      the out-of-core property itself, N >> S, asserted
#                      end-to-end.  Skipped with CI_SKIP_BENCH_GATE=1.
#   5. coldtier scale  benchmarks.bench_paging --scale --tier mmap, also
#                      a FRESH process: N = 10^6 stateless clients
#                      streamed from a disk-backed StreamingFederatedDataset
#                      with peak RssAnon asserted against the cold bytes,
#                      then N = 2.5*10^5 STATEFUL scaffold clients through
#                      the mmap ClientStore with write-behind scatter
#                      overlap on/off timed and the device watermark
#                      asserted.  Skipped with CI_SKIP_BENCH_GATE=1.
#
# Every stage runs under `timeout`; exit 124 is reported as a TIMEOUT
# (infra budget exceeded), distinct from a test/bench FAILURE.
set -uo pipefail   # no -e: run_stage inspects exit codes itself
cd "$(dirname "$0")/.." || exit 1

FAST=0
for arg in "$@"; do
    case "$arg" in
        --fast) FAST=1 ;;
        *) echo "usage: scripts/ci.sh [--fast]" >&2; exit 2 ;;
    esac
done

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
JUNIT="${CI_JUNIT:-test-results.xml}"

# run_stage NAME TIMEOUT_SECS CMD... — distinguishes timeouts (124) from
# real failures so a budget overrun is never misread as a broken test
run_stage() {
    local name="$1" budget="$2"; shift 2
    echo "=== [$name] $*"
    timeout "$budget" "$@"
    local rc=$?
    if [[ $rc -eq 124 ]]; then
        echo "ERROR: [$name] TIMEOUT after ${budget}s (exit 124) — stage" \
             "exceeded its time budget; this is NOT a test failure" >&2
        exit 124
    elif [[ $rc -ne 0 ]]; then
        echo "ERROR: [$name] FAILED with exit code $rc" >&2
        exit "$rc"
    fi
}

if [[ $FAST -eq 0 ]]; then
    python -m pip install -q -r requirements-dev.txt \
        || echo "WARN: dev deps not installed (offline?); running degraded suite"
else
    echo "=== [deps] skipped (--fast)"
fi

run_stage pytest "${CI_TIMEOUT:-1800}" \
    python -m pytest -x -q --junitxml="$JUNIT"

if [[ "${CI_SKIP_BENCH_GATE:-0}" != 1 ]]; then
    # benchmarks.run --smoke starts with the full bench_cost kernel smoke,
    # so the gated path gets kernel coverage without running it twice
    run_stage bench-smoke "${CI_BENCH_TIMEOUT:-1500}" \
        python -m benchmarks.run --smoke
    run_stage bench-gate 120 \
        python -m benchmarks.bench_gate BENCH_pr10.json \
            benchmarks/baseline_pr10.json --tol 0.25
    run_stage paged-scale "${CI_PAGED_TIMEOUT:-600}" \
        python -m benchmarks.bench_paging --scale
    run_stage coldtier-scale "${CI_COLD_TIMEOUT:-900}" \
        python -m benchmarks.bench_paging --scale --tier mmap
else
    run_stage kernel-smoke "${CI_BENCH_TIMEOUT:-600}" \
        python -m benchmarks.bench_cost --smoke
    echo "=== [bench-gate] skipped (CI_SKIP_BENCH_GATE=1)"
fi

echo "=== tier-1 CI green"

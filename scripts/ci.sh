#!/usr/bin/env bash
# Tier-1 CI: install dev deps (best-effort — the suite degrades gracefully
# without them, see tests/hyp_compat.py) and run the ROADMAP pytest command
# under a timeout.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt \
    || echo "WARN: dev deps not installed (offline?); running degraded suite"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    timeout "${CI_TIMEOUT:-1800}" python -m pytest -x -q
